"""copyscore Pallas kernel vs jnp oracle — interpret mode, shape/dtype sweep
plus hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bucketed import pad_buckets
from repro.core.index import build_index, bucketize
from repro.core.types import CopyConfig
from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims
from repro.kernels.copyscore import copyscore_fused_pallas, copyscore_pallas
from repro.kernels.ops import copyscore, pad_for_copyscore
from repro.kernels.ref import copyscore_fused_ref, copyscore_ref

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _random_instance(rng, S, E, block_e):
    v = (rng.random((S, E)) < 0.15).astype(np.float32)
    p = rng.uniform(0.01, 0.99, size=E // block_e).astype(np.float32)
    acc = rng.uniform(0.05, 0.95, size=S).astype(np.float32)
    return v, p, acc


@pytest.mark.parametrize("S,E,bi,bj,be", [
    (128, 512, 128, 128, 512),
    (256, 1024, 128, 128, 256),
    (128, 256, 64, 64, 128),
    (384, 512, 128, 128, 512),
])
def test_kernel_matches_ref_shapes(S, E, bi, bj, be):
    rng = np.random.default_rng(S + E)
    v, p, acc = _random_instance(rng, S, E, be)
    c_k, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                                s=CFG.s, n_false=CFG.n, block_i=bi, block_j=bj,
                                block_e=be, interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=be)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), rtol=0, atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    v, p, acc = _random_instance(rng, 128, 512, 256)
    c_k, n_k = copyscore_pallas(jnp.asarray(v, dtype), jnp.asarray(p),
                                jnp.asarray(acc), s=CFG.s, n_false=CFG.n,
                                block_i=128, block_j=128, block_e=256,
                                interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=256)
    # incidence is 0/1 so bf16 inputs are exact; accumulation is f32 in both
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))


def test_ops_wrapper_pads_nonaligned_sources():
    rng = np.random.default_rng(1)
    v, p, acc = _random_instance(rng, 200, 512, 512)   # 200 % 128 != 0
    c_k, n_k = copyscore(v, p, acc, s=CFG.s, n_false=CFG.n, block_e=512,
                         impl="interpret")
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=512)
    assert c_k.shape == (200, 200)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)


def test_end_to_end_against_bucketed_index():
    """Kernel path == the production bucketed scorer on a real index."""
    sc = synthetic_claims(SyntheticSpec(n_sources=96, n_items=500,
                                        coverage="stock", n_cliques=4, seed=1))
    p_claim = oracle_claim_probs(sc)
    idx = build_index(sc.dataset, p_claim, CFG)
    b = bucketize(idx, 8)
    sizes = np.diff(b.starts)
    v_pad, p_blk, S = pad_for_copyscore(idx.V.astype(np.float32), b.p_hat,
                                        block_i=32, block_e=64,
                                        bucket_sizes=sizes)
    c_k, n_k = copyscore(v_pad, p_blk, np.pad(sc.dataset.accuracy,
                                              (0, v_pad.shape[0] - S),
                                              constant_values=0.5),
                         s=CFG.s, n_false=CFG.n, block_i=32, block_j=32,
                         block_e=64, impl="interpret")
    c_k = np.asarray(c_k)[:S, :S]

    padded = pad_buckets(b, dtype=jnp.float32)
    from repro.core.bucketed import _bucketed_accumulate
    c_ref, n_ref, _ = _bucketed_accumulate(padded.v_ksw, padded.p_hat,
                                           jnp.asarray(sc.dataset.accuracy),
                                           CFG.s, CFG.n, padded.ebar_bucket)
    np.testing.assert_allclose(c_k, np.asarray(c_ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_param=st.floats(0.05, 0.95),
    n_false=st.floats(2.0, 500.0),
)
def test_property_kernel_equals_oracle(seed, s_param, n_false):
    rng = np.random.default_rng(seed)
    v, p, acc = _random_instance(rng, 64, 128, 64)
    c_k, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                                s=s_param, n_false=n_false, block_i=32,
                                block_j=32, block_e=64, interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=s_param, n_false=n_false, block_e=64)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_counts_are_cooccurrences(seed):
    """n[i,j] must equal the exact integer co-occurrence count V Vᵀ."""
    rng = np.random.default_rng(seed)
    v, p, acc = _random_instance(rng, 64, 128, 64)
    _, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                              s=0.8, n_false=50.0, block_i=32, block_j=32,
                              block_e=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(n_k), v @ v.T)


# ---------------------------------------------------------------------------
# fused dual-direction kernel (the production tiled path)
# ---------------------------------------------------------------------------

def _random_rect(rng, S_r, S_c, E, block_e):
    v_r = (rng.random((S_r, E)) < 0.15).astype(np.float32)
    v_c = (rng.random((S_c, E)) < 0.15).astype(np.float32)
    p = rng.uniform(0.01, 0.99, size=E // block_e).astype(np.float32)
    a_r = rng.uniform(0.05, 0.95, size=S_r).astype(np.float32)
    a_c = rng.uniform(0.05, 0.95, size=S_c).astype(np.float32)
    d = rng.uniform(0.0, 0.2, size=E // block_e).astype(np.float32)
    return v_r, v_c, p, a_r, a_c, d


def _fused(v_r, v_c, p, a_r, a_c, d, m, *, bi=32, bj=32, be=64, dtype=None):
    cast = (lambda x: jnp.asarray(x)) if dtype is None \
        else (lambda x: jnp.asarray(x, dtype))
    return copyscore_fused_pallas(
        cast(v_r), jnp.asarray(p), jnp.asarray(a_r), v_cols=cast(v_c),
        acc_cols=jnp.asarray(a_c), delta_blk=jnp.asarray(d),
        nout_blk=jnp.asarray(m), s=CFG.s, n_false=CFG.n,
        block_i=bi, block_j=bj, block_e=be, interpret=True)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ebar=st.integers(0, 4))
def test_property_fused_dual_matches_ref_both_orientations(seed, ebar):
    """On a rectangular tile the fused kernel's C→ equals the single-direction
    oracle for (rows, cols) and C←ᵀ equals it for (cols, rows); the shared
    channels match the oracle's count/err and the non-Ē-masked count."""
    rng = np.random.default_rng(seed)
    v_r, v_c, p, a_r, a_c, d = _random_rect(rng, 64, 96, 256, 64)
    m = (np.arange(4) < ebar).astype(np.float32)
    cf, cb, n, n_out, err = _fused(v_r, v_c, p, a_r, a_c, d, m)

    fwd_c, fwd_n, fwd_e = copyscore_ref(
        jnp.asarray(v_r), jnp.asarray(p), jnp.asarray(a_r),
        v_cols=jnp.asarray(v_c), acc_cols=jnp.asarray(a_c),
        delta_blk=jnp.asarray(d), s=CFG.s, n_false=CFG.n, block_e=64)
    mir_c, _ = copyscore_ref(
        jnp.asarray(v_c), jnp.asarray(p), jnp.asarray(a_c),
        v_cols=jnp.asarray(v_r), acc_cols=jnp.asarray(a_r),
        s=CFG.s, n_false=CFG.n, block_e=64)

    np.testing.assert_allclose(np.asarray(cf), np.asarray(fwd_c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cb).T, np.asarray(mir_c),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(n), np.asarray(fwd_n))
    np.testing.assert_allclose(np.asarray(err), np.asarray(fwd_e),
                               rtol=1e-5, atol=1e-5)
    # n_out ≡ co-occurrence over the masked (non-Ē) entry blocks only
    e_out = int(m.sum()) * 64
    np.testing.assert_array_equal(np.asarray(n_out),
                                  v_r[:, :e_out] @ v_c[:, :e_out].T)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_fused_int8_bit_exact_vs_f32(seed):
    """int8 incidence takes the int32 MXU accumulation path: every count
    channel is bit-exact vs the f32 path and the scores are identical (the
    VPU combine sees the same f32 counts)."""
    rng = np.random.default_rng(seed)
    v_r, v_c, p, a_r, a_c, d = _random_rect(rng, 64, 64, 128, 64)
    m = np.array([1.0, 0.0], np.float32)
    out_f32 = _fused(v_r, v_c, p, a_r, a_c, d, m)
    out_i8 = _fused(v_r, v_c, p, a_r, a_c, d, m, dtype=jnp.int8)
    for a, b in zip(out_f32, out_i8):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_ref_matches_fused_kernel_square():
    rng = np.random.default_rng(11)
    v, p, acc = _random_instance(rng, 128, 256, 64)
    d = rng.uniform(0, 0.1, 4).astype(np.float32)
    m = (np.arange(4) < 3).astype(np.float32)
    kern = copyscore_fused_pallas(
        jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
        delta_blk=jnp.asarray(d), nout_blk=jnp.asarray(m),
        s=CFG.s, n_false=CFG.n, block_i=64, block_j=64, block_e=64,
        interpret=True)
    ref = copyscore_fused_ref(
        jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
        delta_blk=jnp.asarray(d), nout_blk=jnp.asarray(m),
        s=CFG.s, n_false=CFG.n, block_e=64)
    for a, b in zip(kern, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_fused_diagonal_tile_backward_is_forward_transpose():
    """On a diagonal tile (rows == cols) C← must equal C→ᵀ bitwise — the
    engine relies on this when it scatters both orientations of tile (r, r)."""
    rng = np.random.default_rng(5)
    v, p, acc = _random_instance(rng, 64, 128, 64)
    d = np.zeros(2, np.float32)
    m = np.ones(2, np.float32)
    cf, cb, *_ = _fused(v, v, p, acc, acc, d, m)
    np.testing.assert_array_equal(np.asarray(cb), np.asarray(cf).T)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p_lo=st.floats(0.005, 0.2))
def test_property_lower_p_gives_higher_score(seed, p_lo):
    """Paper §II: sharing a more-likely-false value is stronger evidence —
    C_same is monotonically decreasing in the entry probability."""
    rng = np.random.default_rng(seed)
    v = np.ones((8, 64), np.float32)     # a pair sharing everything
    acc = rng.uniform(0.2, 0.9, size=8).astype(np.float32)
    c_lo, _ = copyscore_ref(jnp.asarray(v), jnp.asarray([p_lo]), jnp.asarray(acc),
                            s=0.8, n_false=50.0, block_e=64)
    c_hi, _ = copyscore_ref(jnp.asarray(v), jnp.asarray([p_lo + 0.5]),
                            jnp.asarray(acc), s=0.8, n_false=50.0, block_e=64)
    off = ~np.eye(8, dtype=bool)
    assert (np.asarray(c_lo)[off] > np.asarray(c_hi)[off]).all()
