"""copyscore Pallas kernel vs jnp oracle — interpret mode, shape/dtype sweep
plus hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bucketed import pad_buckets
from repro.core.index import build_index, bucketize
from repro.core.types import CopyConfig
from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims
from repro.kernels.copyscore import copyscore_pallas
from repro.kernels.ops import copyscore, pad_for_copyscore
from repro.kernels.ref import copyscore_ref

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _random_instance(rng, S, E, block_e):
    v = (rng.random((S, E)) < 0.15).astype(np.float32)
    p = rng.uniform(0.01, 0.99, size=E // block_e).astype(np.float32)
    acc = rng.uniform(0.05, 0.95, size=S).astype(np.float32)
    return v, p, acc


@pytest.mark.parametrize("S,E,bi,bj,be", [
    (128, 512, 128, 128, 512),
    (256, 1024, 128, 128, 256),
    (128, 256, 64, 64, 128),
    (384, 512, 128, 128, 512),
])
def test_kernel_matches_ref_shapes(S, E, bi, bj, be):
    rng = np.random.default_rng(S + E)
    v, p, acc = _random_instance(rng, S, E, be)
    c_k, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                                s=CFG.s, n_false=CFG.n, block_i=bi, block_j=bj,
                                block_e=be, interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=be)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(n_k), np.asarray(n_r), rtol=0, atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    v, p, acc = _random_instance(rng, 128, 512, 256)
    c_k, n_k = copyscore_pallas(jnp.asarray(v, dtype), jnp.asarray(p),
                                jnp.asarray(acc), s=CFG.s, n_false=CFG.n,
                                block_i=128, block_j=128, block_e=256,
                                interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=256)
    # incidence is 0/1 so bf16 inputs are exact; accumulation is f32 in both
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))


def test_ops_wrapper_pads_nonaligned_sources():
    rng = np.random.default_rng(1)
    v, p, acc = _random_instance(rng, 200, 512, 512)   # 200 % 128 != 0
    c_k, n_k = copyscore(v, p, acc, s=CFG.s, n_false=CFG.n, block_e=512,
                         impl="interpret")
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=CFG.s, n_false=CFG.n, block_e=512)
    assert c_k.shape == (200, 200)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=2e-5, atol=2e-5)


def test_end_to_end_against_bucketed_index():
    """Kernel path == the production bucketed scorer on a real index."""
    sc = synthetic_claims(SyntheticSpec(n_sources=96, n_items=500,
                                        coverage="stock", n_cliques=4, seed=1))
    p_claim = oracle_claim_probs(sc)
    idx = build_index(sc.dataset, p_claim, CFG)
    b = bucketize(idx, 8)
    sizes = np.diff(b.starts)
    v_pad, p_blk, S = pad_for_copyscore(idx.V.astype(np.float32), b.p_hat,
                                        block_i=32, block_e=64,
                                        bucket_sizes=sizes)
    c_k, n_k = copyscore(v_pad, p_blk, np.pad(sc.dataset.accuracy,
                                              (0, v_pad.shape[0] - S),
                                              constant_values=0.5),
                         s=CFG.s, n_false=CFG.n, block_i=32, block_j=32,
                         block_e=64, impl="interpret")
    c_k = np.asarray(c_k)[:S, :S]

    padded = pad_buckets(b, dtype=jnp.float32)
    from repro.core.bucketed import _bucketed_accumulate
    c_ref, n_ref, _ = _bucketed_accumulate(padded.v_ksw, padded.p_hat,
                                           jnp.asarray(sc.dataset.accuracy),
                                           CFG.s, CFG.n, padded.ebar_bucket)
    np.testing.assert_allclose(c_k, np.asarray(c_ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_param=st.floats(0.05, 0.95),
    n_false=st.floats(2.0, 500.0),
)
def test_property_kernel_equals_oracle(seed, s_param, n_false):
    rng = np.random.default_rng(seed)
    v, p, acc = _random_instance(rng, 64, 128, 64)
    c_k, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                                s=s_param, n_false=n_false, block_i=32,
                                block_j=32, block_e=64, interpret=True)
    c_r, n_r = copyscore_ref(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                             s=s_param, n_false=n_false, block_e=64)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_counts_are_cooccurrences(seed):
    """n[i,j] must equal the exact integer co-occurrence count V Vᵀ."""
    rng = np.random.default_rng(seed)
    v, p, acc = _random_instance(rng, 64, 128, 64)
    _, n_k = copyscore_pallas(jnp.asarray(v), jnp.asarray(p), jnp.asarray(acc),
                              s=0.8, n_false=50.0, block_i=32, block_j=32,
                              block_e=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(n_k), v @ v.T)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), p_lo=st.floats(0.005, 0.2))
def test_property_lower_p_gives_higher_score(seed, p_lo):
    """Paper §II: sharing a more-likely-false value is stronger evidence —
    C_same is monotonically decreasing in the entry probability."""
    rng = np.random.default_rng(seed)
    v = np.ones((8, 64), np.float32)     # a pair sharing everything
    acc = rng.uniform(0.2, 0.9, size=8).astype(np.float32)
    c_lo, _ = copyscore_ref(jnp.asarray(v), jnp.asarray([p_lo]), jnp.asarray(acc),
                            s=0.8, n_false=50.0, block_e=64)
    c_hi, _ = copyscore_ref(jnp.asarray(v), jnp.asarray([p_lo + 0.5]),
                            jnp.asarray(acc), s=0.8, n_false=50.0, block_e=64)
    off = ~np.eye(8, dtype=bool)
    assert (np.asarray(c_lo)[off] > np.asarray(c_hi)[off]).all()
