"""flash attention Pallas kernels vs jnp oracle — interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import flash_attention
from repro.kernels.ref import attention_ref


def _qkv(rng, B, Hq, Hkv, S, D, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, S, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk", [
    (1, 2, 2, 128, 64, 64, 64),     # MHA
    (1, 4, 2, 128, 64, 64, 64),     # GQA group=2
    (2, 4, 1, 128, 64, 32, 64),     # MQA
    (1, 2, 2, 256, 128, 128, 128),  # bigger blocks
])
def test_fwd_matches_ref(B, Hq, Hkv, S, D, bq, bk):
    rng = np.random.default_rng(B * 100 + Hq)
    q, k, v = _qkv(rng, B, Hq, Hkv, S, D)
    o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk,
                                 interpret=True)
    o_ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)
    # lse sanity: finite, ordered with sequence position for causal
    assert np.isfinite(np.asarray(lse)).all()


def test_fwd_noncausal_cross_attention():
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 64)
    o, _ = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64,
                               interpret=True)
    o_ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_fwd_sliding_window(window):
    rng = np.random.default_rng(window)
    q, k, v = _qkv(rng, 1, 2, 1, 256, 64)
    o, _ = flash_attention_fwd(q, k, v, causal=True, window=window,
                               block_q=64, block_k=64, interpret=True)
    o_ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=2e-5)


def test_bwd_matches_autodiff_of_ref():
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 1, 4, 2, 128, 64)

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                            impl="interpret")
        return jnp.sum(o * jnp.cos(o))

    def loss_ref(q, k, v):
        o = attention_ref(q, k, v, causal=True)
        return jnp.sum(o * jnp.cos(o))

    g_k = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_k, g_r, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4, err_msg=name)


def test_bwd_sliding_window_grads():
    rng = np.random.default_rng(5)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 64)

    def mk(fn, **kw):
        def loss(q, k, v):
            return jnp.sum(fn(q, k, v, **kw) ** 2)
        return loss

    g_k = jax.grad(mk(lambda q, k, v: flash_attention(
        q, k, v, causal=True, window=48, block_q=64, block_k=64,
        impl="interpret")), argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(mk(lambda q, k, v: attention_ref(
        q, k, v, causal=True, window=48)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4)


def test_bf16_inputs():
    rng = np.random.default_rng(11)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 64, dtype=jnp.bfloat16)
    o, _ = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    o_ref = attention_ref(q, k, v, causal=True)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o, dtype=np.float32),
                               np.asarray(o_ref, dtype=np.float32),
                               atol=2e-2, rtol=2e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       hq=st.sampled_from([1, 2, 4]),
       causal=st.booleans())
def test_property_fwd_equals_ref(seed, hq, causal):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, 1, hq, 1, 64, 64)
    o, _ = flash_attention_fwd(q, k, v, causal=causal, block_q=32, block_k=32,
                               interpret=True)
    o_ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=3e-5, rtol=3e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_softmax_rows_sum_to_one(seed):
    """Invariant: with v = all-ones, attention output must be exactly 1."""
    rng = np.random.default_rng(seed)
    q, k, _ = _qkv(rng, 1, 2, 2, 64, 64)
    v = jnp.ones_like(k)
    o, _ = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(o), 1.0, atol=1e-5)
