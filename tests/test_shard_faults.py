"""Fault injection on the sharded data plane (reuses tests/faults.py).

Two failure modes from ISSUE 8:

- a shard raising mid-scan must surface as ONE typed ``ShardScanError``
  (carrying the shard id, chained to the injected cause) with no partial
  decision matrix leaking — the engine stays usable and a retry after the
  fault clears is bit-equal to the unsharded reference;
- spill corruption (torn frame, CRC mismatch) must fall back to
  regathering from the committed source store — bit-exact, healing the
  on-disk frame — and raise ``SpillCorruptionError`` only when the facade
  has no source to regather from.
"""
import os

import faults
import numpy as np
import pytest

import repro.core.shardplan as shardplan
from repro.core import (
    CopyConfig,
    CorpusStore,
    DetectionEngine,
    ShardScanError,
    SpillCorruptionError,
    shard_store,
)
from repro.data.claims import oracle_claim_probs, synthetic_claims
from repro.data.claims import SyntheticSpec

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)
SPEC = SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                     n_cliques=4, clique_size=3, clique_items=12, seed=0)


def _world():
    sc = synthetic_claims(SPEC)
    return sc, oracle_claim_probs(sc)


def _store(rng, n_rows=48, n_entries=40, ce=16):
    dense = (rng.random((n_rows, n_entries)) < 0.3).astype(np.int8)
    chunks = [np.ascontiguousarray(dense[:, i: i + ce])
              for i in range(0, n_entries, ce)]
    return dense, CorpusStore(
        chunks=chunks,
        entry_item=np.arange(n_entries, dtype=np.int32),
        entry_value=np.zeros(n_entries, np.int32),
        entry_p=np.full(n_entries, 0.5, np.float32),
        entry_score=np.zeros(n_entries, np.float32),
        chunk_entries=ce, n_rows=n_rows, capacity=n_rows)


def test_shard_fault_mid_scan_is_one_typed_error(monkeypatch):
    sc, p = _world()
    ref = DetectionEngine(CFG, mode="bucketed", tile=64).detect(sc.dataset, p)
    eng = DetectionEngine(CFG, mode="bucketed", tile=64, n_shards=2)

    # arm the fault on the engine's GATHERED scan store only (it carries a
    # ``_regather`` source ref; the base committed store does not), so the
    # injection lands inside the per-shard tile scan, not index build
    armed = {"on": True, "hits": 0}
    orig = shardplan.ShardedCorpusStore.assemble_rows

    def boom(self, c, r0, r1):
        if armed["on"] and self._regather is not None:
            armed["hits"] += 1
            raise faults.InjectedFault("shard slab read died mid-scan")
        return orig(self, c, r0, r1)

    monkeypatch.setattr(shardplan.ShardedCorpusStore, "assemble_rows", boom)
    with pytest.raises(ShardScanError) as ei:
        eng.detect(sc.dataset, p)
    assert isinstance(ei.value.shard, int)
    assert isinstance(ei.value.__cause__, faults.InjectedFault)
    assert armed["hits"] == 1, "fault must surface once, not per tile"
    # no partial decision matrix leaked into the engine's stats surface
    assert "n_shards" not in (eng.last_stats or {})

    # fault clears -> the same engine serves bit-equal decisions again
    armed["on"] = False
    res = eng.detect(sc.dataset, p)
    assert np.array_equal(res.copying, ref.copying)


@pytest.mark.parametrize("corruption", ["torn", "crc"])
def test_spill_corruption_regathers_from_source(tmp_path, corruption):
    rng = np.random.default_rng(3)
    dense, base = _store(rng)
    sh = shard_store(base, 3)
    order = rng.integers(-1, base.n_entries, 32)
    g = sh.gather_entries(order)
    ref = base.gather_entries(order).to_dense()

    g.seal(pack=True, spill_dir=str(tmp_path))
    for s in range(g.n_shards):
        for c in range(g.n_chunks):
            g.evict_block(s, c)
    path = g._slices[1]._spill_path(0)
    blob = open(path, "rb").read()
    if corruption == "torn":                 # SIGKILL mid-append image
        open(path, "wb").write(blob[: max(4, len(blob) // 2)])
    else:                                    # bit rot: CRC mismatch
        body = bytearray(blob)
        body[len(body) // 2] ^= 0xFF
        open(path, "wb").write(bytes(body))

    assert np.array_equal(g.to_dense(), ref)          # regather fallback
    # the on-disk frame was healed: a fresh evict/reload cycle needs no
    # fallback and still serves the same bits
    g.evict_block(1, 0)
    assert np.array_equal(g.to_dense(), ref)


def test_spill_corruption_without_source_is_typed(tmp_path):
    rng = np.random.default_rng(4)
    dense, base = _store(rng)
    sh = shard_store(base, 2)                # committed store: no source
    sh.seal(pack=False, spill_dir=str(tmp_path))
    sh.evict_block(0, 0)
    path = sh._slices[0]._spill_path(0)
    open(path, "wb").write(b"\x00garbage, not a spill frame")
    with pytest.raises(SpillCorruptionError):
        sh.assemble_rows(0, 0, sh.n_rows)
    # the untouched shard still serves its rows
    r0, r1 = sh.plan.range_of(1)
    assert os.path.exists(path)
    assert np.array_equal(sh.assemble_rows(1, r0, r1)[: r1 - r0],
                          dense[r0:r1, 16:32])