"""Bitpacked membership (1 bit/entry): identity + exact-count properties.

``pack_membership`` / ``unpack_membership`` must be a lossless pair for
every block shape — widths that are NOT multiples of 8 included (the
packed byte axis rounds up; the 8-column ``align_chunk`` invariant is a
kernel concern, not a packing requirement) — and ``packed_count_matmul``
must be bit-equal to the int8 matmul: byte-AND + popcount partial sums
are exact small integers, the same argument ``cooccurrence`` relies on.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    PackedBlock,
    pack_membership,
    packed_count_matmul,
    unpack_membership,
)

ODD_WIDTHS = [1, 3, 7, 8, 9, 13, 16, 27, 64, 100]


@pytest.mark.parametrize("width", ODD_WIDTHS)
def test_pack_unpack_identity_any_width(width):
    rng = np.random.default_rng(width)
    block = (rng.random((17, width)) < 0.4).astype(np.int8)
    packed = pack_membership(block)
    assert packed.width == width
    assert packed.bits.shape == (17, -(-width // 8))
    assert np.array_equal(unpack_membership(packed), block)
    # trailing pad bits of the final byte must be zero (phantom members
    # would corrupt whole-byte AND/popcount arithmetic)
    full = np.unpackbits(packed.bits, axis=1)
    assert not full[:, width:].any()


@pytest.mark.parametrize("fill", [0, 1])
def test_pack_unpack_all_zero_all_one(fill):
    for width in (5, 8, 21):
        block = np.full((9, width), fill, np.int8)
        out = unpack_membership(pack_membership(block))
        assert np.array_equal(out, block)


def test_pack_rejects_non_2d():
    with pytest.raises(ValueError):
        pack_membership(np.zeros(8, np.int8))


def test_packed_matmul_rejects_width_mismatch():
    a = pack_membership(np.zeros((2, 8), np.int8))
    b = pack_membership(np.zeros((2, 9), np.int8))
    with pytest.raises(ValueError):
        packed_count_matmul(a, b)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), rows=st.integers(1, 40),
       width=st.integers(1, 70), density=st.floats(0.0, 1.0))
def test_pack_unpack_identity_property(seed, rows, width, density):
    rng = np.random.default_rng(seed)
    block = (rng.random((rows, width)) < density).astype(np.int8)
    assert np.array_equal(unpack_membership(pack_membership(block)), block)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(1, 30),
       m=st.integers(1, 30), width=st.integers(1, 60))
def test_packed_count_matmul_equals_int8(seed, n, m, width):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, width)) < 0.4).astype(np.int8)
    b = (rng.random((m, width)) < 0.4).astype(np.int8)
    pa, pb = pack_membership(a), pack_membership(b)
    ref = (a.astype(np.float32) @ b.T.astype(np.float32))
    assert np.array_equal(packed_count_matmul(pa, pb), ref)
    self_ref = (a.astype(np.float32) @ a.T.astype(np.float32))
    assert np.array_equal(packed_count_matmul(pa), self_ref)
    # small row_block forces the blocked path through several strips
    assert np.array_equal(packed_count_matmul(pa, pb, row_block=3), ref)


def test_packed_block_is_immutable():
    packed = pack_membership(np.ones((2, 8), np.int8))
    with pytest.raises(Exception):
        packed.width = 16
    assert isinstance(packed, PackedBlock)
