"""Runtime substrate: train loop, checkpoint/restart, fault injection,
straggler monitor, optimizers, sharding rules."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import Model
from repro.optim import adafactor, adamw
from repro.runtime.sharding import spec_for
from repro.runtime.train_loop import (
    FaultInjector,
    StepMonitor,
    init_train_state,
    make_train_step,
    train,
)


def _tiny_model():
    return Model(get_config("llama3.2-1b").reduced(d_model=32, d_ff=64, vocab=64))


def _data(cfg, n_batches=200, B=4, S=16, seed=0):
    """Learnable stream: each row is a modular-successor sequence."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        start = rng.integers(0, cfg.vocab_size, (B, 1))
        toks = (start + np.arange(S + 1)) % cfg.vocab_size
        yield {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def test_loss_decreases():
    model = _tiny_model()
    state, hist = train(model, _data(model.cfg, 60), steps=60, peak_lr=1e-2,
                        warmup=5, log_every=0)
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    assert last < first - 0.1, (first, last)


def test_grad_accum_matches_full_batch():
    model = _tiny_model()
    opt = adamw()
    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(1e-3, 1, 10)
    step1 = make_train_step(model, opt, lr, grad_accum=1)
    step4 = make_train_step(model, opt, lr, grad_accum=4)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    toks = rng.integers(0, model.cfg.vocab_size, (8, 17))
    full = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    micro = jax.tree.map(lambda a: a.reshape(4, 2, *a.shape[1:]), full)

    s1, m1 = jax.jit(step1)(state, full)
    s4, m4 = jax.jit(step4)(state, micro)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    l1 = jax.tree.leaves(s1["params"])
    l4 = jax.tree.leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    model = _tiny_model()
    opt = adamw()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, state, {"note": "x"})
    restored, manifest = load_checkpoint(str(tmp_path), state)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_async(tmp_path):
    model = _tiny_model()
    opt = adamw()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    kept = sorted(os.path.basename(p) for p in glob.glob(str(tmp_path / "step_*")))
    assert kept == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_fault_recovery_resumes_from_checkpoint(tmp_path):
    model = _tiny_model()
    inj = FaultInjector(fail_at=[23, 37])
    state, hist = train(model, _data(model.cfg, 300), steps=60, peak_lr=5e-3,
                        warmup=5, checkpoint_dir=str(tmp_path),
                        checkpoint_every=10, fault_injector=inj,
                        async_checkpoint=False, log_every=0)
    assert int(state["step"]) == 60
    # training restarted from step 20 after the fault at 23: step 20 appears twice
    steps_seen = [h["step"] for h in hist]
    assert steps_seen.count(20) >= 2


def test_straggler_monitor_flags_outliers():
    mon = StepMonitor(slack=2.0)
    flagged = []
    mon.on_straggler = lambda s, t, e: flagged.append(s)
    for s in range(20):
        mon.record(s, 1.0)
    assert not flagged
    mon.record(20, 5.0)
    assert flagged == [20]
    # baseline is protected from outlier poisoning
    assert mon.ema < 1.5


def test_adafactor_state_is_factored():
    model = _tiny_model()
    opt = adafactor()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    p_leaves = jax.tree.leaves(state["params"])
    s_leaves = jax.tree.leaves(state["opt"])
    assert sum(l.size for l in s_leaves) < 0.6 * sum(l.size for l in p_leaves)


def test_sharding_rules_divisibility_fallback():
    import jax as _jax
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    # heads divide → heads on model; d_model on data
    sp = spec_for(("d_model", "heads", "head_dim"), (2048, 32, 64), mesh)
    assert sp == jax.sharding.PartitionSpec("data", "model", None)
    # gemma: 8 heads don't divide a 16-way axis → the small attention weight
    # replicates on 'model' (head_dim is deliberately NOT sharded for params
    # — a hd-sharded QK contraction psums full logits, §Perf H1b)
    mesh16 = _make_fake_mesh()
    sp = spec_for(("d_model", "heads", "head_dim"), (2048, 8, 256), mesh16)
    assert sp == jax.sharding.PartitionSpec("data", None, None)
    # …but a decode cache prefers kv_heads, then its seq dim
    sp = spec_for(("layer", "batch", "kv_heads", "seq", "head_dim"),
                  (18, 128, 1, 32768, 256), mesh16, kind="act")
    assert sp == jax.sharding.PartitionSpec(None, "data", None, "model", None)
    # hymba vocab 32001 → replicated
    sp = spec_for(("vocab", "d_model"), (32001, 1600), mesh16)
    assert sp == jax.sharding.PartitionSpec(None, "data")


def _make_fake_mesh():
    """An abstract 16×16 mesh for sharding-rule unit tests (no devices)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((16, 16), ("data", "model"))     # jax ≥ 0.5
    except TypeError:
        return AbstractMesh((("data", 16), ("model", 16)))   # jax 0.4.x
