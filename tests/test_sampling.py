"""Sampling strategies (§VI-E, Table IX) + FAGININPUT baseline (Table X),
plus the sample-then-verify properties of ISSUE 3 (determinism / rate /
exactness on the candidate set)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bucketed import bucketed_index_detect, index_detect_exact
from repro.core.engine import DetectionEngine
from repro.core.fagin import fagin_input
from repro.core.sampling import sample_by_cell, sample_by_item, scale_sample
from repro.core.types import CopyConfig
from repro.data.claims import (
    SyntheticSpec,
    motivating_example,
    motivating_value_probs,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)

# module-level caches (plain functions, not fixtures: hypothesis @given
# redraws examples inside one test call, where function fixtures misbehave)
_PROP_CACHE: dict = {}


def _prop_dataset():
    """Small long-tail dataset reused across property examples."""
    if "ds" not in _PROP_CACHE:
        _PROP_CACHE["ds"] = synthetic_claims(SyntheticSpec(
            n_sources=60, n_items=600, coverage="book", n_cliques=4,
            clique_size=3, clique_items=10, seed=0)).dataset
    return _PROP_CACHE["ds"]


def _verify_case():
    """(dataset, p_claim, exact result) for the sample_verify property."""
    if "verify" not in _PROP_CACHE:
        sc = synthetic_claims(SyntheticSpec(
            n_sources=64, n_items=384, coverage="book", n_cliques=4,
            clique_size=3, clique_items=12, seed=0))
        p = oracle_claim_probs(sc)
        exact = index_detect_exact(sc.dataset, p, CFG)
        _PROP_CACHE["verify"] = (sc.dataset, p, exact)
    return _PROP_CACHE["verify"]


def test_sample_by_item_rate():
    ds = synthetic_claims(SyntheticSpec(n_sources=30, n_items=1000, seed=0)).dataset
    idx = sample_by_item(ds, 0.1, seed=1)
    assert len(idx) == 100
    assert len(np.unique(idx)) == 100


def test_sample_by_cell_hits_target():
    ds = synthetic_claims(SyntheticSpec(n_sources=30, n_items=1000,
                                        coverage="stock", seed=0)).dataset
    idx = sample_by_cell(ds, 0.25, seed=1)
    cells = ds.provided_mask[:, idx].sum()
    assert cells >= 0.24 * ds.provided_mask.sum()


def test_scale_sample_guarantees_min_items_per_source():
    spec = SyntheticSpec(n_sources=120, n_items=800, coverage="book", seed=2)
    ds = synthetic_claims(spec).dataset
    idx = scale_sample(ds, 0.1, min_per_source=4, seed=3)
    counts = ds.provided_mask[:, idx].sum(axis=1)
    provided = ds.provided_mask.sum(axis=1)
    # every source keeps ≥ min(4, what it has) sampled items
    assert (counts >= np.minimum(provided, 4)).all()


def test_scale_sample_beats_naive_on_longtail():
    """Table IX: SCALESAMPLE ≫ BYITEM on Book-shaped data at equal rates —
    the paper's regime where copiers provide only a few items, so naive item
    sampling drops all their evidence while the ≥N=4 guarantee keeps it."""
    spec = SyntheticSpec(n_sources=150, n_items=1200, coverage="book",
                         n_cliques=12, clique_size=3, clique_items=10, seed=5)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    planted = {(min(a, b), max(a, b)) for a, b in sc.copy_edges}

    recalls = {"scalesample": [], "byitem": []}
    for seed in (1, 2, 3):
        idx_ss = scale_sample(sc.dataset, 0.12, min_per_source=4, seed=seed)
        rate = len(idx_ss) / sc.dataset.n_items
        for name, items in (
            ("scalesample", idx_ss),
            ("byitem", sample_by_item(sc.dataset, rate, seed=seed)),
        ):
            sub = sc.dataset.subset_items(items)
            res = bucketed_index_detect(sub, p[:, items], CFG)
            recalls[name].append(len(res.copying_pairs() & planted) / len(planted))
    assert np.mean(recalls["scalesample"]) > np.mean(recalls["byitem"]) + 0.2, recalls
    assert np.mean(recalls["scalesample"]) >= 0.8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), rate=st.floats(0.05, 0.5))
def test_samplers_deterministic_and_respect_rate(seed, rate):
    """ISSUE 3: every sampler is a pure function of (dataset, rate, seed),
    returns sorted unique item indices, and honors the requested rate."""
    ds = _prop_dataset()
    D = ds.n_items
    for fn, kw in ((sample_by_item, {}), (sample_by_cell, {}),
                   (scale_sample, {"min_per_source": 4})):
        a = fn(ds, rate, seed=seed, **kw)
        b = fn(ds, rate, seed=seed, **kw)
        np.testing.assert_array_equal(a, b)          # deterministic
        assert (np.diff(a) > 0).all()                # sorted, unique
        assert a.size and 0 <= a[0] and a[-1] < D    # valid item ids

    assert len(sample_by_item(ds, rate, seed=seed)) == max(int(round(rate * D)), 1)
    # SCALESAMPLE: at least the requested item rate (the ≥N floor only adds)
    assert len(scale_sample(ds, rate, seed=seed)) >= int(round(rate * D))
    # BYCELL: non-empty-cell coverage reaches the requested fraction
    cells = ds.provided_mask[:, sample_by_cell(ds, rate, seed=seed)].sum()
    assert cells >= rate * ds.provided_mask.sum()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), rate=st.floats(0.1, 0.4),
       strategy=st.sampled_from(["scale", "item", "cell"]))
def test_sample_verify_equals_exact_on_candidates(seed, rate, strategy):
    """ISSUE 3 tentpole property: whatever the sample (strategy, rate, seed),
    every candidate pair's final decision equals ``index_detect_exact`` and
    no pair outside the candidate set is ever reported copying."""
    ds, p, exact = _verify_case()
    eng = DetectionEngine(CFG, mode="sample_verify", tile=32,
                          sample_rate=rate, sample_strategy=strategy,
                          sample_seed=seed)
    res = eng.detect(ds, p)
    cand = eng._last_considered
    assert (res.copying[cand] == exact.copying[cand]).all()
    assert not res.copying[~cand].any()


def test_fagin_input_materializes_every_pair_score():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    lists, diff_list, counter, secs = fagin_input(ds, p, CFG)
    assert len(lists) == 13
    # Σ_E C(|S̄(E)|, 2) = 53 pair-scores — no pruning possible
    assert counter.shared_values_examined == 53
    assert counter.score_computations == 106
    # lists are sorted by decreasing score
    for _, _, scores in lists:
        assert np.all(np.diff(scores) <= 1e-6)
