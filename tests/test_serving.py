"""Batched detection serving (core/serving.py, DESIGN.md §5).

The load-bearing property: folding many requests into one tiled engine pass
returns exactly the decisions each request would get from its own pass —
batching is a pure throughput optimization, never a semantic change.
"""
import numpy as np
import pytest

from repro.core import CopyConfig, DetectionEngine
from repro.core.serving import (
    DetectRequest,
    DetectionService,
    ServiceOverloaded,
    serve_batch,
)
from repro.data.claims import (
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
    synthetic_query_rows,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


@pytest.fixture(scope="module")
def corpus():
    sc = synthetic_claims(SyntheticSpec(n_sources=80, n_items=400,
                                        coverage="stock", n_cliques=4, seed=0))
    return sc, oracle_claim_probs(sc)


@pytest.fixture(scope="module")
def requests(corpus):
    sc, _ = corpus
    vals, acc, pq, origins = synthetic_query_rows(sc, 12, seed=1)
    reqs = [DetectRequest(rid=i, values=vals[3 * i: 3 * i + 3],
                          accuracy=acc[3 * i: 3 * i + 3],
                          p_claim=pq[3 * i: 3 * i + 3])
            for i in range(4)]
    return reqs, origins


def test_batched_equals_per_request(corpus, requests):
    sc, p = corpus
    reqs, _ = requests
    eng = DetectionEngine(CFG, mode="bucketed", tile=64)
    batched = serve_batch(sc.dataset, p, eng, reqs)
    assert [b.rid for b in batched] == [r.rid for r in reqs]
    for req, b in zip(reqs, batched):
        (s,) = serve_batch(sc.dataset, p, eng, [req])
        np.testing.assert_array_equal(b.copying, s.copying)
        np.testing.assert_array_equal(b.intra_copying, s.intra_copying)
        assert b.copying.shape == (req.n_rows, sc.dataset.n_sources)
        assert b.batch_requests == len(reqs)
        assert b.batch_rows == sum(r.n_rows for r in reqs)


def test_planted_copiers_detected(corpus, requests):
    """Query rows generated as copiers of a corpus source are detected."""
    sc, p = corpus
    reqs, origins = requests
    eng = DetectionEngine(CFG, mode="bucketed", tile=64)
    responses = serve_batch(sc.dataset, p, eng, reqs)
    hits = planted = 0
    for i, resp in enumerate(responses):
        for row in range(reqs[i].n_rows):
            o = int(origins[3 * i + row])
            if o >= 0:
                planted += 1
                hits += int(resp.copying[row, o])
    assert planted >= 4
    assert hits / planted >= 0.75, (hits, planted)


def test_serve_batch_rejects_bad_inputs(corpus, requests):
    sc, p = corpus
    reqs, _ = requests
    inc = DetectionEngine(CFG, mode="incremental")
    with pytest.raises(ValueError, match="stateless"):
        serve_batch(sc.dataset, p, inc, reqs)
    eng = DetectionEngine(CFG, mode="bucketed")
    bad = DetectRequest(rid=9, values=np.full((1, 7), -1, np.int32),
                        accuracy=np.array([0.5], np.float32),
                        p_claim=np.zeros((1, 7), np.float32))
    with pytest.raises(ValueError, match="items"):
        serve_batch(sc.dataset, p, eng, [bad])
    assert serve_batch(sc.dataset, p, eng, []) == []


def test_service_async_futures(corpus, requests):
    """Worker thread drains the queue; futures carry per-request slices
    identical to the synchronous path, and latency is recorded."""
    sc, p = corpus
    reqs, _ = requests
    eng = DetectionEngine(CFG, mode="bucketed", tile=64)
    singles = [serve_batch(sc.dataset, p, eng, [r])[0] for r in reqs]
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64,
                           max_batch_requests=4)
    with svc:
        futs = [svc.submit(r) for r in reqs]
        outs = [f.result(timeout=300) for f in futs]
    for b, s in zip(outs, singles):
        np.testing.assert_array_equal(b.copying, s.copying)
        assert b.latency_s > 0
    assert svc.stats.requests == len(reqs)
    assert svc.stats.batches <= len(reqs)


def test_service_flush_without_worker(corpus, requests):
    """flush() drains synchronously when no worker thread is running."""
    sc, p = corpus
    reqs, _ = requests
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64,
                           max_batch_requests=8)
    futs = [svc.submit(r) for r in reqs]
    assert svc.flush() == len(reqs)
    assert all(f.done() for f in futs)
    # one engine pass served everything (max_batch_requests ≥ len(reqs))
    assert svc.stats.batches == 1
    assert futs[0].result().batch_requests == len(reqs)


def test_service_backpressure(corpus, requests):
    """submit blocks on a full queue and sheds load after the timeout."""
    sc, p = corpus
    reqs, _ = requests
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64,
                           max_pending_rows=7)   # fits two 3-row requests
    svc.submit(reqs[0], timeout=0.05)
    svc.submit(reqs[1], timeout=0.05)
    with pytest.raises(ServiceOverloaded):
        svc.submit(reqs[2], timeout=0.05)
    assert svc.stats.rejected == 1
    # a request that could never fit the budget fails fast, not by timeout
    with pytest.raises(ValueError, match="max_pending_rows"):
        big = DetectRequest(rid=99, values=np.full((8, 400), -1, np.int32),
                            accuracy=np.full(8, 0.5, np.float32),
                            p_claim=np.zeros((8, 400), np.float32))
        svc.submit(big)
    assert svc.flush() == 2                      # queued work still serves
    svc.submit(reqs[2], timeout=0.05)            # and capacity freed up
    assert svc.flush() == 1


def test_cancelled_future_does_not_kill_worker(corpus, requests):
    """A client cancelling its pending future must not take the batch (or
    the worker) down — the other requests in the batch still resolve."""
    sc, p = corpus
    reqs, _ = requests
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64,
                           max_batch_requests=8)
    f0 = svc.submit(reqs[0])
    rest = [svc.submit(r) for r in reqs[1:]]
    assert f0.cancel()
    assert svc.flush() == len(reqs)
    assert f0.cancelled()
    for f in rest:
        assert f.result(timeout=60).copying.shape[1] == sc.dataset.n_sources


def test_resident_store_zero_full_corpus_concat(corpus, requests, monkeypatch):
    """ISSUE 4: the service's resident buffers kill the per-batch O(S·D)
    union concat — no np.concatenate anywhere near corpus size happens while
    serving, the engine sees zero-copy views of the resident buffers, and
    the staged bytes are only the query rows."""
    sc, p = corpus
    reqs, _ = requests
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64,
                           max_batch_requests=4)
    # the union handed to the engine is a view of the resident buffers
    union, union_p, staged = svc.resident.stage(reqs)
    assert np.shares_memory(union.values, svc.resident.values)
    assert np.shares_memory(union_p, svc.resident.p_claim)
    assert staged == sum(r.values.nbytes + r.accuracy.nbytes +
                         r.p_claim.nbytes for r in reqs)

    corpus_bytes = sc.dataset.values.nbytes
    concat_sizes = []
    orig = np.concatenate

    def spy(arrays, *a, **kw):
        out = orig(arrays, *a, **kw)
        concat_sizes.append(out.nbytes)
        return out

    monkeypatch.setattr(np, "concatenate", spy)
    futs = [svc.submit(r) for r in reqs]
    assert svc.flush() == len(reqs)
    monkeypatch.undo()
    assert max(concat_sizes, default=0) < corpus_bytes // 2, \
        "a full-corpus-sized concatenation happened during serving"
    resp = futs[0].result()
    assert resp.host_copy_bytes > 0
    assert resp.host_copy_bytes < corpus_bytes          # query rows only
    assert svc.stats.host_copy_bytes == resp.host_copy_bytes


def test_serve_batch_overflowing_resident_slack_rejected(corpus, requests):
    """A batch larger than the resident slack fails fast with a clear error."""
    sc, p = corpus
    reqs, _ = requests
    from repro.core.serving import ResidentCorpus
    rc = ResidentCorpus(sc.dataset, p, max_query_rows=2)
    eng = DetectionEngine(CFG, mode="bucketed", tile=64)
    with pytest.raises(ValueError, match="slack"):
        serve_batch(sc.dataset, p, eng, reqs, resident=rc)


def test_flush_refused_while_worker_runs(corpus):
    """flush() must not drive the stateful engine from a second thread."""
    sc, p = corpus
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64)
    with svc:
        with pytest.raises(RuntimeError, match="worker"):
            svc.flush()
    assert svc.flush() == 0                      # fine again once stopped
