"""ISSUE 10 acceptance: shard-owner router decisions are bit-equal to a
single-host service — every servable engine mode × {2, 4} owners at
S ∈ {64, 512} under 8 virtual devices. The ninth mode (incremental) cannot
be served (its bookkeeping assumes a fixed source axis) and is pinned at
the engine level instead: owner-count row-range placement equals unsharded.

Mirrors tests/test_shard_modes.py: one subprocess with 8 virtual devices.
Tiled fan-out modes (bucketed, sampled, sample_verify) go through the
router's owner scatter/merge path (``_submit_owner_fanout``); host modes
read through the primary's shard facade — both must reproduce the
single-host responses bit-for-bit, before AND after a routed commit.
"""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine
    from repro.core.serving import DetectRequest, DetectionService, ReplicaRouter
    from repro.data.claims import (
        SyntheticSpec, oracle_claim_probs, synthetic_claims,
        synthetic_query_rows)

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    SERVABLE = ("pairwise", "exact", "bound", "bound+", "hybrid",
                "sampled", "sample_verify", "bucketed")
    ENGINE_KW = dict(tile=64, devices=8, sample_rate=0.2, sample_seed=1)

    def one_response(svc, req):
        fut = svc.submit(req)
        svc.flush()
        return fut.result()

    def resp_equal(a, b):
        return (np.array_equal(a.copying, b.copying)
                and np.array_equal(a.intra_copying, b.intra_copying)
                and np.array_equal(a.c_fwd, b.c_fwd)
                and np.array_equal(a.pr_independent, b.pr_independent))

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        vals, acc, pq, _ = synthetic_query_rows(sc, 8, seed=3)
        req = DetectRequest(rid=1, values=vals[:4], accuracy=acc[:4],
                            p_claim=pq[:4])
        req2 = DetectRequest(rid=2, values=vals[4:8], accuracy=acc[4:8],
                             p_claim=pq[4:8])
        for mode in SERVABLE:
            single = DetectionService(sc.dataset, p, cfg, mode=mode,
                                      **ENGINE_KW)
            ref = one_response(single, req)
            single.commit(vals[4:6], acc[4:6], pq[4:6])
            ref2 = one_response(single, req2)
            for owners in (2, 4):
                router = ReplicaRouter(sc.dataset, p, cfg,
                                       shard_owners=owners, mode=mode,
                                       **ENGINE_KW)
                got = one_response(router, req)
                router.commit(vals[4:6], acc[4:6], pq[4:6])
                got2 = one_response(router, req2)
                fanout = mode in DetectionEngine.OWNER_FANOUT_MODES
                out[f"S{S}/{mode}/owners{owners}"] = {
                    "equal": bool(resp_equal(ref, got)),
                    "equal_after_commit": bool(resp_equal(ref2, got2)),
                    "epoch": int(router.epoch),
                    "fanout": bool(fanout),
                    "copying_bits": int(got.copying.sum()
                                        + got2.copying.sum()),
                }
        # ninth mode: incremental is engine-only — owner-count placement
        # over the sharded facade must stay bit-equal to unsharded
        eng_ref = DetectionEngine(cfg, mode="incremental", **ENGINE_KW)
        inc_ref = eng_ref.detect(sc.dataset, p).copying
        for owners in (2, 4):
            eng = DetectionEngine(cfg, mode="incremental", n_shards=owners,
                                  **ENGINE_KW)
            got = eng.detect(sc.dataset, p).copying
            out[f"S{S}/incremental/owners{owners}"] = {
                "equal": bool(np.array_equal(inc_ref, got)),
                "equal_after_commit": True, "epoch": 0, "fanout": False,
                "copying_bits": int(got.sum())}
    print("RESULT" + json.dumps(out))
""")


def test_owner_router_bit_equal_all_modes():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=1800,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # 9 modes × 2 owner counts × 2 corpus sizes
    assert len(out) == 36, sorted(out)
    for combo, r in out.items():
        assert r["equal"], f"{combo}: owner-router decisions diverged"
        assert r["equal_after_commit"], (
            f"{combo}: decisions diverged after a routed commit")
    # the tiled modes went through the fan-out path, and something detected
    assert sum(1 for r in out.values() if r["fanout"]) == 12
    assert any(r["copying_bits"] > 0 for r in out.values())
    # routed commits moved every replica to the same epoch
    assert all(r["epoch"] == 1 for k, r in out.items()
               if "incremental" not in k)
