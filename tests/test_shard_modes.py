"""ISSUE 8 acceptance: row-range-sharded decisions are bit-equal to the
unsharded engine — every engine mode, S ∈ {64, 512} × {1, 2, 4} shards ×
{1, 8} devices — and stay bit-equal after a commit → retract → commit
schedule replayed through the WAL on each shard count.

Mirrors tests/test_store_modes.py: one subprocess with 8 virtual devices;
device counts run via the engine's ``devices`` option inside one process.
Host-side indexed modes (exact, bound family, incremental) never touch the
mesh, so they run at 1 device; the tiled modes (bucketed, sampled,
sample_verify) run under both mesh sizes. Exactness-preserving modes are
additionally pinned to ``index_detect_exact`` — the unsharded reference
the ISSUE names.
"""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import shutil
    import tempfile
    import numpy as np
    from repro.core import (CopyConfig, DetectionEngine, DurabilityOptions,
                            ShardedCorpusStore)
    from repro.core.bucketed import index_detect_exact
    from repro.core.serving import DetectionService
    from repro.data.claims import (
        SyntheticSpec, oracle_claim_probs, synthetic_claims,
        synthetic_query_rows)

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    TILED = ("bucketed", "sampled", "sample_verify")
    # modes the suite pins to INDEX decisions (the bound family prunes
    # with early decisions, so it is only reference-equal to itself)
    EXACTNESS = ("exact", "bucketed")

    def decisions(mode, sc, p, n_shards, devices):
        kw = {"n_shards": n_shards} if n_shards > 1 else {}
        eng = DetectionEngine(cfg, mode=mode, tile=64, devices=devices,
                              sample_rate=0.2, sample_seed=1, **kw)
        out = [eng.detect(sc.dataset, p).copying]
        if mode == "incremental":
            # round 2 exercises the delta path over the (sharded) store
            rng = np.random.default_rng(7)
            p2 = np.clip(p + np.where(p > 0, rng.normal(0, 0.004, p.shape), 0),
                         1e-3, 0.999).astype(np.float32)
            out.append(eng.detect(sc.dataset, p2).copying)
        return out

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        exact_ref = index_detect_exact(sc.dataset, p, cfg).copying
        for mode in ("pairwise", "exact", "bound", "bound+", "hybrid",
                     "incremental", "sampled", "sample_verify", "bucketed"):
            dev_counts = (1, 8) if mode in TILED else (1,)
            for n_dev in dev_counts:
                ref = decisions(mode, sc, p, 1, n_dev)
                for n_shards in (1, 2, 4):
                    got = decisions(mode, sc, p, n_shards, n_dev)
                    eq = all(np.array_equal(a, b) for a, b in zip(ref, got))
                    if mode in EXACTNESS:
                        eq = eq and np.array_equal(got[0], exact_ref)
                    out[f"S{S}/{mode}/dev{n_dev}/shards{n_shards}"] = {
                        "equal": bool(eq),
                        "copying_bits": int(sum(x.sum() for x in got))}

    # commit -> retract -> commit, replayed through the WAL per shard count
    S, spec = 64, specs[64]
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    vals, acc, pq, _ = synthetic_query_rows(sc, 12, seed=3)
    wal_ref = None
    for n_shards in (1, 2, 4):
        kw = {"n_shards": n_shards} if n_shards > 1 else {}
        state_dir = tempfile.mkdtemp(prefix=f"shard{n_shards}-")
        try:
            live = DetectionService(
                sc.dataset, p, cfg, mode="bucketed", tile=64,
                durability=DurabilityOptions(state_dir=state_dir,
                                             snapshot_every=2), **kw)
            live.commit(vals[:6], acc[:6], pq[:6])
            live.retract([S + 1, S + 3, S + 4])
            live.commit(vals[6:12], acc[6:12], pq[6:12])
            restored = DetectionService.restore(state_dir)

            dense_live = live._index.store.to_dense()
            dense_rest = restored._index.store.to_dense()
            if wal_ref is None:
                wal_ref = dense_live          # unsharded schedule outcome
            rest_store = restored._index.store
            out[f"wal/shards{n_shards}"] = {
                "live_equal_unsharded": bool(
                    np.array_equal(dense_live, wal_ref)),
                "restored_equal_live": bool(
                    np.array_equal(dense_rest, dense_live)),
                "epoch_equal": restored.epoch == live.epoch,
                "replayed": int(restored.restore_info.replayed_commits),
                "sharded_restore": (n_shards == 1) or (
                    isinstance(rest_store, ShardedCorpusStore)
                    and rest_store.n_shards == n_shards),
            }
        finally:
            shutil.rmtree(state_dir, ignore_errors=True)
    print("RESULT" + json.dumps(out))
""")


def test_all_modes_sharded_vs_unsharded_identical():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=1800,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    matrix = {k: v for k, v in out.items() if not k.startswith("wal/")}
    # per S: 6 host-mode combos + 3 tiled modes × 2 devices = 12 mode/dev
    # cells, each at 3 shard counts → 36; × 2 corpus sizes = 72
    assert len(matrix) == 72, sorted(matrix)
    for combo, r in matrix.items():
        assert r["equal"], f"{combo}: sharded decisions diverged"
    assert any(r["copying_bits"] > 0 for r in matrix.values())

    wal = {k: v for k, v in out.items() if k.startswith("wal/")}
    assert len(wal) == 3, sorted(wal)
    for combo, r in wal.items():
        assert r["live_equal_unsharded"], f"{combo}: schedule diverged"
        assert r["restored_equal_live"], f"{combo}: WAL replay diverged"
        assert r["epoch_equal"], f"{combo}: epoch diverged after restore"
        assert r["sharded_restore"], f"{combo}: restore lost the shard plan"
        assert r["replayed"] >= 1, f"{combo}: nothing replayed from the WAL"
