"""CorpusStore (DESIGN.md §6): chunked incidence is bit-exact vs dense,
row slack works, build peak allocation respects the chunk-bytes cap, and the
synthetic-claims spec validation fails fast instead of spinning."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CopyConfig, DetectionEngine, build_index
from repro.core.bucketed import index_detect_exact
from repro.core.index import engine_chunks
from repro.core.store import align_chunk
from repro.core.types import ClaimsDataset
from repro.data.claims import (
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _random_world(seed: int, n_src: int = 24, n_items: int = 80):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.6,
                      rng.integers(0, 4, (n_src, n_items)), -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.1, 0.95, n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    return ds, p


# ---------------------------------------------------------------------------
# chunked == dense, bit for bit
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.integers(1, 96),
       n_src=st.integers(4, 24), n_items=st.integers(10, 90))
def test_chunked_build_bit_exact_vs_dense(seed, chunk, n_src, n_items):
    """ISSUE 4: chunked-store gather is bit-exact vs the dense incidence for
    random claim sets and chunk widths."""
    ds, p = _random_world(seed, n_src, n_items)
    idx_c = build_index(ds, p, CFG, chunk_entries=chunk)
    idx_d = build_index(ds, p, CFG, chunk_entries=1 << 22)
    assert idx_d.store.n_chunks <= 1
    assert idx_c.store.chunk_entries == align_chunk(chunk)
    np.testing.assert_array_equal(idx_c.store.to_dense(), idx_d.store.to_dense())
    np.testing.assert_array_equal(idx_c.entry_item, idx_d.entry_item)
    np.testing.assert_array_equal(idx_c.entry_p, idx_d.entry_p)
    np.testing.assert_array_equal(idx_c.entry_score, idx_d.entry_score)
    assert idx_c.ebar_start == idx_d.ebar_start
    # every chunk respects the width bound — the peak-allocation guarantee
    for ch in idx_c.store.iter_chunks():
        assert ch.width <= idx_c.store.chunk_entries


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.integers(1, 64),
       lo=st.integers(0, 40), width=st.integers(0, 40))
def test_slice_and_gather_bit_exact(seed, chunk, lo, width):
    """slice_entries / gather_entries / cooccurrence agree with the dense
    forms for any chunking, range, and dtype conversion."""
    ds, p = _random_world(seed)
    idx = build_index(ds, p, CFG, chunk_entries=chunk)
    E = idx.n_entries
    dense = idx.store.to_dense()
    e0 = min(lo, E)
    e1 = min(lo + width, E)
    for dtype in (np.int8, np.float32):
        np.testing.assert_array_equal(
            idx.store.slice_entries(e0, e1, dtype=dtype),
            dense[:, e0:e1].astype(dtype))
    rng = np.random.default_rng(seed)
    order = rng.permutation(E)
    g = idx.store.gather_entries(order, chunk_entries=max(chunk // 2, 1))
    np.testing.assert_array_equal(g.to_dense(), dense[:, order])
    np.testing.assert_array_equal(g.entry_p, idx.entry_p[order])
    # -1 markers become inert zero columns
    order2 = np.concatenate([order[: E // 2], [-1, -1]])
    g2 = idx.store.gather_entries(order2)
    np.testing.assert_array_equal(g2.to_dense()[:, -2:], 0)
    assert (g2.entry_item[-2:] == -1).all()
    # chunk-streamed co-occurrence == dense matmul (exact integer f32 sums)
    d32 = dense.astype(np.float32)
    np.testing.assert_array_equal(idx.store.cooccurrence(), d32 @ d32.T)
    np.testing.assert_array_equal(
        idx.store.cooccurrence(stop=idx.ebar_start),
        d32[:, : idx.ebar_start] @ d32[:, : idx.ebar_start].T)


def test_engine_chunks_layout():
    """engine_chunks: uniform width, chunk-aligned Ē boundary, live p̂ stats."""
    ds, p = _random_world(5, n_src=32, n_items=120)
    idx = build_index(ds, p, CFG, chunk_entries=16)
    ech = engine_chunks(idx, n_buckets=8, row_capacity=40)
    b = ech.width
    assert b % 8 == 0
    assert ech.store.capacity == 40
    for ch in ech.store.iter_chunks():
        assert ch.width == b
    # every live entry appears exactly once; padding columns are inert
    live = ech.store.entry_item >= 0
    assert int(live.sum()) == idx.n_entries == ech.n_live
    assert ech.store.to_dense()[:, ~live].sum() == 0
    # Ē boundary is chunk-aligned: non-Ē live entries fill chunks < ebar_chunk
    starts = np.arange(ech.store.n_entries) // b
    nonebar_chunks = set(starts[live][: idx.ebar_start]
                         if idx.ebar_start else [])
    assert all(c < ech.ebar_chunk for c in nonebar_chunks)
    assert (ech.nout == (np.arange(ech.n_chunks) < ech.ebar_chunk)).all()
    # per-chunk p extremes bound the live entries of that chunk
    for k in range(ech.n_chunks):
        seg = slice(k * b, (k + 1) * b)
        m = live[seg]
        if m.any():
            ps = ech.store.entry_p[seg][m]
            assert ech.p_lo[k] <= ps.min() and ech.p_hi[k] >= ps.max()


def test_copyscore_store_matches_dense_kernel():
    """The chunked full-square dispatch (ops.copyscore_store) reproduces the
    dense bucket-aligned kernel: counts bit-equal (integer-exact f32 sums),
    scores to f32 round-off (per-chunk elementwise math compiles separately
    from the dense scan's)."""
    from repro.kernels.ops import copyscore, copyscore_store

    ds, p = _random_world(9, n_src=24, n_items=100)
    idx = build_index(ds, p, CFG, chunk_entries=16)
    ech = engine_chunks(idx, n_buckets=6)
    dense = ech.store.to_dense().astype(np.float32)
    c_d, n_d = copyscore(dense, ech.p_hat, ds.accuracy,
                         s=CFG.s, n_false=CFG.n, block_e=ech.width,
                         impl="ref")
    c_s, n_s = copyscore_store(ech.store, ech.p_hat, ds.accuracy,
                               s=CFG.s, n_false=CFG.n, impl="ref")
    np.testing.assert_array_equal(np.asarray(n_d), n_s)
    np.testing.assert_allclose(np.asarray(c_d), c_s, rtol=1e-5, atol=1e-4)


def test_serve_batch_rejects_mismatched_resident():
    """A resident built over a different corpus fails fast, not silently."""
    from repro.core.serving import DetectRequest, ResidentCorpus, serve_batch

    ds, p = _random_world(12, n_src=32, n_items=28)
    other, other_p = _random_world(13, n_src=24, n_items=28)
    rc = ResidentCorpus(other, other_p, max_query_rows=4)
    eng = DetectionEngine(CFG, mode="bucketed", tile=32)
    req = DetectRequest(rid=0, values=np.full((1, 28), -1, np.int32),
                        accuracy=np.array([0.5], np.float32),
                        p_claim=np.zeros((1, 28), np.float32))
    with pytest.raises(ValueError, match="same corpus"):
        serve_batch(ds, p, eng, [req], resident=rc)


def test_chunk_group_bytes_narrows_width_and_keeps_decisions():
    """chunk_group_bytes is a HARD per-pass ceiling: it narrows the engine
    chunk width when one n_buckets-derived chunk would exceed it, and clamps
    the group size — decisions still equal the exact INDEX."""
    ds, p = _random_world(3, n_src=48, n_items=160)
    idx = build_index(ds, p, CFG)
    wide = DetectionEngine(CFG, mode="bucketed", tile=48, n_buckets=4)
    res_w = wide.detect(ds, p, index=idx)
    budget = 48 * 8 * 2                 # two 8-entry columns of S_pad rows
    tight = DetectionEngine(CFG, mode="bucketed", tile=48, n_buckets=4,
                            chunk_group_bytes=budget, chunk_group=64)
    res_t = tight.detect(ds, p, index=idx)
    assert tight.last_stats["chunk_width"] < wide.last_stats["chunk_width"]
    assert tight.last_stats["peak_group_bytes"] <= budget
    exact = index_detect_exact(ds, p, CFG, index=idx)
    np.testing.assert_array_equal(res_w.copying, exact.copying)
    np.testing.assert_array_equal(res_t.copying, exact.copying)


# ---------------------------------------------------------------------------
# row slack: append_rows / truncate_rows
# ---------------------------------------------------------------------------

def test_append_rows_matches_rebuilt_membership():
    """Appended rows get exactly the membership bits a rebuild would give
    them for the EXISTING entry set (new shared values need a re-index)."""
    ds, p = _random_world(11, n_src=20, n_items=60)
    idx = build_index(ds, p, CFG, chunk_entries=8, row_capacity=26)
    store = idx.store
    assert store.capacity == 26
    rng = np.random.default_rng(0)
    new_rows = np.where(rng.random((4, 60)) < 0.5,
                        rng.integers(0, 4, (4, 60)), -1).astype(np.int32)
    bits = store.append_rows(new_rows)
    assert store.n_rows == 24
    dense = store.to_dense()
    expect = (new_rows[:, store.entry_item] ==
              store.entry_value[None, :]).astype(np.int8)
    np.testing.assert_array_equal(dense[20:], expect)
    assert bits == int(expect.sum())
    # truncate restores the corpus-only store exactly
    store.truncate_rows(20)
    np.testing.assert_array_equal(store.to_dense(),
                                  build_index(ds, p, CFG, chunk_entries=8)
                                  .store.to_dense())
    with pytest.raises(ValueError, match="capacity"):
        store.append_rows(np.full((7, 60), -1, np.int32))


# ---------------------------------------------------------------------------
# memory smoke: chunk-bytes cap at S=2048 (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_chunk_bytes_cap_s2048_decisions_exact():
    """Build at S=2048 under a 1 MiB chunk-bytes cap: no single incidence
    allocation exceeds the cap anywhere in the pipeline, and engine decisions
    still equal ``index_detect_exact``."""
    cap = 1 << 20
    spec = SyntheticSpec(n_sources=2048, n_items=3072, coverage="book",
                         n_cliques=50, clique_size=3, clique_items=12, seed=0)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    idx = build_index(sc.dataset, p, CFG, chunk_bytes=cap)
    assert idx.store.n_chunks > 1, "cap must force a multi-chunk store"
    assert idx.store.max_chunk_nbytes <= cap
    # a budget that is NOT row-count-aligned still holds (width rounds DOWN)
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 3, (100, 40)).astype(np.int32)
    ds_small = ClaimsDataset(values=vals,
                             accuracy=np.full(100, 0.5, np.float32))
    p_small = np.full(vals.shape, 0.3, np.float32)
    idx_small = build_index(ds_small, p_small, CFG, chunk_bytes=1000)
    assert idx_small.store.max_chunk_nbytes <= 1000
    eng = DetectionEngine(CFG, mode="bucketed", tile=256,
                          chunk_group_bytes=cap)
    res = eng.detect(sc.dataset, p, index=idx)
    st = eng.last_stats
    # the engine's resident incidence per device pass stays under the cap too
    assert st["chunks"] > 1
    assert st["peak_group_bytes"] <= cap
    exact = index_detect_exact(sc.dataset, p, CFG, index=idx)
    np.testing.assert_array_equal(res.copying, exact.copying)


# ---------------------------------------------------------------------------
# synthetic-claims spec validation (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_synthetic_claims_rejects_oversubscribed_cliques():
    """n_cliques·clique_size > n_sources used to spin the unused-source
    rejection loop forever; now it raises up front."""
    bad = SyntheticSpec(n_sources=10, n_items=50, n_cliques=4, clique_size=3)
    with pytest.raises(ValueError, match="n_sources"):
        synthetic_claims(bad)
    # the boundary case (every source in a clique) still generates
    ok = SyntheticSpec(n_sources=12, n_items=50, n_cliques=4, clique_size=3)
    sc = synthetic_claims(ok)
    assert sc.dataset.n_sources == 12
    assert len({s for pair in sc.copies for s in pair}) <= 12
