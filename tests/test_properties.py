"""Property-based tests (hypothesis) on the copy-model invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bucketed import index_detect_exact
from repro.core.index import build_index, entry_contribution_score
from repro.core.scoring import pairwise_detect, score_same_np
from repro.core.types import ClaimsDataset, CopyConfig
from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims

accs = st.floats(0.02, 0.98)
probs = st.floats(0.005, 0.995)


@settings(max_examples=100, deadline=None)
@given(p=probs, a1=accs, a2=accs, s=st.floats(0.05, 0.95),
       n=st.floats(2.0, 1000.0))
def test_same_value_contribution_is_positive(p, a1, a2, s, n):
    """§II: 'C→(D) is positive when S1 and S2 share the same value on D' —
    holds whenever the shared-value likelihood ratio exceeds 1, which the
    paper proves for the n-false-values model."""
    c = score_same_np(p, a1, a2, s, n)
    ratio = (p * a2 + (1 - p) * (1 - a2)) / (
        p * a1 * a2 + (1 - p) * (1 - a1) * (1 - a2) / n)
    if ratio > 1.0:
        assert c > 0.0
    # and different values always contribute ln(1−s) < 0
    assert np.log(1 - s) < 0


@settings(max_examples=100, deadline=None)
@given(a1=accs, a2=accs, s=st.floats(0.05, 0.95), n=st.floats(25.0, 1000.0),
       p_lo=st.floats(0.005, 0.4), dp=st.floats(0.05, 0.5))
def test_lower_probability_stronger_evidence(a1, a2, s, n, p_lo, dp):
    """§II: 'it is larger when the shared value has a lower P(D.v)'.

    NOTE (found by hypothesis): this monotonicity is NOT unconditional — the
    exact condition (sign of d ratio/dp, Möbius in p) reduces to
    a₁ > 1/(n+1): the copier must be better than uniform random guessing
    over the n+1 possible values. Below that, sharing a TRUE value is the
    stronger copying evidence (a worse-than-random source providing the
    truth independently is itself unlikely). The paper's n≈50–100 regime
    satisfies this for any a₁ ≳ .02."""
    import hypothesis
    hypothesis.assume(a1 > 1.0 / (n + 1.0) + 1e-3)
    c_lo = score_same_np(p_lo, a1, a2, s, n)
    c_hi = score_same_np(min(p_lo + dp, 0.99), a1, a2, s, n)
    assert c_lo >= c_hi - 1e-7


@settings(max_examples=60, deadline=None)
@given(p=probs, s=st.floats(0.05, 0.95), n=st.floats(25.0, 500.0),
       accs_list=st.lists(accs, min_size=2, max_size=6))
def test_prop_3_1_upper_bounds_all_pairs(p, s, n, accs_list):
    """Prop 3.1/3.4: M̂(D.v) bounds the contribution of EVERY provider pair.

    NOTE (found by hypothesis): like the monotonicity property above, the
    proposition's case analysis (proof omitted in the paper) requires every
    provider to beat the uniform-guessing baseline, aᵢ > 1/(n+1); e.g. at
    n=5, p=.75, accs {.5, .0625} the maximizing pair is (min-acc → max-acc),
    which none of the three cases selects. The paper's n ≈ 50–100 /
    accuracy ≳ .05 settings are safely inside the regime tested here."""
    import hypothesis
    hypothesis.assume(min(accs_list) > 1.0 / (n + 1.0) + 1e-3)
    cfg = CopyConfig(alpha=0.1, s=s, n=n)
    a = np.asarray(accs_list)
    m_hat = entry_contribution_score(p, a, cfg)
    for i in range(len(a)):
        for j in range(len(a)):
            if i != j:
                assert score_same_np(p, a[i], a[j], s, n) <= m_hat + 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_src=st.integers(8, 30),
       n_items=st.integers(10, 60))
def test_index_decisions_equal_pairwise(seed, n_src, n_items):
    """Prop 3.5 as a property: INDEX ≡ PAIRWISE decisions on random worlds."""
    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.7,
                      rng.integers(0, 4, (n_src, n_items)), -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.1, 0.95, n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    ref = pairwise_detect(ds, p, cfg)
    res = index_detect_exact(ds, p, cfg)
    np.testing.assert_array_equal(res.copying, ref.copying)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_index_structure_invariants(seed):
    """Def 3.2: every entry ≥2 providers; no source twice per item; scores
    sorted; Ē suffix sums below θ_ind."""
    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    sc = synthetic_claims(SyntheticSpec(n_sources=30, n_items=100, seed=seed))
    p = oracle_claim_probs(sc)
    idx = build_index(sc.dataset, p, cfg)
    if idx.n_entries == 0:
        return
    assert (idx.V.sum(axis=0) >= 2).all()
    for d in np.unique(idx.entry_item):
        assert idx.V[:, idx.entry_item == d].sum(axis=1).max() <= 1
    assert (np.diff(idx.entry_score) <= 1e-5).all()
    tail = np.maximum(idx.entry_score[idx.ebar_start:], 0.0)
    assert tail.sum() < cfg.theta_ind + 1e-5
