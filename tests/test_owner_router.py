"""Shard-owner router behavior (ISSUE 10, DESIGN.md §12).

Covers the router-level contracts the subprocess equivalence matrix
(tests/test_owner_modes.py) does not: fault handling when an owner replica
dies mid-scan (one typed error, no partial merge, breaker-gated rejoin —
satellite 3), owner-range-tagged WAL records and independent per-replica
restore, and the unseal → rebalance → reseal operator drill end-to-end
(satellite 2).
"""
import os

import numpy as np
import pytest

from repro.core.serving import (
    DetectRequest,
    DetectionService,
    DurabilityOptions,
    ReplicaRouter,
)
from repro.core.shardplan import (
    ShardScanError,
    ShardedCorpusStore,
    make_shard_plan,
)
from repro.core.types import ClaimsDataset, CopyConfig
from repro.core.wal import CommitLog, CommitRecord, RetractRecord, _encode_arrays

from tests.faults import FakeClock


def _corpus(S=64, D=32, V=5, seed=0):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, V, (S, D)).astype(np.int32)
    vals[rng.random((S, D)) < 0.3] = -1
    vals[8] = vals[3]                       # one certain copier pair
    acc = rng.uniform(0.4, 0.9, S).astype(np.float32)
    p = rng.uniform(0.3, 0.9, (S, D)).astype(np.float32)
    return ClaimsDataset(values=vals, accuracy=acc), p


def _query(ds, q=4, seed=1):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, 5, (q, ds.n_items)).astype(np.int32)
    vals[rng.random((q, ds.n_items)) < 0.3] = -1
    vals[0] = ds.values[3]
    acc = rng.uniform(0.4, 0.9, q).astype(np.float32)
    p = rng.uniform(0.3, 0.9, (q, ds.n_items)).astype(np.float32)
    return vals, acc, p


def _serve_one(svc, req):
    fut = svc.submit(req)
    svc.flush()
    return fut.result()


# ---------------------------------------------------------------------------
# Satellite 3: a dead owner replica mid-scan
# ---------------------------------------------------------------------------

def test_dead_owner_mid_scan_typed_error_then_rejoin():
    ds, p = _corpus()
    cfg = CopyConfig()
    qv, qa, qp = _query(ds)
    req = DetectRequest(rid=1, values=qv, accuracy=qa, p_claim=qp)

    single = DetectionService(ds, p, cfg, mode="bucketed", tile=16)
    ref = _serve_one(single, req)

    clock = FakeClock()
    router = ReplicaRouter(ds, p, cfg, shard_owners=2, mode="bucketed",
                           tile=16, breaker_threshold=2,
                           breaker_cooldown_s=5.0, breaker_clock=clock)
    eng = router.replicas[0].engine
    orig_partial = eng.detect_owner_partial
    orig_finalize = eng.finalize_owner_partials
    calls = {"partial": 0, "finalize": 0}

    def dead_owner_1(ds_, p_, owner, index=None, ctx=None):
        calls["partial"] += 1
        if owner == 1:
            raise RuntimeError("owner host 1 is unreachable")
        return orig_partial(ds_, p_, owner, index=index, ctx=ctx)

    def counting_finalize(*a, **kw):
        calls["finalize"] += 1
        return orig_finalize(*a, **kw)

    eng.detect_owner_partial = dead_owner_1
    eng.finalize_owner_partials = counting_finalize
    try:
        # ONE typed error carrying the owner id; no partial grids merged
        with pytest.raises(ShardScanError) as ei:
            router.submit(req).result()
        assert ei.value.shard == 1
        assert calls["finalize"] == 0
        assert router.breakers[1].failures == 1

        # second failure trips the breaker (threshold=2): the NEXT scan is
        # refused fast — before the dead owner's partial is even attempted
        with pytest.raises(ShardScanError):
            router.submit(req).result()
        assert router.breakers[1].state == "open"
        seen = calls["partial"]
        with pytest.raises(ShardScanError) as ei:
            router.submit(req).result()
        assert ei.value.shard == 1
        assert "circuit-open" in str(ei.value)
        assert calls["partial"] == seen + 1      # owner 0 probed, 1 skipped

        # a write while owner 1 is down defers into its backlog; the
        # healthy rest commits and the fleet epoch advances without it
        infos = router.commit(qv[:2], qa[:2], qp[:2])
        assert infos[1] is None and len(router._backlogs[1]) == 1
        assert router._in_sync() == [0]
    finally:
        eng.detect_owner_partial = orig_partial
        eng.finalize_owner_partials = orig_finalize

    # rejoin: cooldown elapses, catch-up replays the backlog, the breaker
    # closes, and fan-out reads serve again — bit-equal to single-host
    clock.advance(6.0)
    replayed = router.catch_up()
    assert replayed[1] == 1 and not router._backlogs[1]
    assert router.breakers[1].state == "closed"
    assert router._in_sync() == [0, 1]
    assert router.epoch == 1

    single.commit(qv[:2], qa[:2], qp[:2])
    req2 = DetectRequest(rid=2, values=qv, accuracy=qa, p_claim=qp)
    got = _serve_one(router, req2)
    want = _serve_one(single, req2)
    assert np.array_equal(got.copying, want.copying)
    assert np.array_equal(got.c_fwd, want.c_fwd)
    assert ref.copying.shape == (4, 64)


# ---------------------------------------------------------------------------
# Owner-range-tagged WAL records, independent per-replica restore
# ---------------------------------------------------------------------------

def test_owner_range_in_wal_and_independent_restore(tmp_path):
    ds, p = _corpus()
    cfg = CopyConfig()
    qv, qa, qp = _query(ds, q=6)
    state = str(tmp_path / "fleet")
    router = ReplicaRouter(
        ds, p, cfg, shard_owners=2, mode="bucketed", tile=16,
        durability=DurabilityOptions(state_dir=state, snapshot_every=0))
    n0 = ds.n_sources
    router.commit(qv[:4], qa[:4], qp[:4])
    router.retract([1, 3])
    router.commit(qv[4:6], qa[4:6], qp[4:6])
    live_epoch = router.epoch
    live_dense = router.replicas[0]._index.store.to_dense()

    # every replica logged every record, each stamped with the owning range
    for i in range(2):
        records, _, _ = CommitLog.scan(
            os.path.join(state, f"replica-{i}", "commits.wal"))
        assert [type(r).__name__ for r in records] == [
            "CommitRecord", "RetractRecord", "CommitRecord"]
        assert (records[0].owner_lo, records[0].owner_hi) == (n0, n0 + 4)
        assert (records[1].owner_lo, records[1].owner_hi) == (1, 4)
        assert (records[2].owner_lo, records[2].owner_hi) == (n0 + 2, n0 + 4)
        # the commit's rows belong to ONE owner under the plan
        plan = router._owner_plan()
        assert plan.owner_of_row(records[0].owner_lo) == plan.owner_of_row(
            records[0].owner_hi - 1)

    # replica-0 (the primary) restores alone and reproduces the index
    primary = DetectionService.restore(os.path.join(state, "replica-0"))
    assert primary.epoch == live_epoch
    assert isinstance(primary._index.store, ShardedCorpusStore)
    assert np.array_equal(primary._index.store.to_dense(), live_dense)

    # replica-1 restores independently from ITS state dir, adopting the
    # restored primary's index (its snapshot carries claims state only)
    member = DetectionService.restore(os.path.join(state, "replica-1"),
                                      _shared_index=primary._index)
    assert member.epoch == live_epoch
    assert member._index_shared
    assert np.array_equal(
        member.resident.values[:member.resident.n_corpus],
        primary.resident.values[:primary.resident.n_corpus])


def test_wal_owner_range_back_compat():
    # a pre-§12 record (3-int / 2-int meta) decodes with an unscoped range
    old_commit = _encode_arrays({
        "values": np.zeros((1, 4), np.int32),
        "accuracy": np.zeros(1, np.float32),
        "p_claim": np.zeros((1, 4), np.float32),
        "touched_keys": np.zeros(0, np.int64),
        "meta": np.array([3, 1, 0], np.int64)})
    rec = CommitRecord.from_payload(old_commit)
    assert (rec.owner_lo, rec.owner_hi) == (-1, -1)
    assert (rec.epoch, rec.compact, rec.compacted) == (3, True, False)
    old_retract = _encode_arrays({
        "row_ids": np.array([2], np.int64),
        "touched_keys": np.zeros(0, np.int64),
        "meta": np.array([4, 10], np.int64)})
    rrec = RetractRecord.from_payload(old_retract)
    assert (rrec.owner_lo, rrec.owner_hi) == (-1, -1)
    assert (rrec.epoch, rrec.n_before) == (4, 10)
    # round-trip of a scoped record keeps the range
    rt = CommitRecord.from_payload(CommitRecord(
        epoch=5, values=np.zeros((1, 4), np.int32),
        accuracy=np.zeros(1, np.float32),
        p_claim=np.zeros((1, 4), np.float32),
        touched_keys=np.zeros(0, np.int64), compact=True, compacted=False,
        owner_lo=64, owner_hi=68).payload())
    assert (rt.owner_lo, rt.owner_hi) == (64, 68)


# ---------------------------------------------------------------------------
# Satellite 2: unseal → rebalance → reseal through the router
# ---------------------------------------------------------------------------

def test_rebalance_drill_end_to_end():
    ds, p = _corpus()
    cfg = CopyConfig()
    qv, qa, qp = _query(ds, q=40, seed=9)
    router = ReplicaRouter(ds, p, cfg, shard_owners=2, mode="bucketed",
                           tile=16)
    store = router.replicas[0]._index.store
    # growth lands in the tail owner's range — skew the placement
    for k in range(0, 40, 8):
        router.commit(qv[k:k + 8], qa[k:k + 8], qp[k:k + 8])
    assert store.plan.imbalance() > 1.25

    moved = router.rebalance(tolerance=0.25)
    assert moved
    n_rows = store.n_rows
    fresh_plan = make_shard_plan(n_rows, 2)
    assert np.array_equal(store.plan.bounds, fresh_plan.bounds)
    assert np.array_equal(store.plan.sizes(), fresh_plan.sizes())
    assert store.plan.imbalance() <= 1.25

    # per-shard footprints match a freshly-planned build over the same
    # corpus: same live rows per slice (entry COLUMN order differs — the
    # live store carries delta chunks a fresh build folds in)
    fresh = DetectionService(
        ClaimsDataset(
            values=router.replicas[0].resident.values[:n_rows].copy(),
            accuracy=router.replicas[0].resident.accuracy[:n_rows].copy()),
        router.replicas[0].resident.p_claim[:n_rows].copy(),
        cfg, mode="bucketed", tile=16, n_shards=2)
    assert np.array_equal(fresh._index.store.plan.sizes(),
                          store.plan.sizes())

    # decisions after the rebalance match the fresh plan bit-for-bit
    req = DetectRequest(rid=7, values=qv[:4], accuracy=qa[:4],
                        p_claim=qp[:4])
    got = _serve_one(router, req)
    want = _serve_one(fresh, req)
    assert np.array_equal(got.copying, want.copying)
    assert np.array_equal(got.c_fwd, want.c_fwd)
    assert np.array_equal(got.pr_independent, want.pr_independent)

    # the sealed drill: seal (bitpacked), rebalance again after more skew —
    # the router unseals, re-splits, reseals; reads still work after
    router.commit(qv[:8], qa[:8], qp[:8])
    store.seal(pack=True)
    moved2 = router.rebalance(tolerance=0.0)
    assert moved2 and store.sealed
    store.unseal()
    got2 = _serve_one(router, DetectRequest(rid=8, values=qv[:2],
                                            accuracy=qa[:2], p_claim=qp[:2]))
    assert got2.copying.shape == (2, router.replicas[0].resident.n_corpus)


def test_rebalance_requires_sharded_index():
    ds, p = _corpus(S=32)
    router = ReplicaRouter(ds, p, CopyConfig(), n_replicas=2,
                           mode="bucketed", tile=16)
    with pytest.raises(RuntimeError):
        router.rebalance()
