"""Per-architecture smoke tests: reduced config, one forward + one train
gradient step on CPU; output shapes correct and finite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.cond_len:
        batch["cond"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.cond_len, cfg.cond_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch["tokens"], cond=batch.get("cond"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_direction(arch):
    """One SGD step along the gradient must not blow up; loss finite and
    grads nonzero for at least the embedding."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, key=1)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch

    params2 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = model.loss(params2, batch)
    assert bool(jnp.isfinite(loss2)), arch
    assert float(loss2) < float(loss) + 1.0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full-size configs carry the exact assigned hyperparameters."""
    assigned = {
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }
    cfg = get_config(arch)
    L, d, h, kv, ff, v = assigned[cfg.name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v)
    assert sum(c for _, c in cfg.plan) == cfg.n_layers
    if cfg.name.startswith("phi3.5"):
        assert cfg.n_experts == 16 and cfg.top_k == 2
    if cfg.name.startswith("grok"):
        assert cfg.n_experts == 8 and cfg.top_k == 2
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_state == 16
        assert cfg.supports_long_context
