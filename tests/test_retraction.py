"""ISSUE 7 acceptance: retract-then-detect decisions equal a rebuild of the
service without the retracted sources — every engine mode — including after
a kill/restore that replays the retraction from the WAL.

Mirrors tests/test_mutation_modes.py: the nine-mode matrix runs in one
subprocess with 8 virtual devices at the INDEX level (commit, retract, then
compare the committed-and-retracted index against ``build_index`` over the
surviving claims). Service-level behavior — resident compaction, eager
cache reconciliation, the WAL ``RetractRecord``, LIFO rollback — is pinned
in-process on the servable modes.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    ClaimsDataset,
    CopyConfig,
    DetectionService,
    DetectRequest,
    DurabilityOptions,
    RetractRecord,
)
from repro.core.wal import CommitLog, LOG_NAME
from repro.data.claims import (
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
    synthetic_query_rows,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import (CopyConfig, DetectionEngine, build_index,
                            commit_rows, retract_rows)
    from repro.core.types import ClaimsDataset
    from repro.data.claims import (
        SyntheticSpec, oracle_claim_probs, synthetic_claims,
        synthetic_query_rows)

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    INDEXED = ("exact", "bound", "bound+", "hybrid", "bucketed", "incremental")

    def decisions(mode, ds, p, idx, devices):
        eng = DetectionEngine(cfg, mode=mode, tile=64, devices=devices,
                              sample_rate=0.2, sample_seed=1)
        use_idx = idx if mode in INDEXED else None
        return eng.detect(ds, p, index=use_idx).copying

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        q = 6
        vals, acc, pq, _ = synthetic_query_rows(sc, q, seed=3)
        union = ClaimsDataset(
            values=np.concatenate([sc.dataset.values, vals]),
            accuracy=np.concatenate([sc.dataset.accuracy, acc]))
        union_p = np.concatenate([p, pq])

        idx = build_index(sc.dataset, p, cfg, row_capacity=S + q)
        commit_rows(idx, union, union_p, cfg, q, compact=False)
        assert idx.store.n_delta_chunks > 0, "schedule must leave deltas"

        # retract a mix: two original corpus rows (clique members — their
        # loss changes decisions) and two committed rows (delta territory)
        row_ids = np.array([1, 2, S + 1, S + 4], np.int64)
        keep = np.setdiff1d(np.arange(S + q), row_ids)
        ds_after = ClaimsDataset(values=union.values[keep],
                                 accuracy=union.accuracy[keep])
        p_after = union_p[keep]
        info = retract_rows(idx, ds_after, cfg, row_ids)
        idx_rebuilt = build_index(ds_after, p_after, cfg)

        for mode in ("pairwise", "exact", "bound", "bound+", "hybrid",
                     "incremental", "sampled", "sample_verify", "bucketed"):
            dev_counts = (1, 8) if mode in ("bucketed", "sampled",
                                            "sample_verify") else (1,)
            for n_dev in dev_counts:
                a = decisions(mode, ds_after, p_after, idx, n_dev)
                b = decisions(mode, ds_after, p_after, idx_rebuilt, n_dev)
                out[f"S{S}/{mode}/dev{n_dev}"] = {
                    "equal": bool(np.array_equal(a, b)),
                    "copying_bits": int(a.sum()),
                    "touched": info.touched_entries,
                    "gc": info.gc_entries}
    print("RESULT" + json.dumps(out))
""")


def test_all_modes_retract_equals_rebuild():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert len(out) == 24, sorted(out)
    for combo, r in out.items():
        assert r["equal"], f"{combo}: retract-then-detect diverged from rebuild"
        assert r["touched"] > 0, f"{combo}: retraction touched no entries"
    assert any(r["copying_bits"] > 0 for r in out.values())


# ---------------------------------------------------------------------------
# service-level: resident compaction, cache reconciliation, WAL, rollback
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    sc = synthetic_claims(SyntheticSpec(n_sources=60, n_items=300,
                                        coverage="stock", n_cliques=4, seed=2))
    p = oracle_claim_probs(sc)
    vals, acc, pq, _ = synthetic_query_rows(sc, 9, seed=5)
    reqs = [DetectRequest(rid=i, values=vals[3 * i: 3 * i + 3],
                          accuracy=acc[3 * i: 3 * i + 3],
                          p_claim=pq[3 * i: 3 * i + 3])
            for i in range(3)]
    return sc, p, reqs


def _answers(svc, reqs, tag):
    futs = [svc.submit(DetectRequest(rid=f"{tag}-{r.rid}", values=r.values,
                                     accuracy=r.accuracy, p_claim=r.p_claim))
            for r in reqs]
    svc.flush()
    return [f.result(timeout=30) for f in futs]


def test_service_retract_with_warm_cache_equals_rebuild(world):
    """A warm cache survives the retraction only where provably unaffected —
    the post-retraction answers (hits included) equal a cold rebuild."""
    sc, p, reqs = world
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64)
    _answers(svc, reqs, "warm")
    _answers(svc, reqs, "warm")          # second round hits the cache
    assert svc.cache.hits > 0
    row_ids = [3, 17, 41]
    info = svc.retract(row_ids)
    assert info.rows == 3
    assert svc.stats.retractions == 1 and svc.stats.retracted_rows == 3
    after = _answers(svc, reqs, "after")

    keep = np.setdiff1d(np.arange(sc.dataset.n_sources), row_ids)
    ref = DetectionService(
        ClaimsDataset(values=sc.dataset.values[keep],
                      accuracy=sc.dataset.accuracy[keep]),
        p[keep], CFG, mode="bucketed", tile=64, result_cache=False)
    expected = _answers(ref, reqs, "ref")
    for a, b in zip(after, expected):
        np.testing.assert_array_equal(a.copying, b.copying)
        np.testing.assert_array_equal(a.intra_copying, b.intra_copying)
        assert a.copying.shape[1] == keep.size


def test_service_retract_rollback_bit_exact(world):
    sc, p, reqs = world
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64)
    before = _answers(svc, reqs, "before")
    e0, n0 = svc.epoch, svc.resident.n_corpus
    svc.retract([0, 7])
    assert svc.epoch == e0 + 1 and svc.resident.n_corpus == n0 - 2
    svc.rollback_last_retract()
    assert svc.epoch == e0 and svc.resident.n_corpus == n0
    assert svc.stats.retractions == 0 and svc.stats.retracted_rows == 0
    after = _answers(svc, reqs, "rb")
    for a, b in zip(after, before):
        np.testing.assert_array_equal(a.copying, b.copying)
    with pytest.raises(RuntimeError, match="no retraction"):
        svc.rollback_last_retract()


def test_service_retract_validates_and_guards_lifo(world):
    sc, p, _ = world
    svc = DetectionService(sc.dataset, p, CFG, mode="bucketed", tile=64)
    with pytest.raises(ValueError, match="no rows"):
        svc.retract([])
    with pytest.raises(ValueError, match="row ids"):
        svc.retract([sc.dataset.n_sources])
    rng = np.random.default_rng(0)
    svc.retract([5])
    svc.commit(rng.integers(0, 3, (1, sc.dataset.n_items)).astype(np.int32),
               np.array([0.7], np.float32),
               rng.uniform(0.2, 0.8, (1, sc.dataset.n_items)).astype(np.float32))
    # the commit is now the newest mutation — the retraction can no longer
    # be unwound (LIFO), and vice versa after another retract
    with pytest.raises(RuntimeError, match="no retraction"):
        svc.rollback_last_retract()
    svc.retract([9])
    with pytest.raises(RuntimeError, match="no commit"):
        svc.rollback_last_commit()


def test_restore_replays_retraction_from_wal(tmp_path, world):
    """Kill after commit→retract→commit; restore replays the RetractRecord
    between the commits and lands on identical decisions and counters."""
    sc, p, reqs = world
    rng = np.random.default_rng(3)
    c = lambda: (rng.integers(0, 3, (2, sc.dataset.n_items)).astype(np.int32),
                 rng.uniform(0.5, 0.9, 2).astype(np.float32),
                 rng.uniform(0.2, 0.8, (2, sc.dataset.n_items)).astype(np.float32))
    svc = DetectionService(
        sc.dataset, p, CFG, mode="bucketed", tile=64,
        durability=DurabilityOptions(state_dir=str(tmp_path), snapshot_every=0))
    svc.commit(*c())
    svc.retract([2, sc.dataset.n_sources])   # one base row, one committed row
    svc.commit(*c())
    live = _answers(svc, reqs, "live")
    e_live, n_live = svc.epoch, svc.resident.n_corpus
    del svc                                   # simulated kill: no clean stop

    records, _, _ = CommitLog.scan(str(tmp_path / LOG_NAME))
    assert sum(isinstance(r, RetractRecord) for r in records) == 1

    svc2 = DetectionService.restore(str(tmp_path))
    assert svc2.restore_info.replayed_commits == 3
    assert svc2.epoch == e_live and svc2.resident.n_corpus == n_live
    assert svc2.stats.retractions == 1 and svc2.stats.retracted_rows == 2
    restored = _answers(svc2, reqs, "restored")
    for a, b in zip(restored, live):
        np.testing.assert_array_equal(a.copying, b.copying)
        np.testing.assert_array_equal(a.intra_copying, b.intra_copying)
