"""DetectionEngine: tile-pruned + sharded detection is decision-identical to
the exact INDEX, across tile sizes and mesh sizes (8 virtual devices run in a
subprocess, as in test_distributed_core)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import CopyConfig, DetectionEngine
from repro.core.bucketed import index_detect_exact
from repro.data.claims import (
    SyntheticSpec,
    motivating_example,
    motivating_value_probs,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


@pytest.fixture(scope="module")
def motivating():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    return ds, p


@pytest.fixture(scope="module")
def synthetic():
    sc = synthetic_claims(SyntheticSpec(n_sources=96, n_items=480,
                                        coverage="book", n_cliques=5,
                                        clique_size=3, clique_items=12, seed=3))
    p = oracle_claim_probs(sc)
    return sc.dataset, p, index_detect_exact(sc.dataset, p, CFG)


def test_exact_mode_paper_accounting(motivating):
    # Ex. 3.6: 26 pairs / 51 shared values / 154 computations
    ds, p = motivating
    res = DetectionEngine(CFG, mode="exact").detect(ds, p)
    assert res.counter.pairs_considered == 26
    assert res.counter.shared_values_examined == 51
    assert res.counter.score_computations == 154


def test_tiled_matches_exact_on_motivating(motivating):
    ds, p = motivating
    exact = DetectionEngine(CFG, mode="exact").detect(ds, p)
    res = DetectionEngine(CFG, mode="bucketed", tile=64).detect(ds, p)
    np.testing.assert_array_equal(res.copying, exact.copying)
    assert res.counter.pairs_considered == exact.counter.pairs_considered
    assert res.counter.shared_values_examined == exact.counter.shared_values_examined


@pytest.mark.parametrize("tile", [32, 128])
def test_tiled_matches_exact_random(synthetic, tile):
    ds, p, exact = synthetic
    eng = DetectionEngine(CFG, mode="bucketed", tile=tile)
    res = eng.detect(ds, p)
    np.testing.assert_array_equal(res.copying, exact.copying)
    assert res.counter.pairs_considered == exact.counter.pairs_considered
    st = eng.last_stats
    assert st["tiles_total"] >= 1
    # triangular schedule: tiles scheduled ≤ (n_blocks² + n_blocks) / 2
    n_blocks = -(-ds.n_sources // st["tile"])
    assert st["tiles_kept"] <= (n_blocks * n_blocks + n_blocks) // 2


def test_tile_edge_clamps_small_datasets():
    """S < 64 must not pad up to a 64-wide tile: the edge is the smallest
    multiple of 8 ≥ min(S, requested)."""
    eng = DetectionEngine(CFG, mode="bucketed", tile=256)
    assert eng._tile_edge(10) == 16
    assert eng._tile_edge(8) == 8
    assert eng._tile_edge(64) == 64
    assert eng._tile_edge(2048) == 256
    assert DetectionEngine(CFG, mode="bucketed", tile=48)._tile_edge(2048) == 48


def test_tiny_dataset_decisions_match_exact():
    """A 20-source dataset runs on a 24-wide tile (not 64) and still matches
    the exact INDEX."""
    rng = np.random.default_rng(7)
    from repro.core import ClaimsDataset
    values = rng.integers(0, 3, (20, 60)).astype(np.int32)
    values[rng.random((20, 60)) < 0.3] = -1
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.9, 20).astype(np.float32))
    p = np.where(values >= 0, 0.4, 0.0).astype(np.float32)
    exact = index_detect_exact(ds, p, CFG)
    eng = DetectionEngine(CFG, mode="bucketed", tile=256)
    res = eng.detect(ds, p)
    assert eng.last_stats["tile"] == 24
    np.testing.assert_array_equal(res.copying, exact.copying)


def test_tile_pruning_skips_disjoint_groups():
    """Two provider groups over disjoint items: every cross tile is pruned,
    decisions still match the exact INDEX."""
    rng = np.random.default_rng(0)
    S, D = 96, 240
    half_s, half_d = S // 2, D // 2
    values = np.full((S, D), -1, np.int32)
    values[:half_s, :half_d] = rng.integers(0, 3, (half_s, half_d))
    values[half_s:, half_d:] = rng.integers(0, 3, (half_s, half_d))
    from repro.core import ClaimsDataset
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.9, S).astype(np.float32))
    p = np.where(values >= 0, 0.4, 0.0).astype(np.float32)

    exact = index_detect_exact(ds, p, CFG)
    eng = DetectionEngine(CFG, mode="bucketed", tile=48)
    res = eng.detect(ds, p)
    np.testing.assert_array_equal(res.copying, exact.copying)
    stats = eng.last_stats
    # 2×2 blocks → 3 unordered tiles; the single cross-group tile is pruned
    assert stats["tiles_total"] == 3
    assert stats["tiles_pruned"] == 1
    assert stats["tiles_kept"] == 2            # the two diagonal tiles
    # pruned pairs are reported independent, same as the Ē-skip rule
    assert (res.pr_independent[:half_s, half_s:] == 1.0).all()


def test_sample_verify_matches_exact_on_candidates(synthetic):
    """ISSUE 3 tentpole: every candidate pair's decision equals the exact
    INDEX (the rescore is exact), and nothing outside the net is reported."""
    ds, p, exact = synthetic
    eng = DetectionEngine(CFG, mode="sample_verify", sample_rate=0.2)
    res = eng.detect(ds, p)
    cand = eng._last_considered
    st = eng.last_stats
    assert st["candidate_pairs"] > 0
    assert st["sweep_rounds"] >= 1
    np.testing.assert_array_equal(res.copying[cand], exact.copying[cand])
    assert not res.copying[~cand].any()
    # the exact rescore happens on the full dataset: scores at candidate
    # pairs are bit-equal to the exact INDEX's (both use the same kernel)
    np.testing.assert_allclose(res.c_fwd[cand], exact.c_fwd[cand], atol=1e-4)


def test_sample_verify_deterministic(synthetic):
    """Fixed sample_seed ⇒ identical sample, candidates, and decisions."""
    ds, p, _ = synthetic
    r1 = DetectionEngine(CFG, mode="sample_verify").detect(ds, p)
    r2 = DetectionEngine(CFG, mode="sample_verify").detect(ds, p)
    np.testing.assert_array_equal(r1.copying, r2.copying)
    np.testing.assert_array_equal(r1.c_fwd, r2.c_fwd)


def test_sampled_mode_equals_tiled_on_subset(synthetic):
    ds, p, _ = synthetic
    items = np.arange(0, ds.n_items, 3)
    sub = ds.subset_items(items)
    direct = DetectionEngine(CFG, mode="bucketed").detect(sub, p[:, items])
    sampled = DetectionEngine(CFG, mode="sampled").detect(ds, p, items=items)
    np.testing.assert_array_equal(sampled.copying, direct.copying)


def test_incremental_lifecycle(synthetic):
    ds, p, _ = synthetic
    eng = DetectionEngine(CFG, mode="incremental")
    first = eng.detect(ds, p)
    assert eng.incremental_state is not None
    rng = np.random.default_rng(1)
    p2 = np.clip(p + np.where(p > 0, rng.normal(0, 0.003, p.shape), 0),
                 1e-3, 0.999).astype(np.float32)
    second = eng.detect(ds, p2)
    # small drift: decisions essentially stable
    flips = int(np.sum(first.copying != second.copying))
    assert flips <= 4
    eng.reset()
    assert eng.incremental_state is None


def test_unknown_mode_rejected():
    with pytest.raises(ValueError):
        DetectionEngine(CFG, mode="nope")


SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine
    from repro.core.bucketed import index_detect_exact
    from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    sc = synthetic_claims(SyntheticSpec(n_sources=96, n_items=400,
                                        coverage="stock", n_cliques=5, seed=0))
    p = oracle_claim_probs(sc)
    exact = index_detect_exact(sc.dataset, p, cfg)
    r1 = DetectionEngine(cfg, mode="bucketed", tile=32, devices=1).detect(sc.dataset, p)
    e8 = DetectionEngine(cfg, mode="bucketed", tile=32, devices=8)
    r8 = e8.detect(sc.dataset, p)
    n_blocks = -(-sc.dataset.n_sources // e8.last_stats["tile"])
    out = {
        "c_diff": float(np.abs(r1.c_fwd - r8.c_fwd).max()),
        "dec_18": bool(np.array_equal(r1.copying, r8.copying)),
        "dec_exact": bool(np.array_equal(r8.copying, exact.copying)),
        "n_devices": int(e8.last_stats["n_devices"]),
        "tiles_kept": int(e8.last_stats["tiles_kept"]),
        "tri_bound": (n_blocks * n_blocks + n_blocks) // 2,
    }
    print("RESULT" + json.dumps(out))
""")


def test_sharded_engine_matches_single_device():
    proc = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["n_devices"] == 8
    assert out["c_diff"] < 1e-4
    assert out["dec_18"] and out["dec_exact"]
    # triangular schedule holds on the sharded mesh too
    assert out["tiles_kept"] <= out["tri_bound"]


SAMPLE_VERIFY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine
    from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        exact = DetectionEngine(cfg, mode="exact").detect(sc.dataset, p)
        for n_dev in (1, 8):
            eng = DetectionEngine(cfg, mode="sample_verify", devices=n_dev,
                                  tile=64, sample_rate=0.15)
            res = eng.detect(sc.dataset, p)
            cand = eng._last_considered
            out[f"S{S}_dev{n_dev}"] = {
                "agree": bool((res.copying[cand] == exact.copying[cand]).all()),
                "none_outside": bool(not res.copying[~cand].any()),
                "n_cand": int(eng.last_stats["candidate_pairs"]),
            }
    print("RESULT" + json.dumps(out))
""")


def test_sample_verify_matrix_sources_devices():
    """ISSUE 3 acceptance: sample_verify decisions equal index_detect_exact
    on the candidate set at S ∈ {64, 512} × {1, 8} devices."""
    proc = subprocess.run([sys.executable, "-c", SAMPLE_VERIFY_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert set(out) == {"S64_dev1", "S64_dev8", "S512_dev1", "S512_dev8"}
    for combo, r in out.items():
        assert r["agree"], f"{combo}: decisions diverged from exact"
        assert r["none_outside"], combo
        assert r["n_cand"] > 0, combo
