"""ISSUE 7 acceptance: traffic hardening under overload (DESIGN.md §9).

Deadline propagation (admission shed → queue expiry → post-pass miss),
the adaptive batch limit, failed-pass accounting, the per-replica circuit
breaker's full closed → open → half-open → closed cycle with backlog
catch-up, and the stop()-vs-submitters race — all driven through the
tests/faults.py injection harness, no real overload required.
"""
import threading
import time

import numpy as np
import pytest

import faults
from repro.core import CopyConfig
from repro.core.serving import (
    CircuitBreaker,
    DeadlineExceeded,
    DetectRequest,
    DetectionService,
    ReplicaBroadcastError,
    ReplicaRouter,
    ServiceOverloaded,
    ServiceStopped,
)
from repro.data.claims import (
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
    synthetic_query_rows,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


@pytest.fixture(scope="module")
def world():
    sc = synthetic_claims(SyntheticSpec(n_sources=48, n_items=240,
                                        coverage="stock", n_cliques=3, seed=4))
    p = oracle_claim_probs(sc)
    vals, acc, pq, _ = synthetic_query_rows(sc, 3, seed=6)
    return sc, p, (vals, acc, pq)


def _req(world, rid, deadline_s=None):
    _, _, (vals, acc, pq) = world
    return DetectRequest(rid=rid, values=vals, accuracy=acc, p_claim=pq,
                         deadline_s=deadline_s)


def _svc(world, **kw):
    sc, p, _ = world
    kw.setdefault("mode", "bucketed")
    kw.setdefault("tile", 64)
    return DetectionService(sc.dataset, p, CFG, **kw)


# ---------------------------------------------------------------------------
# deadlines: queue expiry, admission control, wait percentiles
# ---------------------------------------------------------------------------

def test_deadline_expires_while_queued(world):
    """A request whose deadline passes in the queue is shed at batch start
    with a typed error — it never rides (and slows) the engine pass."""
    svc = _svc(world)
    clock = faults.FakeClock()
    svc._clock = clock
    f_ddl = svc.submit(_req(world, "ddl", deadline_s=1.0))
    f_free = svc.submit(_req(world, "free"))
    clock.advance(2.0)
    svc.flush()
    with pytest.raises(DeadlineExceeded, match="queued"):
        f_ddl.result(timeout=5)
    assert f_free.result(timeout=5).rid == "free"
    assert svc.stats.expired == 1
    assert svc.stats.rejected == 0          # expiry is not backpressure
    assert svc.stats.requests == 1          # only the live request served


def test_admission_control_sheds_on_arrival(world):
    """When the latency EWMA predicts the deadline cannot hold, submit
    raises immediately — the queue never sees the request."""
    svc = _svc(world, max_batch_requests=2)
    svc._ewma_batch_s = 1.0                  # as if batches take 1s
    queued = [svc.submit(_req(world, f"q{i}")) for i in range(2)]
    # one batch ahead + own pass → ~2s predicted; a 0.5s deadline is hopeless
    with pytest.raises(DeadlineExceeded, match="shed on arrival"):
        svc.submit(_req(world, "doomed", deadline_s=0.5))
    assert svc.stats.shed == 1
    # a generous deadline is admitted despite the same queue
    ok = svc.submit(_req(world, "patient", deadline_s=60.0))
    svc.flush()
    assert all(f.result(timeout=5) for f in queued)
    assert ok.result(timeout=5).rid == "patient"
    # with no estimate yet, admission stands down instead of shedding blind
    svc2 = _svc(world)
    assert svc2._admission_wait_estimate() == 0.0


def test_queue_wait_percentiles_recorded(world):
    svc = _svc(world)
    assert svc.stats.queue_wait_p50 == 0.0 == svc.stats.queue_wait_p99
    futs = [svc.submit(_req(world, i)) for i in range(3)]
    svc.flush()
    [f.result(timeout=5) for f in futs]
    assert len(svc.stats.queue_wait_samples) == 3
    assert svc.stats.queue_wait_p99 >= svc.stats.queue_wait_p50 >= 0.0


def test_clock_jump_expires_typed_not_hung(world):
    """tests/faults.py skew: a forward clock jump between submit and drain
    expires queued deadlines as typed errors — never a wedged future."""
    svc = _svc(world)
    fut = svc.submit(_req(world, "jump", deadline_s=5.0))
    with faults.skewed_clock(svc, 60.0):
        svc.flush()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5)
    assert svc.stats.expired == 1


# ---------------------------------------------------------------------------
# adaptive batch limit + failed-pass accounting
# ---------------------------------------------------------------------------

def test_adaptive_batch_shrinks_on_miss_then_regrows(world):
    svc = _svc(world, max_batch_requests=4)
    clock = faults.FakeClock()
    svc._clock = clock
    import repro.core.serving as serving_mod
    orig = serving_mod.serve_batch

    def ticking(*a, **kw):                   # the pass takes 1 fake second
        clock.advance(1.0)
        return orig(*a, **kw)

    serving_mod.serve_batch = ticking
    try:
        # alive at batch start, missed after the pass → multiplicative shrink
        fut = svc.submit(_req(world, "miss", deadline_s=0.5))
        svc.flush()
        fut.result(timeout=5)                # a miss still gets its answer
        assert svc._batch_limit == 2 and svc.stats.batch_shrinks == 1
        assert svc._ewma_batch_s > 0.0
        # deadline-clean batches regrow the limit additively (every 4th)
        for i in range(8):
            svc.submit(_req(world, f"ok{i}"))
            svc.flush()
        assert svc._batch_limit > 2
        assert svc.stats.batch_grows >= 1
    finally:
        serving_mod.serve_batch = orig


def test_failed_pass_counts_failed_stats(world):
    """The PR-6 blind spot: a failing engine pass must show up in stats."""
    svc = _svc(world)
    import repro.core.serving as serving_mod
    orig = serving_mod.serve_batch

    def boom(*a, **kw):
        raise RuntimeError("engine on fire")

    serving_mod.serve_batch = boom
    try:
        futs = [svc.submit(_req(world, i)) for i in range(2)]
        svc.flush()
    finally:
        serving_mod.serve_batch = orig
    for f in futs:
        with pytest.raises(RuntimeError, match="on fire"):
            f.result(timeout=5)
    assert svc.stats.failed_batches == 1
    assert svc.stats.failed_requests == 2
    assert svc.stats.requests == 0           # failures are not successes


# ---------------------------------------------------------------------------
# circuit breaker: unit cycle + router protocol under injected faults
# ---------------------------------------------------------------------------

def test_breaker_state_machine():
    clock = faults.FakeClock()
    br = CircuitBreaker(failure_threshold=3, cooldown_s=10.0, clock=clock)
    assert br.allow() and br.state == "closed"
    br.record_failure(); br.record_failure()
    assert br.allow()                        # below threshold: still closed
    br.record_failure()
    assert br.state == "open" and br.trips == 1 and not br.allow()
    clock.advance(9.9)
    assert not br.allow()                    # cooldown not elapsed
    clock.advance(0.2)
    assert br.allow() and br.state == "half-open"
    br.record_failure()                      # probe failed: re-open, re-trip
    assert br.state == "open" and br.trips == 2 and not br.allow()
    clock.advance(10.1)
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.failures == 0
    with pytest.raises(ValueError, match="threshold"):
        CircuitBreaker(failure_threshold=0)


def test_router_breaker_ejects_and_replica_rejoins(world):
    sc, p, _ = world
    rng = np.random.default_rng(9)
    c = lambda: (rng.integers(0, 3, (1, sc.dataset.n_items)).astype(np.int32),
                 rng.uniform(0.5, 0.9, 1).astype(np.float32),
                 rng.uniform(0.2, 0.8, (1, sc.dataset.n_items)).astype(np.float32))
    router = ReplicaRouter(sc.dataset, p, CFG, n_replicas=2, mode="bucketed",
                           tile=64, breaker_threshold=2,
                           breaker_cooldown_s=10.0)
    clock = faults.FakeClock()
    router.breakers[1]._clock = clock
    with faults.failing_writes(router.replicas[1]) as fault:
        # failure 1 (below threshold): classic abort — fleet rolled back
        with pytest.raises(ReplicaBroadcastError) as ei:
            router.commit(*c())
        assert ei.value.replica == 1
        assert isinstance(ei.value.__cause__, faults.InjectedFault)
        assert router.epoch == 0
        # failure 2 (threshold): replica ejected, fleet commits without it
        infos = router.commit(*c())
        assert infos[0] is not None and infos[1] is None
        assert router.epoch == 1 and router.replicas[1].epoch == 0
        st = router.stats
        assert st.breaker_trips == 1 and st.breaker_open == 1
        # while open (cooldown pending): writes buffer, reads route around
        router.retract([3])
        assert router.epoch == 2 and len(router._backlogs[1]) == 2
        fut = router.submit(_req(world, "read"))
        router.replicas[0].flush()
        assert fut.result(timeout=5).copying.shape[1] == \
            router.replicas[0].resident.n_corpus
        fault["left"] = 0                    # replica healed
    clock.advance(11.0)                      # cooldown elapses → probe
    router.commit(*c())                      # catch-up: 2 backlog ops + live
    assert router.replicas[1].epoch == router.replicas[0].epoch == 3
    assert router.stats.breaker_open == 0
    assert not router._backlogs[1]
    # two commits landed (the first aborted), one retraction: 48 + 2 - 1
    assert {svc.resident.n_corpus for svc in router.replicas} == \
        {sc.dataset.n_sources + 2 - 1}


def test_router_all_open_is_typed(world):
    sc, p, _ = world
    rng = np.random.default_rng(11)
    vals = rng.integers(0, 3, (1, sc.dataset.n_items)).astype(np.int32)
    acc = np.array([0.7], np.float32)
    pc = rng.uniform(0.2, 0.8, (1, sc.dataset.n_items)).astype(np.float32)
    router = ReplicaRouter(sc.dataset, p, CFG, n_replicas=1, mode="bucketed",
                           tile=64, breaker_threshold=1,
                           breaker_cooldown_s=1e9)
    with faults.failing_writes(router.replicas[0]):
        # threshold=1 trips instantly; the sole replica ejected means NO
        # replica applied — the write never happened, and the tentative
        # backlog copy is popped back out
        with pytest.raises(ReplicaBroadcastError):
            router.commit(vals, acc, pc)
    assert not router._backlogs[0]
    assert router.breakers[0].state == "open"
    # breaker open, nothing in sync: writes and reads both refuse, typed
    with pytest.raises(ReplicaBroadcastError, match="circuit breaker"):
        router.commit(vals, acc, pc)
    with pytest.raises(ServiceOverloaded, match="in-sync"):
        router.submit(_req(world, "r"))
    with pytest.raises(RuntimeError, match="no in-sync"):
        _ = router.epoch


# ---------------------------------------------------------------------------
# stop() vs blocked submitters and a mid-flight batch
# ---------------------------------------------------------------------------

def test_stop_race_no_stranded_futures(world):
    """stop() while submitters are blocked on backpressure and a batch is
    mid-flight: every submit either returns a future that resolves or
    raises a typed rejection — no deadlock, nothing stranded."""
    svc = _svc(world, max_batch_requests=2, max_pending_rows=9)
    futures, errors = [], []
    lock = threading.Lock()

    def submitter(k):
        for j in range(4):
            try:
                fut = svc.submit(_req(world, f"{k}-{j}"), timeout=5.0)
                with lock:
                    futures.append(fut)
            except (ServiceStopped, ServiceOverloaded) as exc:
                with lock:
                    errors.append(exc)

    with faults.slow_passes(0.05):
        svc.start()
        threads = [threading.Thread(target=submitter, args=(k,))
                   for k in range(6)]
        for t in threads:
            t.start()
        time.sleep(0.15)                     # mid-flight batch guaranteed
        svc.stop()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive(), "submitter deadlocked across stop()"
    svc.flush()          # drain submits that landed after the stop settled
    assert len(futures) + len(errors) == 24
    for fut in futures:
        assert fut.done(), "future stranded past stop()+flush()"
        assert fut.result(timeout=0).copying is not None
    assert all(isinstance(e, (ServiceStopped, ServiceOverloaded))
               for e in errors)
    # at least the mid-flight batch's requests actually resolved
    assert len(futures) > 0


def test_submit_after_stopping_flag_is_typed(world):
    svc = _svc(world)
    svc._stopping = True
    with pytest.raises(ServiceStopped, match="stopping"):
        svc.submit(_req(world, "late"))
    svc._stopping = False
