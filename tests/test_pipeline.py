"""Async double-buffered chunk staging + serve-path cache reuse (§11).

Covers the prefetcher's contract (ordering, sync fallback, typed error
propagation, no stranded threads/buffers) and the serving-layer property
the whole delta plumbing exists for: after a service ``commit()``, the next
detect reuses the incrementally-updated mask cache — ZERO full-chunk
block-OR regathers, counted by monkeypatching the one entry point
(``tilecache.chunk_block_inc``).
"""
import threading
import time

import faults
import numpy as np
import pytest

from repro.core import CopyConfig, DetectionEngine, build_index
from repro.core import tilecache
from repro.core.pipeline import ChunkPrefetcher, PipelineStageError
from repro.core.serving import DetectRequest, DetectionService
from repro.core.types import ClaimsDataset

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _world(seed=0, n_src=40, n_items=160):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.4,
                      rng.integers(0, 4, (n_src, n_items)),
                      -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.95,
                                            n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    return ds, p


def _reqs(ds, p, n=4, q=2, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        vals = np.where(rng.random((q, ds.n_items)) < 0.3,
                        rng.integers(0, 4, (q, ds.n_items)),
                        -1).astype(np.int32)
        acc = rng.uniform(0.3, 0.95, q).astype(np.float32)
        pq = np.where(vals == 0, 0.9,
                      np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
        out.append(DetectRequest(rid=i, values=vals, accuracy=acc,
                                 p_claim=pq))
    return out


# ---------------------------------------------------------------------------
# ChunkPrefetcher unit contract
# ---------------------------------------------------------------------------

def test_prefetcher_preserves_order_and_telemetry():
    """Items arrive in descriptor order at every depth; depth=0 runs inline
    (stage_wait == staging by construction), depth≥1 on a worker thread."""
    for depth in (0, 1, 3):
        staged = []

        def stage(d):
            staged.append((d, threading.current_thread()
                           is threading.main_thread()))
            return d * 10
        pf = ChunkPrefetcher(list(range(5)), stage, depth=depth)
        try:
            assert list(pf) == [0, 10, 20, 30, 40]
        finally:
            pf.close()
        assert [d for d, _ in staged] == [0, 1, 2, 3, 4]
        on_main = {m for _, m in staged}
        assert on_main == ({True} if depth == 0 else {False})
        assert pf.staging_s >= 0 and pf.stage_wait_s >= 0
        if depth == 0:
            assert pf.stage_wait_s == pf.staging_s


def test_prefetcher_raising_stage_is_a_typed_error():
    """An injected stage fault (tests/faults.py) surfaces as
    PipelineStageError with the cause preserved, the worker thread dies,
    and close() leaves nothing stranded."""
    n0 = threading.active_count()

    def stage(d):
        if d == 2:
            raise faults.InjectedFault("boom at 2")
        return d
    pf = ChunkPrefetcher(list(range(6)), stage, depth=2)
    got = []
    with pytest.raises(PipelineStageError, match="boom at 2") as ei:
        for item in pf:
            got.append(item)
    assert isinstance(ei.value.__cause__, faults.InjectedFault)
    pf.close()
    assert got == [0, 1]
    deadline = time.monotonic() + 5
    while threading.active_count() > n0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == n0


def test_prefetcher_slow_stage_keeps_order_and_counts_waits():
    """A slow stage thread never reorders items — the consumer just waits,
    and the wait shows up in stage_wait_s."""
    def stage(d):
        time.sleep(0.02)
        return d
    pf = ChunkPrefetcher(list(range(4)), stage, depth=1)
    try:
        assert list(pf) == [0, 1, 2, 3]
    finally:
        pf.close()
    assert pf.staging_s >= 0.08
    assert pf.stage_wait_s > 0


def test_engine_stage_fault_is_typed_and_engine_reusable():
    """A staging fault inside detect() raises PipelineStageError; the same
    engine object then serves the next detect normally (no stranded worker,
    no corrupted pipeline state)."""
    ds, p = _world(3)
    idx = build_index(ds, p, CFG)
    eng = DetectionEngine(CFG, mode="bucketed", tile=32, prefetch_depth=2)
    ref = eng.detect(ds, p, index=idx)
    n0 = threading.active_count()
    orig = DetectionEngine._stage_v

    def broken(self, v_np, dtype):
        raise faults.InjectedFault("injected staging fault")
    DetectionEngine._stage_v = broken
    try:
        with pytest.raises(PipelineStageError, match="injected staging"):
            eng.detect(ds, p, index=idx)
    finally:
        DetectionEngine._stage_v = orig
    deadline = time.monotonic() + 5
    while threading.active_count() > n0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == n0
    again = eng.detect(ds, p, index=idx)
    np.testing.assert_array_equal(again.copying, ref.copying)


def test_prefetch_depths_agree_on_decisions():
    """prefetch_depth 0 / 1 / 2 produce identical decisions and stats that
    account staging consistently."""
    ds, p = _world(5)
    idx = build_index(ds, p, CFG)
    ref = None
    for depth in (0, 1, 2):
        eng = DetectionEngine(CFG, mode="bucketed", tile=32,
                              prefetch_depth=depth)
        res = eng.detect(ds, p, index=idx)
        assert eng.last_stats["prefetch_depth"] == depth
        assert eng.last_stats["staging_s"] >= 0
        if ref is None:
            ref = res
        else:
            np.testing.assert_array_equal(res.copying, ref.copying)


# ---------------------------------------------------------------------------
# serving: commit→detect does ZERO full-chunk regathers
# ---------------------------------------------------------------------------

def _count_regathers(monkeypatch):
    calls = {"n": 0}
    real = tilecache.chunk_block_inc

    def counted(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)
    monkeypatch.setattr(tilecache, "chunk_block_inc", counted)
    return calls


def test_service_commit_then_detect_zero_regathers(monkeypatch):
    """After the first (cache-building) batch, every later batch — across a
    permanent commit AND the per-batch transient commit→rollback — detects
    off the incrementally-maintained cache: zero chunk_block_inc calls."""
    ds, p = _world(9)
    svc = DetectionService(ds, p, CFG, mode="bucketed", tile=32,
                           max_batch_requests=4, result_cache=False)
    reqs = _reqs(ds, p)

    def flush(rs):
        futs = [svc.submit(r) for r in rs]
        svc.flush()
        return [f.result() for f in futs]

    flush(reqs)                               # builds the cache
    builds0 = svc.engine.last_stats["mask_full_builds"]

    calls = _count_regathers(monkeypatch)
    before = flush(reqs[:2])
    assert calls["n"] == 0, f"steady-state batch regathered {calls['n']}"
    assert svc.engine.last_stats["mask_source"] == "cache"

    rng = np.random.default_rng(10)
    vals = np.where(rng.random((3, ds.n_items)) < 0.3,
                    rng.integers(0, 4, (3, ds.n_items)), -1).astype(np.int32)
    acc = np.full(3, 0.7, np.float32)
    pq = np.where(vals == 0, 0.9,
                  np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
    calls["n"] = 0
    svc.commit(vals, acc, pq)
    after = flush(reqs[:2])
    assert calls["n"] == 0, f"commit→detect regathered {calls['n']}"
    st = svc.engine.last_stats
    assert st["mask_source"] == "cache"
    assert st["mask_full_builds"] == builds0   # never rebuilt
    assert st["mask_blocks_updated"] > 0       # but incrementally updated
    # grown corpus ⇒ responses stay well-formed for the same requests
    assert all(a.copying.shape[0] == b.copying.shape[0]
               for a, b in zip(before, after))


def test_service_retract_keeps_cache_and_matches_rebuild(monkeypatch):
    """retract() keeps the delta chain alive (touched-block recompute, no
    full rebuild) and decisions equal a from-scratch service."""
    ds, p = _world(15)
    svc = DetectionService(ds, p, CFG, mode="bucketed", tile=32,
                           max_batch_requests=4, result_cache=False)
    reqs = _reqs(ds, p)

    def flush(s, rs):
        futs = [s.submit(r) for r in rs]
        s.flush()
        return [f.result() for f in futs]

    flush(svc, reqs)
    builds0 = svc.engine.last_stats["mask_full_builds"]
    calls = _count_regathers(monkeypatch)
    svc.retract(np.array([2, 7]))
    got = flush(svc, reqs)
    assert calls["n"] == 0
    assert svc.engine.last_stats["mask_full_builds"] == builds0
    cold = DetectionService(
        ClaimsDataset(values=svc.base.values, accuracy=svc.base.accuracy),
        svc.base_p.copy(), CFG, mode="bucketed", tile=32,
        max_batch_requests=4, result_cache=False)
    ref = flush(cold, reqs)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a.copying, b.copying)
