"""Decode correctness: token-by-token decode_step with caches must produce
the same logits as the teacher-forced full forward, for every block kind."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model

# representative arch per block-kind path
ARCHS = [
    "llama3.2-1b",            # dense GQA
    "qwen2.5-3b",             # dense + qkv bias
    "phi3.5-moe-42b-a6.6b",   # moe
    "falcon-mamba-7b",        # ssm
    "hymba-1.5b",             # hybrid (SWA + full segments)
    "musicgen-large",         # cross-attn every layer
    "llama-3.2-vision-11b",   # interleaved cross-attn
]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity dropping is position-dependent (forward routes the whole
        # sequence, decode routes one token) — remove drops for exact parity
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    cond = None
    if cfg.cond_len:
        cond = jnp.asarray(rng.normal(0, 1, (B, cfg.cond_len, cfg.cond_dim)),
                           jnp.float32)

    ref_logits = model.forward(params, tokens, cond=cond)      # (B,S,V)

    cache = model.init_cache(B, S, dtype=jnp.float32)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t], jnp.int32(t),
                             cond=cond)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_cache_rotates():
    """With a window smaller than the sequence, decode must still match the
    windowed forward (rotating cache + absolute-position masking)."""
    cfg = get_config("hymba-1.5b").reduced()
    cfg = cfg.replace(swa_window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    B, S = 1, 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    ref_logits = model.forward(params, tokens)

    cache = model.init_cache(B, S, dtype=jnp.float32)
    # SWA segments allocate only `window` slots
    for seg_cache, (kind, _) in zip(cache, cfg.plan):
        if kind == "hybrid_swa":
            assert seg_cache["kv"]["k"].shape[3] == 8
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t], jnp.int32(t))
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


def test_greedy_decode_runs():
    from repro.models.model import greedy_decode
    cfg = get_config("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out = greedy_decode(model, params, prompt, n_new=4)
    assert out.shape == (1, 8)
    np.testing.assert_array_equal(np.asarray(out[:, :4]), np.asarray(prompt))
