"""Continuous-batching serve loop: correctness vs sequential decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.runtime.serve_loop import Request, ServeLoop


@pytest.fixture(scope="module")
def served():
    cfg = get_config("llama3.2-1b").reduced(d_model=32, d_ff=64, vocab=64)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_reference(model, params, prompt, n_new, max_seq):
    """Single-request greedy decode via the scalar-pos path."""
    cache = model.init_cache(1, max_seq, dtype=jnp.float32)
    tok = jnp.asarray([prompt[0]], jnp.int32)
    out = []
    for t in range(len(prompt) + n_new - 1):
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(t))
        nxt = int(np.argmax(np.asarray(logits)[0]))
        if t + 1 < len(prompt):
            tok = jnp.asarray([prompt[t + 1]], jnp.int32)
        else:
            out.append(nxt)
            tok = jnp.asarray([nxt], jnp.int32)
    return out


def test_interleaved_requests_match_sequential(served):
    cfg, model, params = served
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, L).astype(np.int32)
               for L in (5, 9, 3, 7, 4, 6)]           # > n_slots, mixed lengths
    n_new = 6
    refs = [_sequential_reference(model, params, p, n_new, 64) for p in prompts]

    loop = ServeLoop(model, params, n_slots=3, max_seq=64)
    reqs = [Request(rid=i, prompt=p, max_new=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        loop.submit(r)
    loop.run()

    for r, ref in zip(reqs, refs):
        assert r.done
        assert r.output == ref, (r.rid, r.output, ref)
    # continuous batching: 6 requests through 3 slots in one loop instance
    assert loop.steps < sum(len(p) + n_new for p in prompts)


def test_slot_reuse_is_isolated(served):
    """A slot reused by a later request must not see the earlier request's
    KV entries (absolute-position masking + overwrite discipline)."""
    cfg, model, params = served
    rng = np.random.default_rng(1)
    long_p = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
    late_p = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)

    loop = ServeLoop(model, params, n_slots=2, max_seq=64)
    reqs = [Request(0, long_p, max_new=4), Request(1, short_p, max_new=2),
            Request(2, late_p, max_new=4)]            # reuses a slot mid-run
    for r in reqs:
        loop.submit(r)
    loop.run()

    ref = _sequential_reference(model, params, late_p, 4, 64)
    assert reqs[2].output == ref
