"""BOUND / BOUND+ / HYBRID (§IV) — early termination with the paper's bounds."""
import numpy as np
import pytest

from repro.core.bound import bound_detect, hybrid_detect
from repro.core.bucketed import index_detect_exact
from repro.core.scoring import pairwise_detect
from repro.core.types import CopyConfig, pair_f_measure
from repro.data.claims import (
    SyntheticSpec,
    motivating_example,
    motivating_value_probs,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


@pytest.fixture(scope="module")
def motivating():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    return ds, p, pairwise_detect(ds, p, CFG)


def test_bound_decisions_match_pairwise(motivating):
    ds, p, ref = motivating
    res = bound_detect(ds, p, CFG, n_buckets=13)
    np.testing.assert_array_equal(res.copying, ref.copying)


def test_bound_decides_s2_s3_early(motivating):
    # Ex. 4.2: (S2,S3) concluded copying after 2 shared values (bucket-level:
    # before the full scan ends)
    ds, p, _ = motivating
    _, state = bound_detect(ds, p, CFG, n_buckets=13, return_state=True)
    assert state.decided[2, 3] == 1
    assert state.dec_bucket[2, 3] < 13 - 1


def test_bound_examines_fewer_values_than_index(motivating):
    ds, p, _ = motivating
    exact = index_detect_exact(ds, p, CFG)
    res = bound_detect(ds, p, CFG, n_buckets=13)
    # Ex. 4.2: BOUND considers 33 < 51 shared values (bucket granularity may
    # differ slightly; assert strict improvement)
    assert res.counter.shared_values_examined < exact.counter.shared_values_examined


def test_bound_plus_fewer_bound_computations(motivating):
    ds, p, _ = motivating
    plain = bound_detect(ds, p, CFG, n_buckets=13, use_timers=False)
    plus = bound_detect(ds, p, CFG, n_buckets=13, use_timers=True)
    assert plus.counter.bound_computations <= plain.counter.bound_computations
    np.testing.assert_array_equal(plain.copying, plus.copying)


@pytest.mark.parametrize("coverage", ["book", "stock"])
@pytest.mark.parametrize("algo", ["bound", "bound+", "hybrid"])
def test_synthetic_quality_vs_pairwise(coverage, algo):
    spec = SyntheticSpec(n_sources=70, n_items=500, coverage=coverage,
                         n_cliques=5, clique_size=3, seed=11)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    ref = pairwise_detect(sc.dataset, p, CFG)
    if algo == "bound":
        res = bound_detect(sc.dataset, p, CFG)
    elif algo == "bound+":
        res = bound_detect(sc.dataset, p, CFG, use_timers=True)
    else:
        res = hybrid_detect(sc.dataset, p, CFG)
    prec, rec, f = pair_f_measure(res.copying_pairs(), ref.copying_pairs())
    # Table VI: HYBRID ≥ .985 F-measure vs PAIRWISE. Plain BOUND on long-tail
    # (book) data over-prunes via the h overlap estimate — the paper's own
    # motivation for HYBRID — so it gets a looser gate.
    min_f = 0.94 if algo in ("bound", "bound+") else 0.97
    assert f >= min_f, (prec, rec, f)


def test_chat_bookkeeping_consistency(motivating):
    """Ĉ = C⁰_dec + (l−n)·ln(1−s) must lie in [C^min, C→] (§V preparation)."""
    ds, p, ref = motivating
    _, state = bound_detect(ds, p, CFG, n_buckets=13, return_state=True)
    mask = state.considered & (state.decided == 0)
    # undecided pairs: Ĉ equals the true accumulated C→ (no estimation left)
    np.testing.assert_allclose(state.c_hat[mask], ref.c_fwd[mask], atol=0.05)
