"""ISSUE 5 acceptance: commit-then-detect decisions equal a full rebuild
from the union claim set — every engine mode, S ∈ {64, 512} × {1, 8}
devices — plus a hypothesis property over random commit schedules (sizes,
orders, compaction on/off).

Mirrors tests/test_store_modes.py: one subprocess with 8 virtual devices,
device counts exercised via the engine's ``devices`` option. Index-backed
modes detect with the COMMITTED index (base + delta chunks, Ē mask) against
a fresh ``build_index`` over the union; modes that index internally
(pairwise, sampled, sample_verify) run on the union claims both ways —
the committed corpus is the same claim set, so the whole nine-mode matrix
is pinned to the rebuild.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import CopyConfig, build_index, commit_rows, hybrid_detect
from repro.core.bucketed import index_detect_exact
from repro.core.types import ClaimsDataset

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine, build_index, commit_rows
    from repro.core.types import ClaimsDataset
    from repro.data.claims import (
        SyntheticSpec, oracle_claim_probs, synthetic_claims,
        synthetic_query_rows)

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    INDEXED = ("exact", "bound", "bound+", "hybrid", "bucketed", "incremental")

    def decisions(mode, union, union_p, idx, devices):
        eng = DetectionEngine(cfg, mode=mode, tile=64, devices=devices,
                              sample_rate=0.2, sample_seed=1)
        use_idx = idx if mode in INDEXED else None
        out = [eng.detect(union, union_p, index=use_idx).copying]
        if mode == "incremental":
            rng = np.random.default_rng(7)
            p2 = np.clip(union_p + np.where(union_p > 0,
                                            rng.normal(0, 0.004, union_p.shape),
                                            0), 1e-3, 0.999).astype(np.float32)
            out.append(eng.detect(union, p2).copying)
        return out

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        q1, q2 = 6, 6
        vals, acc, pq, _ = synthetic_query_rows(sc, q1 + q2, seed=3)
        u1 = ClaimsDataset(
            values=np.concatenate([sc.dataset.values, vals[:q1]]),
            accuracy=np.concatenate([sc.dataset.accuracy, acc[:q1]]))
        p1 = np.concatenate([p, pq[:q1]])
        union = ClaimsDataset(
            values=np.concatenate([u1.values, vals[q1:]]),
            accuracy=np.concatenate([u1.accuracy, acc[q1:]]))
        union_p = np.concatenate([p1, pq[q1:]])

        # two-step commit schedule, deltas left in place (no compaction)
        idx = build_index(sc.dataset, p, cfg,
                          row_capacity=sc.dataset.n_sources + q1 + q2)
        i1 = commit_rows(idx, u1, p1, cfg, q1, compact=False)
        i2 = commit_rows(idx, union, union_p, cfg, q2, compact=False)
        assert idx.store.n_delta_chunks > 0, "schedule must leave deltas"
        idx_rebuilt = build_index(union, union_p, cfg)

        for mode in ("pairwise", "exact", "bound", "bound+", "hybrid",
                     "incremental", "sampled", "sample_verify", "bucketed"):
            dev_counts = (1, 8) if mode in ("bucketed", "sampled",
                                            "sample_verify") else (1,)
            for n_dev in dev_counts:
                a = decisions(mode, union, union_p, idx, n_dev)
                b = decisions(mode, union, union_p, idx_rebuilt, n_dev)
                eq = all(np.array_equal(x, y) for x, y in zip(a, b))
                nz = int(sum(x.sum() for x in a))
                out[f"S{S}/{mode}/dev{n_dev}"] = {
                    "equal": bool(eq), "copying_bits": nz,
                    "new_entries": i1.new_entries + i2.new_entries}
    print("RESULT" + json.dumps(out))
""")


def test_all_modes_commit_equals_rebuild():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # 9 modes; 3 tiled modes get an extra dev8 entry → 12 combos per S
    assert len(out) == 24, sorted(out)
    for combo, r in out.items():
        assert r["equal"], f"{combo}: commit-then-detect diverged from rebuild"
        assert r["new_entries"] > 0, f"{combo}: schedule created no deltas"
    assert any(r["copying_bits"] > 0 for r in out.values())


# ---------------------------------------------------------------------------
# hypothesis: random commit schedules keep exact/hybrid pinned to rebuild
# ---------------------------------------------------------------------------

def _world(seed, n_src=22, n_items=70):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.45,
                      rng.integers(0, 4, (n_src, n_items)), -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.95, n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    return ds, p


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       sizes=st.lists(st.integers(0, 5), min_size=1, max_size=3),
       compact=st.booleans(),
       chunk=st.integers(8, 48))
def test_random_commit_schedules_track_rebuild(seed, sizes, compact, chunk):
    """After EVERY commit of a random schedule (random sizes — including
    q=0 — random row content, compaction on/off, random chunking) the
    committed index decides exactly like a rebuild from the union."""
    ds, p = _world(seed)
    rng = np.random.default_rng(seed + 1)
    idx = build_index(ds, p, CFG, chunk_entries=chunk,
                      row_capacity=ds.n_sources + sum(sizes))
    vals_u, acc_u, p_u = ds.values, ds.accuracy, p
    for step, q in enumerate(sizes):
        vals = np.where(rng.random((q, ds.n_items)) < 0.3,
                        rng.integers(0, 4, (q, ds.n_items)), -1).astype(np.int32)
        acc = rng.uniform(0.3, 0.95, q).astype(np.float32)
        pq = np.where(vals == 0, 0.9,
                      np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
        vals_u = np.concatenate([vals_u, vals])
        acc_u = np.concatenate([acc_u, acc])
        p_u = np.concatenate([p_u, pq])
        union = ClaimsDataset(values=vals_u, accuracy=acc_u)
        commit_rows(idx, union, p_u, CFG, q, compact=compact,
                    compact_threshold=0.2)
        fresh = build_index(union, p_u, CFG)
        a = index_detect_exact(union, p_u, CFG, index=idx)
        b = index_detect_exact(union, p_u, CFG, index=fresh)
        np.testing.assert_array_equal(a.copying, b.copying,
                                      err_msg=f"exact diverged at step {step}")
        ha = hybrid_detect(union, p_u, CFG, index=idx)
        hb = hybrid_detect(union, p_u, CFG, index=fresh)
        np.testing.assert_array_equal(ha.copying, hb.copying,
                                      err_msg=f"hybrid diverged at step {step}")
