"""Property tests (hypothesis) on the row-range-sharded corpus data plane.

Random row-range plans — uneven, empty, and single-row shards included —
must be invisible to every consumer: gather / co-occurrence / slice /
column reads off the ``ShardedCorpusStore`` facade are bit-exact against
the dense ``CorpusStore``, partial-grid merging matches the single-host
reduction (sum for counts, MAX for the p̂-error channel), and a
spill → reload → gather roundtrip is bit-exact under random eviction
orders. Runs under the deterministic fallback shim when hypothesis is not
installed (tests/conftest.py).
"""
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CorpusStore,
    ShardPlan,
    make_shard_plan,
    merge_shard_partials,
    rebalance_plan,
    shard_store,
)
from repro.core.engine import DetectionEngine
from repro.core.index import build_index
from repro.core.types import ClaimsDataset, CopyConfig

CE = 16  # chunk width (multiple of 8) — small, so stores are multi-chunk


def _random_store(rng, n_rows, n_entries):
    """A CorpusStore with random sparse incidence + random metadata."""
    dense = (rng.random((n_rows, n_entries)) < 0.3).astype(np.int8)
    chunks = [np.ascontiguousarray(dense[:, i: i + CE])
              for i in range(0, n_entries, CE)]
    return dense, CorpusStore(
        chunks=chunks,
        entry_item=rng.integers(0, 40, n_entries).astype(np.int32),
        entry_value=rng.integers(0, 5, n_entries).astype(np.int32),
        entry_p=rng.random(n_entries).astype(np.float32),
        entry_score=rng.random(n_entries).astype(np.float32),
        chunk_entries=CE, n_rows=n_rows, capacity=n_rows)


def _random_plan(rng, n_rows, n_shards):
    """Row-range plan with random cuts: uneven, empty, single-row shards."""
    cuts = np.sort(rng.integers(0, n_rows + 1, n_shards - 1))
    return ShardPlan(bounds=np.concatenate(([0], cuts, [n_rows])))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_rows=st.integers(1, 96),
       n_shards=st.integers(1, 6))
def test_random_plan_reads_bit_exact(seed, n_rows, n_shards):
    rng = np.random.default_rng(seed)
    n_entries = int(rng.integers(1, 4)) * CE - int(rng.integers(0, 8))
    dense, store = _random_store(rng, n_rows, n_entries)
    sh = shard_store(store, _random_plan(rng, n_rows, n_shards))

    assert np.array_equal(sh.to_dense(), dense)
    e = int(rng.integers(0, n_entries))
    assert np.array_equal(sh.column(e), dense[:, e])
    assert np.array_equal(sh.providers(e), np.nonzero(dense[:, e])[0])
    e0 = int(rng.integers(0, n_entries))
    e1 = int(rng.integers(e0, n_entries)) + 1
    assert np.array_equal(sh.slice_entries(e0, e1),
                          store.slice_entries(e0, e1))
    assert np.array_equal(sh.cooccurrence(), store.cooccurrence())
    mask = rng.random(n_entries) < 0.5
    assert np.array_equal(sh.cooccurrence(mask=mask),
                          store.cooccurrence(mask=mask))
    # gather (with -1 inert padding markers) preserves the plan + the bits
    order = rng.integers(-1, n_entries, int(rng.integers(1, 2 * CE)))
    g_sh, g_ref = sh.gather_entries(order), store.gather_entries(order)
    assert np.array_equal(g_sh.to_dense(), g_ref.to_dense())
    assert np.array_equal(g_sh.entry_item, g_ref.entry_item)
    assert g_sh.n_shards == sh.n_shards


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_shards=st.integers(1, 5),
       s_pad=st.integers(1, 24))
def test_merge_partials_matches_single_host(seed, n_shards, s_pad):
    rng = np.random.default_rng(seed)
    # integer-valued count grids (sums exact in any order) + float err grid
    partials = [tuple(
        [rng.integers(0, 99, (s_pad, s_pad)).astype(np.float32)
         for _ in range(3)]
        + [rng.random((s_pad, s_pad)).astype(np.float32)])
        for _ in range(n_shards)]
    c_same, count, outside, err = merge_shard_partials(partials)
    stacked = [np.stack([p[k] for p in partials]) for k in range(4)]
    assert np.array_equal(c_same, stacked[0].sum(axis=0))
    assert np.array_equal(count, stacked[1].sum(axis=0))
    assert np.array_equal(outside, stacked[2].sum(axis=0))
    # the p̂-error channel merges by MAX: a bound must stay a bound
    assert np.array_equal(err, stacked[3].max(axis=0))
    empty = merge_shard_partials([], shape=(s_pad, s_pad))
    assert all(np.array_equal(g, np.zeros((s_pad, s_pad), np.float32))
               for g in empty)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_rows=st.integers(1, 80),
       n_shards=st.integers(1, 5), pack=st.booleans())
def test_spill_reload_gather_roundtrip(seed, n_rows, n_shards, pack):
    rng = np.random.default_rng(seed)
    n_entries = 3 * CE - int(rng.integers(0, 8))
    dense, store = _random_store(rng, n_rows, n_entries)
    sh = shard_store(store, _random_plan(rng, n_rows, n_shards))
    with tempfile.TemporaryDirectory() as spill:
        sh.seal(pack=pack, spill_dir=spill)
        # evict every (shard, chunk) block in a random order, twice —
        # reloads must heal and re-evictions must stay bit-stable
        cells = [(s, c) for s in range(sh.n_shards)
                 for c in range(sh.n_chunks)]
        for _ in range(2):
            for i in rng.permutation(len(cells)):
                sh.evict_block(*cells[i])
            assert np.array_equal(sh.to_dense(), dense)
        order = rng.integers(-1, n_entries, 2 * CE)
        got = sh.gather_entries(order).to_dense()
    ref = store.gather_entries(order).to_dense()
    assert np.array_equal(got, ref)


@settings(max_examples=25, deadline=None)
@given(n_rows=st.integers(0, 500), n_shards=st.integers(1, 9))
def test_make_shard_plan_partitions_rows(n_rows, n_shards):
    plan = make_shard_plan(n_rows, n_shards)
    assert plan.n_shards == n_shards
    assert plan.n_rows == n_rows
    assert sum(plan.sizes()) == n_rows
    assert max(plan.sizes(), default=0) - min(plan.sizes(), default=0) <= 1
    for r in range(n_rows):
        s = plan.owner_of_row(r)
        r0, r1 = plan.range_of(s)
        assert r0 <= r < r1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_rows=st.integers(2, 300),
       n_shards=st.integers(2, 6))
def test_rebalance_plan_restores_balance(seed, n_rows, n_shards):
    rng = np.random.default_rng(seed)
    plan = _random_plan(rng, n_rows, n_shards)
    out = rebalance_plan(plan, n_rows)
    assert out.n_rows == n_rows and out.n_shards == n_shards
    assert sum(out.sizes()) == n_rows
    # either the skew was within tolerance (plan kept) or it was re-split
    # from scratch into a balanced plan (sizes differ by at most one)
    sizes = out.sizes()
    assert out.imbalance() <= 1.25 or sizes.max() - sizes.min() <= 1
    balanced = make_shard_plan(n_rows, n_shards)
    assert np.array_equal(rebalance_plan(balanced, n_rows).bounds,
                          balanced.bounds)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), n_shards=st.integers(2, 5),
       skew=st.booleans())
def test_degenerate_owner_placements_bit_equal_decisions(seed, n_shards,
                                                         skew):
    """ISSUE 10 satellite: owner fan-out/merge under empty shards,
    single-row ranges, and ~1.25×-skew plans is bit-equal to single-host
    decisions — and a missing owner refuses the merge instead of
    silently merging a partial fleet."""
    rng = np.random.default_rng(seed)
    S, D, V = int(rng.integers(16, 49)), 24, 4
    vals = rng.integers(0, V, (S, D)).astype(np.int32)
    vals[rng.random((S, D)) < 0.3] = -1
    vals[S // 2] = vals[1]                  # one certain copier pair
    ds = ClaimsDataset(
        values=vals, accuracy=rng.uniform(0.4, 0.9, S).astype(np.float32))
    p = rng.uniform(0.3, 0.9, (S, D)).astype(np.float32)
    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)

    idx_ref = build_index(ds, p, cfg)
    ref = DetectionEngine(cfg, mode="bucketed", tile=16).detect(
        ds, p, index=idx_ref)

    if skew:
        # ~1.25×-skew placement: one fat owner, the rest balanced
        big = min(S - 1, max(1, int(round(1.25 * S / n_shards))))
        rest = make_shard_plan(S - big, n_shards - 1)
        plan = ShardPlan(bounds=np.concatenate(([0], big + rest.bounds)))
    else:
        # random cuts: uneven, EMPTY, and single-row owner ranges
        plan = _random_plan(rng, S, n_shards)

    idx = build_index(ds, p, cfg)
    idx.store = shard_store(idx.store, plan)
    eng = DetectionEngine(cfg, mode="bucketed", tile=16)
    ctx = eng.owner_scan_context(ds, p, index=idx)
    partials = [eng.detect_owner_partial(ds, p, s, ctx=ctx)
                for s in range(plan.n_shards)]
    # the merge is owner-keyed: arrival order must not matter
    partials = [partials[i] for i in rng.permutation(len(partials))]
    res = eng.finalize_owner_partials(ds, p, ctx, partials)
    assert np.array_equal(res.copying, ref.copying)
    assert np.array_equal(res.c_fwd, ref.c_fwd)
    assert np.array_equal(res.pr_independent, ref.pr_independent)
    # a fleet missing one owner must refuse, never partial-merge
    with pytest.raises(ValueError):
        eng.finalize_owner_partials(ds, p, ctx, partials[:-1])
