"""ISSUE 6 acceptance: a durable ``DetectionService`` killed mid-log-write
and restored serves IDENTICAL decisions to the never-restarted service —
every engine mode, S ∈ {64, 512}, tiled modes at 1 and 8 devices.

Mirrors tests/test_mutation_modes.py: one subprocess with 8 virtual
devices. Per corpus size the script runs commit/serve waves against a
durable service, appends torn-tail garbage to its commit log (the on-disk
image a SIGKILL mid-append leaves), restores, then pins every mode's
decisions over the restored corpus + committed index to the live service's
— plus the served probe responses and the corpus epochs themselves.
"""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import shutil
    import tempfile
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine, DurabilityOptions
    from repro.core.serving import DetectRequest, DetectionService
    from repro.core.types import ClaimsDataset
    from repro.data.claims import (
        SyntheticSpec, oracle_claim_probs, synthetic_claims,
        synthetic_query_rows)

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }
    INDEXED = ("exact", "bound", "bound+", "hybrid", "bucketed", "incremental")

    def decisions(mode, svc, devices):
        # detect over THIS service's live state: its resident corpus claims
        # and (for index-backed modes) its committed index
        n = svc.resident.n_corpus
        union = ClaimsDataset(values=svc.resident.values[:n].copy(),
                              accuracy=svc.resident.accuracy[:n].copy())
        union_p = svc.resident.p_claim[:n].copy()
        eng = DetectionEngine(cfg, mode=mode, tile=64, devices=devices,
                              sample_rate=0.2, sample_seed=1)
        idx = svc._index if mode in INDEXED else None
        return eng.detect(union, union_p, index=idx).copying

    def serve(svc, rid, vals, acc, pq):
        fut = svc.submit(DetectRequest(rid=rid, values=vals, accuracy=acc,
                                       p_claim=pq))
        svc.flush()
        return fut.result()

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        vals, acc, pq, _ = synthetic_query_rows(sc, 18, seed=3)
        state_dir = tempfile.mkdtemp(prefix=f"dur{S}-")
        try:
            live = DetectionService(
                sc.dataset, p, cfg, mode="bucketed", tile=64,
                durability=DurabilityOptions(state_dir=state_dir,
                                             snapshot_every=2))
            # commit/serve wave mix: two commits straddling a snapshot
            # (snapshot_every=2 -> snapshot at epoch 2), probes between
            live.commit(vals[:6], acc[:6], pq[:6])
            serve(live, 0, vals[12:], acc[12:], pq[12:])
            live.commit(vals[6:12], acc[6:12], pq[6:12])
            probe_live = serve(live, 1, vals[12:], acc[12:], pq[12:])

            # SIGKILL-equivalent drop mid-log-write: the next record's bytes
            # stop partway through — model the torn on-disk image directly
            with open(os.path.join(state_dir, "commits.wal"), "ab") as f:
                f.write(b"\\x13torn tail: not a valid record frame")

            restored = DetectionService.restore(state_dir)
            ri = restored.restore_info
            probe_rest = serve(restored, 2, vals[12:], acc[12:], pq[12:])

            for mode in ("pairwise", "exact", "bound", "bound+", "hybrid",
                         "incremental", "sampled", "sample_verify",
                         "bucketed"):
                dev_counts = (1, 8) if mode in ("bucketed", "sampled",
                                                "sample_verify") else (1,)
                for n_dev in dev_counts:
                    a = decisions(mode, live, n_dev)
                    b = decisions(mode, restored, n_dev)
                    out[f"S{S}/{mode}/dev{n_dev}"] = {
                        "equal": bool(np.array_equal(a, b)),
                        "copying_bits": int(a.sum())}
            out[f"S{S}/service"] = {
                "epoch_equal": restored.epoch == live.epoch,
                "epoch": int(live.epoch),
                "commits_equal":
                    restored.stats.commits == live.stats.commits,
                "rows_equal": restored.stats.committed_rows
                    == live.stats.committed_rows,
                "corpus_equal": bool(
                    restored.resident.n_corpus == live.resident.n_corpus
                    and np.array_equal(
                        restored.resident.values[:live.resident.n_corpus],
                        live.resident.values[:live.resident.n_corpus])),
                "index_equal": bool(np.array_equal(
                    restored._index.store.to_dense(),
                    live._index.store.to_dense())),
                "probe_equal": bool(
                    np.array_equal(probe_rest.copying, probe_live.copying)
                    and np.array_equal(probe_rest.intra_copying,
                                       probe_live.intra_copying)
                    and np.allclose(probe_rest.pr_independent,
                                    probe_live.pr_independent)),
                "torn_bytes": int(ri.discarded_bytes),
                "replayed": int(ri.replayed_commits),
                "snapshot_epoch": int(ri.snapshot_epoch)}
        finally:
            shutil.rmtree(state_dir, ignore_errors=True)
    print("RESULT" + json.dumps(out))
""")


def test_all_modes_survive_kill_restart():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # 9 modes; 3 tiled modes get an extra dev8 entry → 12 combos per S,
    # plus one service-level entry per S
    assert len(out) == 26, sorted(out)
    for combo, r in out.items():
        if combo.endswith("/service"):
            assert r["epoch_equal"] and r["epoch"] == 2, combo
            assert r["commits_equal"] and r["rows_equal"], combo
            assert r["corpus_equal"] and r["index_equal"], combo
            assert r["probe_equal"], f"{combo}: served decisions diverged"
            assert r["torn_bytes"] > 0, f"{combo}: torn tail not discarded"
            # snapshot at epoch 2 → nothing left to replay
            assert r["snapshot_epoch"] == 2 and r["replayed"] == 0, combo
        else:
            assert r["equal"], f"{combo}: restored decisions diverged"
    assert any(r.get("copying_bits", 0) > 0 for r in out.values())
