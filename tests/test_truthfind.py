"""Iterative fusion on the motivating example — Table II behaviour."""
import numpy as np
import pytest

from repro.core.truthfind import build_value_groups, fusion_accuracy, truth_finding
from repro.core.types import CopyConfig
from repro.data.claims import (
    GROUND_TRUTH_COPIES,
    SyntheticSpec,
    motivating_example,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0, c=0.8)


@pytest.fixture(scope="module")
def fused():
    ds = motivating_example()
    return ds, truth_finding(ds, CFG, detector="pairwise", max_rounds=8,
                             track_history=True)


def entry_prob(ds, res, item, vname):
    inv = {v: k for k, v in ds.value_names.items()}
    d, vid = inv[f"{ds.item_names.index(item) and ''}{item}.{vname}"] if False else inv[f"{item}.{vname}"]
    groups = res.groups
    # find the entry for (d, vid) via a provider
    for e in range(len(res.p_entry)):
        if groups.entry_item[e] != d:
            continue
        provs = np.nonzero(groups.V_all[:, e])[0]
        if provs.size and ds.values[provs[0], d] == vid:
            return float(res.p_entry[e])
    raise KeyError((item, vname))


def test_converges_quickly(fused):
    ds, res = fused
    # the paper's example converges in 5 rounds; allow a little slack
    assert res.rounds <= 8


def test_albany_flip(fused):
    """The signature event (Table II-b): naive voting initially prefers
    NY.NewYork (3 copier votes); copy detection flips truth to NY.Albany."""
    ds, res = fused
    assert entry_prob(ds, res, "NY", "Albany") > 0.6
    assert entry_prob(ds, res, "NY", "NewYork") < 0.3


def test_converged_value_probabilities(fused):
    ds, res = fused
    assert entry_prob(ds, res, "NJ", "Trenton") > 0.85
    assert entry_prob(ds, res, "NJ", "Atlantic") < 0.15
    assert entry_prob(ds, res, "TX", "Austin") > 0.85
    assert entry_prob(ds, res, "AZ", "Phoenix") > 0.85


def test_converged_accuracies_match_table_ii(fused):
    ds, res = fused
    acc = res.accuracy
    # Table II-a round 5: S0=.99 S1=.99 S2=.2 S3=.2 S4=.4
    assert acc[0] > 0.9 and acc[1] > 0.9
    assert acc[2] < 0.4 and acc[3] < 0.4
    assert 0.2 < acc[4] < 0.65
    # accurate independents end much higher than the copier clique
    assert acc[0] - acc[2] > 0.4


def test_copying_detected_after_convergence(fused):
    ds, res = fused
    assert GROUND_TRUTH_COPIES <= res.detection.copying_pairs()


def test_value_groups_structure():
    ds = motivating_example()
    g = build_value_groups(ds)
    # 13 shared + 3 singleton values = 16 distinct claims
    assert g.V_all.shape[1] == 16
    # every provided claim maps to an entry
    assert (g.claim_entry[ds.values >= 0] >= 0).all()
    assert (g.claim_entry[ds.values < 0] == -1).all()


def test_fusion_beats_naive_voting_on_synthetic():
    """Copy-aware fusion should recover truth better than copy-blind fusion
    when copier cliques outvote honest sources."""
    spec = SyntheticSpec(n_sources=40, n_items=300, coverage="stock",
                        n_cliques=6, clique_size=4, acc_low=0.25,
                        acc_high=0.9, seed=5)
    sc = synthetic_claims(spec)

    res_copy = truth_finding(sc.dataset, CFG, detector="index", max_rounds=6)
    acc_with = fusion_accuracy(res_copy, sc.dataset, sc.true_values)

    blind = CopyConfig(alpha=1e-9, s=CFG.s, n=CFG.n, c=0.0)  # discount disabled
    res_blind = truth_finding(sc.dataset, blind, detector="index", max_rounds=6)
    acc_without = fusion_accuracy(res_blind, sc.dataset, sc.true_values)

    assert acc_with >= acc_without
    assert acc_with > 0.8
