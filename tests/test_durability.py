"""ISSUE 6: durability layer — commit log, snapshots, kill/restart restore,
rollback, and the ReplicaRouter broadcast-recovery regression.

The kill/restart contract under test (DESIGN.md §8): a durable
``DetectionService`` dropped at ANY point — between commits, mid-log-write
(torn tail), mid-snapshot-write — restores to a service whose decisions,
epochs, and committed state are bit-equal to a twin that never died. Torn
tails are modelled by truncating/corrupting the on-disk files directly
(a SIGKILL can only ever produce a prefix of the bytes the service wrote,
plus possibly garbage in the torn record — both are covered).
"""
import os

import numpy as np
import pytest

from repro.core import (
    CommitLog,
    CommitRecord,
    CopyConfig,
    DetectionService,
    DurabilityOptions,
    NoValidSnapshotError,
    ReplicaBroadcastError,
    ReplicaRouter,
    build_index,
)
from repro.core.index import InvertedIndex
from repro.core.serving import DetectRequest
from repro.core.store import CorpusStore
from repro.core.types import ClaimsDataset, claim_value_keys
from repro.core.wal import (
    WalError,
    latest_valid_snapshot,
    list_snapshots,
    load_snapshot,
    write_snapshot,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _world(seed=0, n_src=40, n_items=160):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.45,
                      rng.integers(0, 4, (n_src, n_items)), -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.95, n_src).astype(np.float32))
    p = np.where(values == 0, 0.9,
                 np.where(values >= 0, 0.05, 0.0)).astype(np.float32)
    return ds, p


def _rows(seed, q, n_items=160):
    rng = np.random.default_rng(seed)
    vals = np.where(rng.random((q, n_items)) < 0.3,
                    rng.integers(0, 4, (q, n_items)), -1).astype(np.int32)
    acc = rng.uniform(0.3, 0.95, q).astype(np.float32)
    pq = np.where(vals == 0, 0.9,
                  np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
    return vals, acc, pq


def _request(seed, q=3, n_items=160, rid=0):
    vals, acc, pq = _rows(seed, q, n_items)
    return DetectRequest(rid=rid, values=vals, accuracy=acc, p_claim=pq)


def _svc(ds, p, tmp_path=None, **kw):
    dur = None
    if tmp_path is not None:
        dur = DurabilityOptions(state_dir=str(tmp_path),
                                **kw.pop("dur_kw", {}))
    return DetectionService(ds, p, CFG, mode="bucketed", tile=64,
                            durability=dur, **kw)


def _serve(svc, req):
    fut = svc.submit(req)
    svc.flush()
    return fut.result()


# ---------------------------------------------------------------------------
# commit log units
# ---------------------------------------------------------------------------

def _record(seed, epoch, q=3):
    vals, acc, pq = _rows(seed, q)
    return CommitRecord(epoch=epoch, values=vals, accuracy=acc, p_claim=pq,
                        touched_keys=claim_value_keys(vals),
                        compact=bool(epoch % 2), compacted=False)


def test_log_roundtrip(tmp_path):
    path = str(tmp_path / "commits.wal")
    log = CommitLog(path)
    recs = [_record(s, e) for s, e in ((1, 1), (2, 2), (3, 3))]
    for r in recs:
        log.append(r)
    log.close()
    back = list(CommitLog.read(path))
    assert [r.epoch for r in back] == [1, 2, 3]
    for a, b in zip(recs, back):
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(a.accuracy, b.accuracy)
        np.testing.assert_array_equal(a.p_claim, b.p_claim)
        np.testing.assert_array_equal(a.touched_keys, b.touched_keys)
        assert a.compact == b.compact and a.compacted == b.compacted


@pytest.mark.parametrize("damage", ["truncate_header", "truncate_payload",
                                    "garbage", "crc_flip"])
def test_log_torn_tail_recovery(tmp_path, damage):
    """Any mid-write drop of the LAST record truncates back to the valid
    prefix; the earlier records survive untouched."""
    path = str(tmp_path / "commits.wal")
    log = CommitLog(path)
    for s, e in ((1, 1), (2, 2)):
        log.append(_record(s, e))
    clean = os.path.getsize(path)
    log.append(_record(3, 3))
    log.close()
    full = os.path.getsize(path)
    with open(path, "rb+") as f:
        if damage == "truncate_header":
            f.truncate(clean + 7)            # mid third-record header
        elif damage == "truncate_payload":
            f.truncate(full - 5)             # payload cut short
        elif damage == "garbage":
            f.truncate(clean)
            f.seek(clean)
            f.write(b"\x00garbage that is not a record header")
        elif damage == "crc_flip":
            f.seek(clean + 20)               # inside the third payload
            byte = f.read(1)
            f.seek(clean + 20)
            f.write(bytes([byte[0] ^ 0xFF]))
    info = CommitLog.recover(path)
    assert info.records == 2
    assert info.discarded_bytes > 0
    assert os.path.getsize(path) == clean
    assert [r.epoch for r in CommitLog.read(path)] == [1, 2]
    # idempotent on the now-clean log
    again = CommitLog.recover(path)
    assert again.discarded_bytes == 0 and again.records == 2


def test_log_rollback_last(tmp_path):
    path = str(tmp_path / "commits.wal")
    log = CommitLog(path)
    log.append(_record(1, 1))
    size1 = os.path.getsize(path)
    log.append(_record(2, 2))
    log.rollback_last()
    assert os.path.getsize(path) == size1
    assert [r.epoch for r in CommitLog.read(path)] == [1]
    with pytest.raises(WalError):
        log.rollback_last()                  # only the LAST append unwinds
    log.append(_record(3, 2))                # appending again still works
    assert [r.epoch for r in CommitLog.read(path)] == [1, 2]
    log.close()


# ---------------------------------------------------------------------------
# snapshot container
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_and_retention(tmp_path):
    sd = str(tmp_path)
    arrays = {"a": np.arange(12, dtype=np.int64).reshape(3, 4),
              "b": np.float32([1.5, -2.0])}
    for epoch in (1, 2, 3):
        write_snapshot(sd, epoch, arrays, retention=2)
    assert [e for e, _ in list_snapshots(sd)] == [2, 3]   # retention pruned
    epoch, path, back, skipped = latest_valid_snapshot(sd)
    assert epoch == 3 and skipped == 0
    np.testing.assert_array_equal(back["a"], arrays["a"])
    np.testing.assert_array_equal(back["b"], arrays["b"])


def test_snapshot_corruption_falls_back(tmp_path):
    sd = str(tmp_path)
    write_snapshot(sd, 1, {"a": np.arange(4)})
    p2 = write_snapshot(sd, 2, {"a": np.arange(8)})
    with open(p2, "rb+") as f:
        f.truncate(os.path.getsize(p2) - 3)  # torn mid-snapshot-write
    with pytest.raises(WalError):
        load_snapshot(p2)
    epoch, _, back, skipped = latest_valid_snapshot(sd)
    assert epoch == 1 and skipped == 1
    assert len(back["a"]) == 4
    os.remove(p2)
    os.remove(list_snapshots(sd)[0][1])
    with pytest.raises(NoValidSnapshotError):
        latest_valid_snapshot(sd)


# ---------------------------------------------------------------------------
# store / index state_dict
# ---------------------------------------------------------------------------

def test_store_index_state_roundtrip():
    """A committed index (deltas + Ē mask) survives (de)serialization
    bit-exact, including after further commits on the restored copy."""
    from repro.core import commit_rows
    ds, p = _world(3)
    idx = build_index(ds, p, CFG, chunk_entries=48,
                      row_capacity=ds.n_sources + 8)
    vals, acc, pq = _rows(11, 4)
    union = ClaimsDataset(values=np.concatenate([ds.values, vals]),
                          accuracy=np.concatenate([ds.accuracy, acc]))
    union_p = np.concatenate([p, pq])
    commit_rows(idx, union, union_p, CFG, 4, compact=False)
    assert idx.store.n_delta_chunks > 0 and idx.ebar_mask is not None

    back = InvertedIndex.from_state_dict(idx.state_dict(),
                                         row_capacity=union.n_sources + 8)
    np.testing.assert_array_equal(back.store.to_dense(), idx.store.to_dense())
    np.testing.assert_array_equal(back.store.entry_item, idx.store.entry_item)
    np.testing.assert_array_equal(back.store.entry_score, idx.store.entry_score)
    np.testing.assert_array_equal(back.l_counts, idx.l_counts)
    np.testing.assert_array_equal(back.items_per_source, idx.items_per_source)
    np.testing.assert_array_equal(back.ebar_mask, idx.ebar_mask)
    assert back.store.chunk_entries == idx.store.chunk_entries
    assert back.store.delta_start == idx.store.delta_start
    assert back.store.n_rows == idx.store.n_rows

    # both copies take the SAME next commit to the same state
    vals2, acc2, pq2 = _rows(12, 3)
    union2 = ClaimsDataset(values=np.concatenate([union.values, vals2]),
                           accuracy=np.concatenate([union.accuracy, acc2]))
    union2_p = np.concatenate([union_p, pq2])
    i1 = commit_rows(idx, union2, union2_p, CFG, 3, compact=False)
    i2 = commit_rows(back, union2, union2_p, CFG, 3, compact=False)
    assert i1.new_entries == i2.new_entries
    np.testing.assert_array_equal(back.store.to_dense(), idx.store.to_dense())
    np.testing.assert_array_equal(back.nonebar_mask, idx.nonebar_mask)


def test_store_state_version_gate():
    ds, p = _world(1)
    store = build_index(ds, p, CFG).store
    d = store.state_dict()
    d = dict(d)
    meta = d["store/meta"].copy()
    meta[0] = 99                              # a future layout version
    d["store/meta"] = meta
    with pytest.raises(ValueError, match="newer"):
        CorpusStore.from_state_dict(d)


# ---------------------------------------------------------------------------
# kill/restart: restored service == never-restarted twin
# ---------------------------------------------------------------------------

def _twins(tmp_path, seed=0, **dur_kw):
    """A durable service and its in-memory twin over the same corpus."""
    ds, p = _world(seed)
    durable = _svc(ds, p, tmp_path, dur_kw=dur_kw)
    twin = _svc(ds, p)
    return durable, twin


def _lockstep(durable, twin, schedule):
    """Apply the same commit/serve schedule to both services."""
    out = []
    for kind, seed in schedule:
        if kind == "commit":
            durable.commit(*_rows(seed, 3))
            twin.commit(*_rows(seed, 3))
        else:
            out.append((_serve(durable, _request(seed)),
                        _serve(twin, _request(seed))))
    return out


SCHEDULE = [("commit", 1), ("serve", 21), ("commit", 2), ("serve", 22),
            ("serve", 21), ("commit", 3), ("serve", 23)]


def test_restore_equals_never_restarted(tmp_path):
    durable, twin = _twins(tmp_path, snapshot_every=2)
    for a, b in _lockstep(durable, twin, SCHEDULE):
        np.testing.assert_array_equal(a.copying, b.copying)
    # "kill": drop the object, restore from disk only
    del durable
    restored = DetectionService.restore(str(tmp_path))
    assert restored.epoch == twin.epoch
    assert restored.stats.commits == twin.stats.commits
    assert restored.stats.committed_rows == twin.stats.committed_rows
    assert restored.resident.n_corpus == twin.resident.n_corpus
    np.testing.assert_array_equal(restored._index.store.to_dense(),
                                  twin._index.store.to_dense())
    for seed in (21, 22, 23, 31):
        a = _serve(restored, _request(seed))
        b = _serve(twin, _request(seed))
        np.testing.assert_array_equal(a.copying, b.copying)
        np.testing.assert_array_equal(a.pr_independent, b.pr_independent)
        np.testing.assert_array_equal(a.intra_copying, b.intra_copying)
    # both continue with further commits in lockstep
    restored.commit(*_rows(4, 2))
    twin.commit(*_rows(4, 2))
    assert restored.epoch == twin.epoch
    a = _serve(restored, _request(40))
    b = _serve(twin, _request(40))
    np.testing.assert_array_equal(a.copying, b.copying)


def _rows_in_items(seed, q, lo, hi, n_items=160):
    """Rows whose claims live only on items [lo, hi) — disjoint item ranges
    have disjoint claim keys, so such commits can't invalidate each other's
    cache entries."""
    vals, acc, pq = _rows(seed, q, n_items)
    vals = vals.copy()
    pq = pq.copy()
    vals[:, :lo] = -1
    vals[:, hi:] = -1
    pq[vals < 0] = 0.0
    return vals, acc, pq


def test_restore_serves_warm_cache(tmp_path):
    """A request served before the snapshot is a cache HIT after restore
    when no replayed commit touches its claims."""
    ds, p = _world(5)
    svc = _svc(ds, p, tmp_path, dur_kw={"snapshot_every": 1})
    cold = _rows_in_items(50, 3, 0, 80)       # claims the commit won't touch
    hot = _rows_in_items(51, 2, 120, 160)     # claims the commit WILL touch
    first = _serve(svc, DetectRequest(rid=0, values=cold[0],
                                      accuracy=cold[1], p_claim=cold[2]))
    assert not first.cache_hit
    _serve(svc, DetectRequest(rid=1, values=hot[0],
                              accuracy=hot[1], p_claim=hot[2]))
    svc.commit(*_rows_in_items(6, 2, 120, 160))
    del svc
    restored = DetectionService.restore(str(tmp_path))
    again = _serve(restored, DetectRequest(rid=2, values=cold[0],
                                           accuracy=cold[1], p_claim=cold[2]))
    assert again.cache_hit                    # untouched claims stay warm
    s0 = first.copying.shape[1]
    np.testing.assert_array_equal(first.copying, again.copying[:, :s0])
    assert not again.copying[:, s0:].any()    # padded cols: no shared keys
    miss = _serve(restored, DetectRequest(rid=3, values=hot[0],
                                          accuracy=hot[1], p_claim=hot[2]))
    assert not miss.cache_hit                 # the commit invalidated these


def test_restore_replays_log_tail(tmp_path):
    """Commits after the last snapshot come back via log replay alone."""
    durable, twin = _twins(tmp_path, seed=2, snapshot_every=0)
    for seed in (1, 2, 3):
        durable.commit(*_rows(seed, 3))
        twin.commit(*_rows(seed, 3))
    del durable
    restored = DetectionService.restore(str(tmp_path))
    assert restored.restore_info.snapshot_epoch == 0
    assert restored.restore_info.replayed_commits == 3
    assert restored.epoch == twin.epoch == 3
    np.testing.assert_array_equal(restored._index.store.to_dense(),
                                  twin._index.store.to_dense())
    a = _serve(restored, _request(60))
    b = _serve(twin, _request(60))
    np.testing.assert_array_equal(a.copying, b.copying)


def test_restore_discards_torn_tail(tmp_path):
    """A SIGKILL mid-log-write loses exactly the torn commit: restore equals
    a twin that never applied it."""
    ds, p = _world(7)
    durable = _svc(ds, p, tmp_path, dur_kw={"snapshot_every": 0})
    twin = _svc(ds, p)
    durable.commit(*_rows(1, 3))
    twin.commit(*_rows(1, 3))
    durable.commit(*_rows(2, 3))             # this commit's record gets torn
    log = str(tmp_path / "commits.wal")
    with open(log, "rb+") as f:
        f.truncate(os.path.getsize(log) - 9)
    restored = DetectionService.restore(str(tmp_path))
    assert restored.restore_info.discarded_bytes > 0
    assert restored.epoch == twin.epoch == 1
    np.testing.assert_array_equal(restored._index.store.to_dense(),
                                  twin._index.store.to_dense())
    a = _serve(restored, _request(61))
    b = _serve(twin, _request(61))
    np.testing.assert_array_equal(a.copying, b.copying)


def test_restore_skips_corrupt_newest_snapshot(tmp_path):
    """Bit-rot in the newest snapshot falls back to the previous one and
    replays the longer log tail to the same state."""
    durable, twin = _twins(tmp_path, seed=4, snapshot_every=1, retention=4)
    for seed in (1, 2, 3):
        durable.commit(*_rows(seed, 3))
        twin.commit(*_rows(seed, 3))
    del durable
    snaps = list_snapshots(str(tmp_path))
    assert [e for e, _ in snaps] == [0, 1, 2, 3]
    with open(snaps[-1][1], "rb+") as f:      # corrupt the epoch-3 snapshot
        f.seek(30)
        f.write(b"\xde\xad\xbe\xef")
    restored = DetectionService.restore(str(tmp_path))
    assert restored.restore_info.skipped_snapshots == 1
    assert restored.restore_info.snapshot_epoch == 2
    assert restored.restore_info.replayed_commits == 1
    assert restored.epoch == twin.epoch == 3
    np.testing.assert_array_equal(restored._index.store.to_dense(),
                                  twin._index.store.to_dense())


def test_restore_nonindexed_mode(tmp_path):
    """Durability works for modes without a committed index (no index in
    the snapshot; replay recommits rows only)."""
    ds, p = _world(6)
    dur = DurabilityOptions(state_dir=str(tmp_path), snapshot_every=2)
    svc = DetectionService(ds, p, CFG, mode="sample_verify", tile=64,
                           sample_rate=0.3, sample_seed=1, durability=dur)
    twin = DetectionService(ds, p, CFG, mode="sample_verify", tile=64,
                            sample_rate=0.3, sample_seed=1)
    for seed in (1, 2, 3):
        svc.commit(*_rows(seed, 3))
        twin.commit(*_rows(seed, 3))
    del svc
    restored = DetectionService.restore(str(tmp_path))
    assert restored.epoch == twin.epoch == 3
    assert restored._index is None
    a = _serve(restored, _request(70))
    b = _serve(twin, _request(70))
    np.testing.assert_array_equal(a.copying, b.copying)


# ---------------------------------------------------------------------------
# rollback_last_commit + router broadcast recovery
# ---------------------------------------------------------------------------

def test_rollback_last_commit_bit_exact(tmp_path):
    ds, p = _world(8)
    svc = _svc(ds, p, tmp_path, dur_kw={"snapshot_every": 0})
    ref = _svc(ds, p)
    svc.commit(*_rows(1, 3))
    ref.commit(*_rows(1, 3))
    log = str(tmp_path / "commits.wal")
    size1 = os.path.getsize(log)
    _serve(svc, _request(80))                 # memoized at epoch 1
    svc.commit(*_rows(2, 4))
    svc.rollback_last_commit()
    assert svc.epoch == ref.epoch == 1
    assert svc.resident.n_corpus == ref.resident.n_corpus
    assert svc.stats.commits == ref.stats.commits == 1
    np.testing.assert_array_equal(svc._index.store.to_dense(),
                                  ref._index.store.to_dense())
    np.testing.assert_array_equal(svc._index.l_counts, ref._index.l_counts)
    assert os.path.getsize(log) == size1      # the record is gone too
    with pytest.raises(RuntimeError):
        svc.rollback_last_commit()            # LIFO: only once
    a = _serve(svc, _request(81))
    b = _serve(ref, _request(81))
    np.testing.assert_array_equal(a.copying, b.copying)
    # and a restore of the rolled-back state dir agrees
    restored = DetectionService.restore(str(tmp_path))
    assert restored.epoch == 1


def test_router_broadcast_failure_rolls_back(tmp_path):
    """Regression (ISSUE 6 satellite): one replica raising mid-broadcast
    must not leave the fleet split-brained."""
    ds, p = _world(9)
    router = ReplicaRouter(ds, p, CFG, n_replicas=3, mode="bucketed",
                           tile=64)
    ref = _svc(ds, p)
    router.commit(*_rows(1, 3))
    ref.commit(*_rows(1, 3))

    calls = {"n": 0}
    orig = DetectionService.commit

    def failing(self, *a, **kw):
        calls["n"] += 1
        if self is router.replicas[2]:
            raise RuntimeError("replica 2 lost its disk")
        return orig(self, *a, **kw)

    router.replicas[2].commit = failing.__get__(router.replicas[2])
    router.replicas[0].commit = failing.__get__(router.replicas[0])
    router.replicas[1].commit = failing.__get__(router.replicas[1])
    with pytest.raises(ReplicaBroadcastError) as ei:
        router.commit(*_rows(2, 4))
    assert ei.value.replica == 2
    assert calls["n"] == 3                     # replicas 0, 1 applied first
    assert router.epoch == ref.epoch == 1      # rolled back, consistent
    for svc in router.replicas:
        assert svc.resident.n_corpus == ref.resident.n_corpus
        np.testing.assert_array_equal(svc._index.store.to_dense(),
                                      ref._index.store.to_dense())
    # the fleet keeps working after recovery
    for svc in router.replicas:
        svc.commit = orig.__get__(svc)
    router.commit(*_rows(3, 2))
    ref.commit(*_rows(3, 2))
    assert router.epoch == ref.epoch == 2
    a = _serve(router.replicas[2], _request(90))
    b = _serve(ref, _request(90))
    np.testing.assert_array_equal(a.copying, b.copying)


def test_router_per_replica_state_dirs(tmp_path):
    ds, p = _world(10)
    dur = DurabilityOptions(state_dir=str(tmp_path), snapshot_every=1)
    router = ReplicaRouter(ds, p, CFG, n_replicas=2, mode="bucketed",
                           tile=64, durability=dur)
    router.commit(*_rows(1, 3))
    for i in range(2):
        sub = tmp_path / f"replica-{i}"
        assert (sub / "manifest.json").exists()
        assert (sub / "commits.wal").exists()
        restored = DetectionService.restore(str(sub))
        assert restored.epoch == 1
