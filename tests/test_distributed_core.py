"""Distributed pair-space scorer (shard_map) == single-device bucketed scorer.

Runs in a subprocess with XLA_FLAGS host-device-count so the main test
process keeps its single-device view (see dryrun.py note in the prompt).
"""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.bucketed import pad_buckets, _bucketed_accumulate
    from repro.core.distributed import distributed_pair_scores
    from repro.core.index import build_index, bucketize
    from repro.core.types import CopyConfig
    from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    sc = synthetic_claims(SyntheticSpec(n_sources=64, n_items=400,
                                        coverage="stock", n_cliques=4, seed=0))
    p = oracle_claim_probs(sc)
    idx = build_index(sc.dataset, p, cfg)
    padded = pad_buckets(bucketize(idx, 16), dtype=jnp.float32)
    acc = jnp.asarray(sc.dataset.accuracy)

    # single-device reference
    c_ref, n_ref, _ = _bucketed_accumulate(
        padded.v_ksw, padded.p_hat, acc, cfg.s, cfg.n, padded.ebar_bucket)

    results = {}
    for axes, shape in ((("data", "model"), (4, 2)),
                        (("pod", "data", "model"), (2, 2, 2))):
        mesh = jax.make_mesh(shape, axes)
        run = distributed_pair_scores(mesh, np.asarray(padded.v_ksw),
                                      np.asarray(padded.p_hat),
                                      np.asarray(acc), cfg)
        c, n = run()
        results["x".join(map(str, shape))] = [
            float(jnp.abs(c - c_ref).max()), float(jnp.abs(n - n_ref).max())]
    print("RESULT" + json.dumps(results))
""")


def test_distributed_matches_single_device():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    results = json.loads(line[len("RESULT"):])
    assert set(results) == {"4x2", "2x2x2"}
    for shape, (dc, dn) in results.items():
        assert dc < 1e-3, (shape, dc)
        assert dn < 1e-3, (shape, dn)
