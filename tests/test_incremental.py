"""INCREMENTAL detection (§V) — decision fidelity + pass-1 settlement."""
import numpy as np

from repro.core.bound import hybrid_detect
from repro.core.incremental import incremental_detect, make_incremental_state
from repro.core.scoring import pairwise_detect
from repro.core.truthfind import truth_finding
from repro.core.types import ClaimsDataset, CopyConfig, pair_f_measure
from repro.data.claims import (
    SyntheticSpec,
    motivating_example,
    motivating_value_probs,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _perturb(p_claim, rng, scale):
    noise = rng.normal(0.0, scale, size=p_claim.shape).astype(np.float32)
    return np.clip(p_claim + np.where(p_claim > 0, noise, 0.0), 1e-3, 0.999)


def test_small_change_round_settles_in_pass1():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    _, state = make_incremental_state(ds, p, CFG, n_buckets=13)
    rng = np.random.default_rng(0)
    p2 = _perturb(p, rng, 0.005)
    res = incremental_detect(ds, p2, CFG, state)
    ref = pairwise_detect(ds, p2, CFG)
    _, _, f = pair_f_measure(res.copying_pairs(), ref.copying_pairs())
    assert f == 1.0
    # Table VIII: ≥98% of pairs terminate at pass 1 on small-change rounds
    assert state.pass1_settled >= 0.9


def test_big_change_flips_decision():
    """Ex. 5.1's flip, reconstructed: a pair decided *copying* because it
    shares 3 low-probability values flips to *no-copying* when those values
    turn out to be likely-true (P .02 → .97), as with NY.Albany in Table IV."""
    # sources 0,1 (acc .6): same values on items 0-2, different on items 3-4.
    # sources 2.. provide co-votes so every value has ≥2 providers.
    values = -np.ones((6, 5), dtype=np.int32)
    values[0] = [0, 0, 0, 1, 1]
    values[1] = [0, 0, 0, 2, 2]
    values[2] = [0, 1, 1, 1, 2]          # co-provider of the shared values
    values[3] = [1, 0, 0, 2, 1]
    values[4] = [1, 1, 1, 1, 1]
    values[5] = [0, 1, 0, 2, 2]
    acc = np.array([0.6, 0.6, 0.5, 0.5, 0.5, 0.5], dtype=np.float32)
    ds = ClaimsDataset(values=values, accuracy=acc)

    p_old = np.full(values.shape, 0.3, dtype=np.float32)
    p_old[values == 0] = 0.02            # the shared values look false
    _, state = make_incremental_state(ds, p_old, CFG, n_buckets=8)
    assert state.copying[0, 1], "precondition: pair decided copying"

    p_new = p_old.copy()
    p_new[values == 0] = 0.97            # they turn out overwhelmingly true
    res = incremental_detect(ds, p_new, CFG, state)
    ref = pairwise_detect(ds, p_new, CFG)
    np.testing.assert_array_equal(res.copying, ref.copying & state.considered)
    assert not res.copying[0, 1], "decision must flip to no-copying"


def test_incremental_sequence_tracks_exact():
    spec = SyntheticSpec(n_sources=60, n_items=400, coverage="stock",
                         n_cliques=5, clique_size=3, seed=2)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    _, state = make_incremental_state(sc.dataset, p, CFG)
    rng = np.random.default_rng(1)
    pk = p
    for rnd in range(3):
        pk = _perturb(pk, rng, 0.01)
        res = incremental_detect(sc.dataset, pk, CFG, state)
        ref = pairwise_detect(sc.dataset, pk, CFG)
        _, _, f = pair_f_measure(res.copying_pairs(), ref.copying_pairs())
        assert f >= 0.95, (rnd, f)


def test_incremental_in_fusion_loop_matches_hybrid():
    spec = SyntheticSpec(n_sources=50, n_items=300, coverage="stock",
                         n_cliques=4, clique_size=3, seed=9)
    sc = synthetic_claims(spec)
    res_inc = truth_finding(sc.dataset, CFG, detector="incremental", max_rounds=6)
    res_hyb = truth_finding(sc.dataset, CFG, detector="hybrid", max_rounds=6)
    _, _, f = pair_f_measure(res_inc.detection.copying_pairs(),
                             res_hyb.detection.copying_pairs())
    assert f >= 0.95
    # accuracy estimates agree closely (paper: accuracy variance ≤ .04)
    assert np.abs(res_inc.accuracy - res_hyb.accuracy).mean() < 0.05


def test_incremental_cheaper_than_hybrid():
    """Table VIII: incremental rounds cost a small fraction of HYBRID."""
    spec = SyntheticSpec(n_sources=80, n_items=800, coverage="stock",
                         n_cliques=5, clique_size=3, seed=4)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    hyb = hybrid_detect(sc.dataset, p, CFG)
    _, state = make_incremental_state(sc.dataset, p, CFG)
    rng = np.random.default_rng(3)
    p2 = _perturb(p, rng, 0.005)
    inc = incremental_detect(sc.dataset, p2, CFG, state)
    assert inc.counter.total < 0.5 * hyb.counter.total
