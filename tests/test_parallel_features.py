"""Pipeline parallelism + gradient compression + elastic restore — run on
8 virtual host devices in a subprocess (main process stays single-device)."""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:
        from jax import shard_map
        _sm_nocheck = {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map
        _sm_nocheck = {"check_rep": False}

    results = {}

    # ---------------- pipeline parallelism -------------------------------
    from repro.runtime.pipeline_parallel import pipeline_apply
    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)), jnp.float32)

    def stage_fn(w_s, h):
        return jnp.tanh(h @ w_s)

    out = pipeline_apply(stage_fn, w, x, mesh, "stage")

    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ w[s])
    results["pipeline_err"] = float(jnp.abs(out - ref).max())

    # ---------------- int8 error-feedback compression --------------------
    from repro.optim.compression import compress_allreduce, init_error_state
    mesh2 = jax.make_mesh((8,), ("data",))
    g = jnp.asarray(rng.normal(0, 1, (8, 64)), jnp.float32)

    def local(gs, err):
        s, e = compress_allreduce(gs, err, "data")
        return s, e
    fn = jax.jit(shard_map(local, mesh=mesh2,
                           in_specs=(P("data"), P("data")),
                           out_specs=(P(None), P("data")),
                           **_sm_nocheck))
    summed, err = fn(g, jnp.zeros_like(g))
    exact = g.sum(axis=0)
    rel = float(jnp.abs(summed[0] - exact).max() / jnp.abs(exact).max())
    results["compress_rel_err"] = rel
    # error feedback: the quantization residual is retained per shard
    results["err_nonzero"] = bool(jnp.abs(err).max() > 0)

    # compressed sum + error feedback converges over repeated steps
    acc_err = jnp.zeros_like(g)
    tot_c = jnp.zeros_like(exact)
    tot_x = jnp.zeros_like(exact)
    for i in range(20):
        gi = jnp.asarray(rng.normal(0, 1, (8, 64)), jnp.float32)
        s_i, acc_err = fn(gi, acc_err)
        tot_c = tot_c + s_i[0]
        tot_x = tot_x + gi.sum(axis=0)
    results["compress_drift"] = float(jnp.abs(tot_c - tot_x).max())

    # ---------------- elastic restore (4 → 8 way) ------------------------
    import tempfile
    from repro.checkpoint import save_checkpoint, load_checkpoint
    tmp = tempfile.mkdtemp()
    mesh4 = jax.make_mesh((4,), ("data",))
    arr = jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32)
    sharded4 = jax.device_put(arr, NamedSharding(mesh4, P("data")))
    save_checkpoint(tmp, 1, {"w": sharded4})
    mesh8 = jax.make_mesh((8,), ("data",))
    restored, _ = load_checkpoint(
        tmp, {"w": arr}, shardings={"w": NamedSharding(mesh8, P("data"))})
    results["elastic_err"] = float(jnp.abs(restored["w"] - arr).max())
    results["elastic_nshards"] = len(restored["w"].sharding.device_set)

    print("RESULT" + json.dumps(results))
""")


def test_parallel_features():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    r = json.loads(line[len("RESULT"):])
    assert r["pipeline_err"] < 1e-5, r
    assert r["compress_rel_err"] < 0.05, r
    assert r["err_nonzero"], r
    # error feedback keeps long-run drift far below naive per-step error
    assert r["compress_drift"] < 0.5, r
    assert r["elastic_err"] == 0.0, r
    assert r["elastic_nshards"] == 8, r
