"""Validate the inverted index (§III) against Table III of the paper."""
import numpy as np
import pytest

from repro.core.index import bucketize, build_index, entry_contribution_score
from repro.core.types import CopyConfig
from repro.data.claims import motivating_example, motivating_value_probs

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)

# Table III: value → (probability, contribution score, #providers)
TABLE_III = {
    "AZ.Tempe": (0.02, 4.59, 2),
    "NJ.Atlantic": (0.01, 4.12, 3),
    "TX.Houston": (0.02, 4.05, 2),
    "NY.NewYork": (0.02, 4.05, 3),
    "TX.Dallas": (0.02, 3.98, 3),
    "NY.Buffalo": (0.04, 3.97, 3),
    "FL.PalmBay": (0.05, 3.97, 3),
    "FL.Miami": (0.03, 3.83, 2),
    "AZ.Phoenix": (0.95, 1.62, 5),
    "NJ.Trenton": (0.97, 1.51, 5),
    "FL.Orlando": (0.92, 0.84, 4),
    "NY.Albany": (0.94, 0.43, 3),
    "TX.Austin": (0.96, 0.43, 4),
}


@pytest.fixture(scope="module")
def index():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    return ds, build_index(ds, p, CFG)


def test_index_has_exactly_the_13_shared_values(index):
    ds, idx = index
    assert idx.n_entries == 13
    names = {ds.value_names[(int(i), int(v))]
             for i, v in zip(idx.entry_item, idx.entry_value)}
    assert names == set(TABLE_III)
    # singletons NJ.Union, AZ.Tucson, TX.Arlington are not indexed
    assert "NJ.Union" not in names


def test_table_iii_scores_and_order(index):
    ds, idx = index
    for e in range(idx.n_entries):
        name = ds.value_names[(int(idx.entry_item[e]), int(idx.entry_value[e]))]
        p_ref, score_ref, nprov = TABLE_III[name]
        assert idx.entry_p[e] == pytest.approx(p_ref, abs=1e-6), name
        # Table III prints probabilities rounded to 2 decimals but computed
        # scores from unrounded ones (e.g. AZ.Phoenix: P≈.945 → 1.62, while
        # P=.95 → 1.60), so allow ±0.025.
        assert idx.entry_score[e] == pytest.approx(score_ref, abs=0.025), name
        assert idx.V[:, e].sum() == nprov, name
    # sorted by decreasing contribution score
    assert np.all(np.diff(idx.entry_score) <= 1e-6)


def test_ebar_is_the_last_two_entries(index):
    # Ex. 3.6: ".43 + .43 < ln(.8/.2) = 1.39" ⇒ Ē = {NY.Albany, TX.Austin}
    ds, idx = index
    assert idx.n_entries - idx.ebar_start == 2
    tail = {ds.value_names[(int(idx.entry_item[e]), int(idx.entry_value[e]))]
            for e in range(idx.ebar_start, idx.n_entries)}
    assert tail == {"NY.Albany", "TX.Austin"}


def test_no_provider_overlap_within_item(index):
    # Def 3.2 guarantee: a source appears in at most one entry per item
    ds, idx = index
    for d in range(ds.n_items):
        cols = idx.V[:, idx.entry_item == d]
        assert cols.sum(axis=1).max() <= 1


def test_shared_item_counts(index):
    ds, idx = index
    # S0 provides 4 items, S1 provides 5, they share 4
    assert idx.l_counts[0, 1] == 4
    assert idx.l_counts[0, 0] == 4
    # Σ_{i<j} l = 181 shared items over 45 pairs (paper's prose says 183;
    # recounting Table I gives 181 — see note in test_scoring.py)
    iu = np.triu_indices(ds.n_sources, k=1)
    assert int(idx.l_counts[iu].sum()) == 181


def test_prop_3_1_agrees_with_bruteforce(index):
    """Prop 3.1 picks the maximizing pair — verify vs brute force over pairs."""
    ds, idx = index
    from repro.core.scoring import score_same_np
    for e in range(idx.n_entries):
        provs = idx.providers(e)
        accs = ds.accuracy[provs]
        best = -np.inf
        for i in range(len(provs)):
            for j in range(len(provs)):
                if i == j:
                    continue
                best = max(best, score_same_np(idx.entry_p[e], accs[i], accs[j],
                                               CFG.s, CFG.n))
        got = entry_contribution_score(idx.entry_p[e], accs, CFG)
        assert got == pytest.approx(best, abs=1e-6)


def test_bucketize_structure(index):
    ds, idx = index
    b = bucketize(idx, n_buckets=4)
    assert b.starts[0] == 0 and b.starts[-1] == idx.n_entries
    # Ē boundary is a bucket boundary
    assert idx.ebar_start in b.starts
    # m_suffix is the exact suffix max of entry scores
    for k in range(b.n_buckets):
        assert b.m_suffix[k] == pytest.approx(idx.entry_score[b.starts[k]:].max())
