"""Shared test setup.

If the real `hypothesis` package is missing (the bare container has no dev
deps installed), register the deterministic fallback in its place before any
test module imports it — collection must never fail on an optional dep.
"""
import importlib.util
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _path = pathlib.Path(__file__).parent / "_hypothesis_fallback.py"
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
