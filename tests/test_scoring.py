"""Validate Eqs. (2)-(8) against the paper's motivating example (Ex. 2.1)."""
import numpy as np
import pytest

from repro.core.scoring import (
    pairwise_detect,
    posterior_independence,
    score_same_np,
)
from repro.core.types import CopyConfig
from repro.data.claims import (
    GROUND_TRUTH_COPIES,
    motivating_example,
    motivating_value_probs,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def test_example_2_1_single_item_contribution():
    # "Suppose that NJ.Atlantic has probability .01 ... C→(D1) = 3.89"
    c = score_same_np(0.01, 0.2, 0.2, CFG.s, CFG.n)
    assert abs(c - 3.89) < 0.01


def test_example_2_1_pair_s2_s3():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    res = pairwise_detect(ds, p, CFG)
    # "eventually C→ = C← = 3.89 + 1.6 + 3.86 + 3.83 − 1.6 = 11.58"
    assert abs(res.c_fwd[2, 3] - 11.58) < 0.05
    assert abs(res.c_fwd[3, 2] - 11.58) < 0.05
    # "Pr(S2 ⊥ S3 | Φ) = .00004, so copying is very likely"
    assert res.pr_independent[2, 3] == pytest.approx(4e-5, rel=0.5)
    assert res.copying[2, 3]


def test_example_2_1_pair_s0_s1():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    res = pairwise_detect(ds, p, CFG)
    # "C→ = C← = .01*4 = .04 and Pr(S0 ⊥ S1|Φ) = .79, so copying is unlikely"
    assert abs(res.c_fwd[0, 1] - 0.04) < 0.02
    assert res.pr_independent[0, 1] == pytest.approx(0.79, abs=0.02)
    assert not res.copying[0, 1]


def test_pairwise_finds_planted_copies():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    res = pairwise_detect(ds, p, CFG)
    detected = res.copying_pairs()
    # the paper: copying within S2–S4 and within S6–S8
    assert GROUND_TRUTH_COPIES <= detected
    # independent high-accuracy sources are not flagged
    assert (0, 1) not in detected
    assert (0, 9) not in detected


def test_pairwise_computation_accounting():
    # Ex. 3.6: "pairwise detection requires examining 45 pairs of sources and
    # 183 shared data items, so in total conducting 183*2 = 366 computations".
    # NOTE: recounting Table I per item (NJ:C(9,2)=36, AZ:C(8,2)=28, NY:36,
    # FL:36, TX:45) gives Σ=181, not 183 — the paper's prose is off by 2.
    ds = motivating_example()
    p = motivating_value_probs(ds)
    res = pairwise_detect(ds, p, CFG)
    assert res.counter.pairs_considered == 45
    assert res.counter.shared_values_examined == 181
    assert res.counter.score_computations == 362


def test_posterior_is_symmetric_and_stable():
    c = np.array([[0.0, 500.0], [500.0, 0.0]], dtype=np.float32)  # huge scores
    pr = np.asarray(posterior_independence(c, c.T, CFG))
    assert np.all(np.isfinite(pr))
    assert pr[0, 1] == pytest.approx(pr[1, 0])
    assert pr[0, 1] < 1e-6
