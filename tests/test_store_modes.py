"""ISSUE 4 acceptance: engine decisions are identical store-vs-dense —
chunked CorpusStore (narrow chunks) against a single-chunk (dense) store —
for every engine mode, at S ∈ {64, 512} × {1, 8} devices.

Runs in a subprocess with 8 virtual devices (as the other sharded tests);
device counts are exercised via the engine's ``devices`` option inside one
process. Modes that never touch the mesh (pairwise, exact, bound family,
incremental) are compared once; the tiled modes run under both mesh sizes.
"""
import json
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine, build_index
    from repro.data.claims import SyntheticSpec, oracle_claim_probs, synthetic_claims

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    CHUNKED, DENSE = 24, 1 << 22
    specs = {
        64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                          n_cliques=4, clique_size=3, clique_items=12, seed=0),
        512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                           n_cliques=14, clique_size=3, clique_items=12, seed=0),
    }

    def decisions(mode, sc, p, chunk, devices):
        eng = DetectionEngine(cfg, mode=mode, tile=64, devices=devices,
                              sample_rate=0.2, sample_seed=1,
                              store_chunk_entries=chunk)
        if mode in ("exact", "bound", "bound+", "hybrid", "bucketed"):
            idx = build_index(sc.dataset, p, cfg, chunk_entries=chunk)
            if mode == "bucketed" and chunk == CHUNKED:
                assert idx.store.n_chunks > 1, "chunked run must be multi-chunk"
            out = [eng.detect(sc.dataset, p, index=idx).copying]
        elif mode == "incremental":
            out = [eng.detect(sc.dataset, p).copying]
            rng = np.random.default_rng(7)
            p2 = np.clip(p + np.where(p > 0, rng.normal(0, 0.004, p.shape), 0),
                         1e-3, 0.999).astype(np.float32)
            out.append(eng.detect(sc.dataset, p2).copying)
        else:
            out = [eng.detect(sc.dataset, p).copying]
        return out

    out = {}
    for S, spec in specs.items():
        sc = synthetic_claims(spec)
        p = oracle_claim_probs(sc)
        for mode in ("pairwise", "exact", "bound", "bound+", "hybrid",
                     "incremental", "sampled", "sample_verify", "bucketed"):
            dev_counts = (1, 8) if mode in ("bucketed", "sampled",
                                            "sample_verify") else (1,)
            for n_dev in dev_counts:
                a = decisions(mode, sc, p, CHUNKED, n_dev)
                b = decisions(mode, sc, p, DENSE, n_dev)
                eq = all(np.array_equal(x, y) for x, y in zip(a, b))
                nz = int(sum(x.sum() for x in a))
                out[f"S{S}/{mode}/dev{n_dev}"] = {"equal": bool(eq),
                                                  "copying_bits": nz}
    print("RESULT" + json.dumps(out))
""")


def test_all_modes_store_vs_dense_identical():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # 9 modes; 3 tiled modes get an extra dev8 entry → 12 combos per S
    assert len(out) == 24, sorted(out)
    for combo, r in out.items():
        assert r["equal"], f"{combo}: store-vs-dense decisions diverged"
    # the worlds actually contain copying to disagree about
    assert any(r["copying_bits"] > 0 for r in out.values())
