"""Live corpus mutation (DESIGN.md §7): delta-chunk commits, bit-exact
rollback, the invalidation-aware result cache, and the replica router.

The load-bearing properties: (a) any interleaving of row staging and
commits can be unwound bit-exactly — a mid-batch failure never corrupts the
committed index; (b) cached pair results are served ONLY when provably
unaffected by every delta since their epoch; (c) replicas that apply the
same commit sequence stay epoch-consistent and decision-identical.
"""
import numpy as np
import pytest

from repro.core import (
    CopyConfig,
    DetectionEngine,
    build_index,
    claim_value_keys,
    commit_rows,
    compact_index,
    rollback_commit,
)
from repro.core.bucketed import index_detect_exact
from repro.core.serving import (
    DetectRequest,
    DetectionService,
    ReplicaRouter,
    serve_batch,
)
from repro.core.types import ClaimsDataset
from repro.data.claims import (
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
    synthetic_query_rows,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


def _world(seed=0, n_src=40, n_items=160):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.4,
                      rng.integers(0, 4, (n_src, n_items)), -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.95, n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    return ds, p


def _rows(seed, q, n_items, n_vals=4):
    rng = np.random.default_rng(seed)
    vals = np.where(rng.random((q, n_items)) < 0.3,
                    rng.integers(0, n_vals, (q, n_items)), -1).astype(np.int32)
    acc = rng.uniform(0.3, 0.95, q).astype(np.float32)
    p = np.where(vals == 0, 0.9, np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
    return vals, acc, p


def _union(ds, p, vals, acc, pq):
    return (ClaimsDataset(values=np.concatenate([ds.values, vals]),
                          accuracy=np.concatenate([ds.accuracy, acc])),
            np.concatenate([p, pq]))


# ---------------------------------------------------------------------------
# interleaved append/truncate + commit/rollback restore bit-exact state
# ---------------------------------------------------------------------------

def test_interleaved_append_truncate_bit_exact():
    """Random interleavings of append_rows / truncate_rows land back on the
    exact corpus-only membership, including q=0 and full-slack appends."""
    ds, p = _world(3)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=52)
    store = idx.store
    ref = store.to_dense().copy()
    S0 = store.n_rows
    rng = np.random.default_rng(1)
    for step in range(30):
        slack = store.capacity - store.n_rows
        if slack == 0 or (store.n_rows > S0 and rng.random() < 0.5):
            store.truncate_rows(
                int(rng.integers(S0, store.n_rows + 1)))
        else:
            q = int(rng.integers(0, slack + 1))       # q = 0 included
            vals = _rows(100 + step, q, ds.n_items)[0]
            store.append_rows(vals)
    # the all-rows-slack edge: fill the slack completely, then unwind
    store.truncate_rows(S0)
    full = store.capacity - S0
    store.append_rows(_rows(999, full, ds.n_items)[0])
    assert store.n_rows == store.capacity
    with pytest.raises(ValueError, match="capacity"):
        store.append_rows(_rows(1000, 1, ds.n_items)[0])
    store.truncate_rows(S0)
    np.testing.assert_array_equal(store.to_dense(), ref)


def test_commit_rollback_restores_everything():
    """rollback_commit after a commit (with delta entries, touched scores,
    l_counts growth) restores the index bit-exact — the mid-batch failure
    contract."""
    ds, p = _world(5)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=52)
    before = {
        "dense": idx.store.to_dense().copy(),
        "score": idx.store.entry_score.copy(),
        "item": idx.store.entry_item.copy(),
        "E": idx.n_entries,
        "chunks": idx.store.n_chunks,
        "ebar": idx.ebar_start,
        "mask": idx.ebar_mask,
        "l": idx.l_counts,
        "ips": idx.items_per_source,
        "epoch": idx.store.epoch,
    }
    vals, acc, pq = _rows(7, 6, ds.n_items)
    union, union_p = _union(ds, p, vals, acc, pq)
    info = commit_rows(idx, union, union_p, CFG, 6, compact=False)
    assert info.new_entries > 0 and info.bits_set > 0
    assert idx.store.n_delta_chunks == info.delta_chunks_added > 0
    rollback_commit(idx, info)
    np.testing.assert_array_equal(idx.store.to_dense(), before["dense"])
    np.testing.assert_array_equal(idx.store.entry_score, before["score"])
    np.testing.assert_array_equal(idx.store.entry_item, before["item"])
    assert idx.n_entries == before["E"]
    assert idx.store.n_chunks == before["chunks"]
    assert idx.ebar_start == before["ebar"] and idx.ebar_mask is before["mask"]
    assert idx.l_counts is before["l"]
    assert idx.items_per_source is before["ips"]
    assert idx.store.epoch == before["epoch"]
    assert idx.store.delta_start is None
    # rollback works across compaction too (store object replaced)
    info2 = commit_rows(idx, union, union_p, CFG, 6, compact=True,
                        compact_threshold=0.0)
    assert info2.compacted
    rollback_commit(idx, info2)
    np.testing.assert_array_equal(idx.store.to_dense(), before["dense"])
    assert idx.store.n_chunks == before["chunks"]


def test_commit_q0_is_a_safe_noop():
    """A zero-row commit must not disturb membership or decisions."""
    ds, p = _world(9)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=48)
    ref = idx.store.to_dense().copy()
    info = commit_rows(idx, ds, p, CFG, 0)
    assert info.rows == 0 and info.new_entries == 0 and info.bits_set == 0
    np.testing.assert_array_equal(idx.store.to_dense(), ref)
    res = index_detect_exact(ds, p, CFG, index=idx)
    res_ref = index_detect_exact(ds, p, CFG, index=build_index(ds, p, CFG))
    np.testing.assert_array_equal(res.copying, res_ref.copying)


def test_serve_batch_failure_rolls_back_transient_commit(monkeypatch):
    """An engine failure mid-batch unwinds the transient commit — the
    committed index is bit-identical afterwards and keeps serving."""
    ds, p = _world(11)
    svc = DetectionService(ds, p, CFG, mode="bucketed", tile=32,
                           max_batch_requests=4)
    idx = svc._index
    ref = idx.store.to_dense().copy()
    ref_E = idx.n_entries
    vals, acc, pq = _rows(13, 3, ds.n_items)
    req = DetectRequest(rid=0, values=vals, accuracy=acc, p_claim=pq)

    def boom(*a, **kw):
        raise RuntimeError("mid-batch failure")

    monkeypatch.setattr(svc.engine, "detect", boom)
    fut = svc.submit(req)
    svc.flush()
    with pytest.raises(RuntimeError, match="mid-batch"):
        fut.result()
    monkeypatch.undo()
    np.testing.assert_array_equal(idx.store.to_dense(), ref)
    assert idx.n_entries == ref_E
    assert idx.store.n_rows == ds.n_sources
    # the service still serves correctly after the failed batch
    fut = svc.submit(req)
    svc.flush()
    fresh = serve_batch(ds, p, DetectionEngine(CFG, mode="bucketed", tile=32),
                        [req])[0]
    np.testing.assert_array_equal(fut.result().copying, fresh.copying)

    # a cache hit co-batched with a failing miss still resolves — only the
    # futures waiting on the broken engine pass see the exception
    vals2, acc2, pq2 = _rows(14, 2, ds.n_items)
    other = DetectRequest(rid=1, values=vals2, accuracy=acc2, p_claim=pq2)
    monkeypatch.setattr(svc.engine, "detect", boom)
    f_hit = svc.submit(req)              # cached above → exact answer in hand
    f_miss = svc.submit(other)
    svc.flush()
    monkeypatch.undo()
    assert f_hit.result().cache_hit
    np.testing.assert_array_equal(f_hit.result().copying, fresh.copying)
    with pytest.raises(RuntimeError, match="mid-batch"):
        f_miss.result()


# ---------------------------------------------------------------------------
# memoized chunk metadata views (satellite: per-epoch identity)
# ---------------------------------------------------------------------------

def test_chunk_views_memoized_per_epoch():
    """Within one (epoch, n_rows) state the SAME ChunkView object comes back
    on every access; structural mutations and row staging invalidate it."""
    ds, p = _world(2)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=48)
    store = idx.store
    v0 = store.chunk(0)
    assert store.chunk(0) is v0                      # identity within epoch
    assert list(store.iter_chunks())[0] is v0
    # row staging changes n_rows → new views
    store.append_rows(_rows(1, 2, ds.n_items)[0])
    v0b = store.chunk(0)
    assert v0b is not v0
    assert store.chunk(0) is v0b
    store.truncate_rows(ds.n_sources)
    # entry mutation bumps the epoch → new views
    vals, acc, pq = _rows(17, 4, ds.n_items)
    union, union_p = _union(ds, p, vals, acc, pq)
    epoch0 = store.epoch
    commit_rows(idx, union, union_p, CFG, 4, compact=False)
    assert idx.store.epoch > epoch0
    assert idx.store.chunk(0) is not v0
    assert idx.store.chunk(0) is idx.store.chunk(0)


# ---------------------------------------------------------------------------
# result cache: exact invalidation
# ---------------------------------------------------------------------------

def test_cache_hit_then_exact_invalidation():
    """A cached response survives commits that share none of its claim keys
    (served with independent padding for the new sources — asserted equal to
    a fresh engine pass) and dies exactly when a commit overlaps them."""
    ds, p = _world(21, n_src=36, n_items=200)
    svc = DetectionService(ds, p, CFG, mode="bucketed", tile=32,
                           max_batch_requests=4)
    D = ds.n_items
    # the request claims only items < D//2
    vals = -np.ones((2, D), np.int32)
    vals[:, : D // 2] = _rows(31, 2, D // 2)[0]
    acc = np.full(2, 0.7, np.float32)
    pq = np.where(vals == 0, 0.9,
                  np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
    req = DetectRequest(rid=0, values=vals, accuracy=acc, p_claim=pq)

    fut = svc.submit(req)
    svc.flush()
    first = fut.result()
    assert not first.cache_hit

    # a DISJOINT commit: rows claiming only items ≥ D//2
    cv = -np.ones((3, D), np.int32)
    cv[:, D // 2:] = _rows(33, 3, D - D // 2)[0]
    ca = np.full(3, 0.7, np.float32)
    cp = np.where(cv == 0, 0.9, np.where(cv >= 0, 0.05, 0.0)).astype(np.float32)
    assert not np.isin(claim_value_keys(vals), claim_value_keys(cv)).any()
    svc.commit(cv, ca, cp)

    fut = svc.submit(req)
    svc.flush()
    hit = fut.result()
    assert hit.cache_hit, "disjoint commit must not invalidate"
    assert hit.copying.shape[1] == svc.resident.n_corpus   # padded columns
    # the padded decision equals a fresh uncached pass over the grown corpus
    fresh = serve_batch(svc.base, svc.base_p,
                        DetectionEngine(CFG, mode="bucketed", tile=32), [req])[0]
    np.testing.assert_array_equal(hit.copying, fresh.copying)

    # an OVERLAPPING commit: re-commit the request's own rows
    svc.commit(vals, acc, pq)
    fut = svc.submit(req)
    svc.flush()
    after = fut.result()
    assert not after.cache_hit, "overlapping commit must invalidate"
    assert svc.stats.cache_invalidations >= 1
    fresh2 = serve_batch(svc.base, svc.base_p,
                         DetectionEngine(CFG, mode="bucketed", tile=32),
                         [req])[0]
    np.testing.assert_array_equal(after.copying, fresh2.copying)


def test_cached_decisions_track_rebuild_across_commits():
    """Commit-then-serve (cache + committed index) equals a rebuilt-from-
    scratch service across a commit schedule — the §7 acceptance property."""
    sc = synthetic_claims(SyntheticSpec(n_sources=48, n_items=256,
                                        coverage="stock", n_cliques=3, seed=4))
    ds, p = sc.dataset, oracle_claim_probs(sc)
    vals, acc, pq, _ = synthetic_query_rows(sc, 12, seed=5)
    reqs = [DetectRequest(rid=i, values=vals[3 * i: 3 * i + 3],
                          accuracy=acc[3 * i: 3 * i + 3],
                          p_claim=pq[3 * i: 3 * i + 3]) for i in range(4)]
    svc = DetectionService(ds, p, CFG, mode="bucketed", tile=32,
                           max_batch_requests=4)
    corpus_v, corpus_a, corpus_p = ds.values, ds.accuracy, p
    for round_ in range(3):
        futs = [svc.submit(r) for r in reqs]
        svc.flush()
        got = [f.result() for f in futs]
        cold = DetectionService(
            ClaimsDataset(values=corpus_v, accuracy=corpus_a), corpus_p, CFG,
            mode="bucketed", tile=32, max_batch_requests=4,
            result_cache=False)
        futs = [cold.submit(r) for r in reqs]
        cold.flush()
        want = [f.result() for f in futs]
        for a, b in zip(got, want):
            np.testing.assert_array_equal(a.copying, b.copying)
            np.testing.assert_array_equal(a.intra_copying, b.intra_copying)
        r = reqs[round_]
        svc.commit(r.values, r.accuracy, r.p_claim)
        corpus_v = np.concatenate([corpus_v, r.values])
        corpus_a = np.concatenate([corpus_a, r.accuracy])
        corpus_p = np.concatenate([corpus_p, r.p_claim])
    assert svc.stats.commits == 3
    # full-axis rows share truth-value claim keys with every commit, so the
    # conservative-exact rule invalidates; hits are exercised by
    # test_cache_hit_then_exact_invalidation's disjoint commits
    assert svc.stats.cache_invalidations > 0


# ---------------------------------------------------------------------------
# replica router
# ---------------------------------------------------------------------------

def test_replica_router_epoch_consistent_and_decision_equal():
    """Round-robined reads return identical decisions from every replica;
    commit broadcast keeps epochs equal; stats aggregate."""
    ds, p = _world(41, n_src=36, n_items=160)
    router = ReplicaRouter(ds, p, CFG, n_replicas=3, mode="bucketed",
                           tile=32, max_batch_requests=4)
    vals, acc, pq = _rows(43, 2, ds.n_items)
    req = DetectRequest(rid=0, values=vals, accuracy=acc, p_claim=pq)
    # one submit per replica (round-robin covers all three)
    futs = [router.submit(req) for _ in range(3)]
    router.flush()
    outs = [f.result() for f in futs]
    for o in outs[1:]:
        np.testing.assert_array_equal(o.copying, outs[0].copying)
    assert router.epoch == 0
    cv, ca, cp = _rows(47, 3, ds.n_items)
    infos = router.commit(cv, ca, cp)
    assert len(infos) == 3
    assert router.epoch == 1
    assert all(svc.resident.n_corpus == ds.n_sources + 3
               for svc in router.replicas)
    # post-commit reads still agree across replicas
    futs = [router.submit(req) for _ in range(3)]
    router.flush()
    outs = [f.result() for f in futs]
    for o in outs[1:]:
        np.testing.assert_array_equal(o.copying, outs[0].copying)
    assert router.stats.commits == 3                 # one per replica
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaRouter(ds, p, CFG, n_replicas=0)


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------

def test_compaction_folds_deltas_and_keeps_decisions():
    """Once deltas exceed the threshold, commit folds them into a
    score-sorted base (prefix Ē restored) without changing decisions."""
    ds, p = _world(51)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=64)
    vals, acc, pq = _rows(53, 6, ds.n_items)
    union, union_p = _union(ds, p, vals, acc, pq)
    info = commit_rows(idx, union, union_p, CFG, 6, compact=True,
                       compact_threshold=0.0)
    assert info.compacted
    assert idx.ebar_mask is None and idx.store.delta_start is None
    assert (idx.store.entry_item >= 0).all()          # padding dropped
    assert np.all(np.diff(idx.store.entry_score) <= 1e-6)   # score-sorted
    fresh = build_index(union, union_p, CFG)
    a = index_detect_exact(union, union_p, CFG, index=idx)
    b = index_detect_exact(union, union_p, CFG, index=fresh)
    np.testing.assert_array_equal(a.copying, b.copying)
    # explicit compaction of an uncompacted commit agrees too
    idx2 = build_index(ds, p, CFG, chunk_entries=16, row_capacity=64)
    commit_rows(idx2, union, union_p, CFG, 6, compact=False)
    compact_index(idx2, CFG)
    c = index_detect_exact(union, union_p, CFG, index=idx2)
    np.testing.assert_array_equal(c.copying, b.copying)
