"""INDEX (exact + bucketed) vs PAIRWISE — same binary decisions (Prop 3.5)."""
import numpy as np
import pytest

from repro.core.bucketed import bucketed_index_detect, index_detect_exact
from repro.core.scoring import pairwise_detect
from repro.core.types import CopyConfig
from repro.data.claims import (
    SyntheticSpec,
    motivating_example,
    motivating_value_probs,
    oracle_claim_probs,
    synthetic_claims,
)

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)


@pytest.fixture(scope="module")
def motivating():
    ds = motivating_example()
    p = motivating_value_probs(ds)
    return ds, p, pairwise_detect(ds, p, CFG)


def test_index_exact_matches_pairwise_decisions(motivating):
    ds, p, ref = motivating
    res = index_detect_exact(ds, p, CFG)
    np.testing.assert_array_equal(res.copying, ref.copying)


def test_index_exact_scores_match_on_considered_pairs(motivating):
    ds, p, ref = motivating
    res = index_detect_exact(ds, p, CFG)
    # where both sides considered the pair, C→ agrees with the oracle
    mask = res.pr_independent < 1.0
    np.testing.assert_allclose(res.c_fwd[mask], ref.c_fwd[mask], atol=1e-3)


def test_index_exact_computation_accounting(motivating):
    # Ex. 3.6: "There are only 26 pairs of sources that occur in entries
    # outside Ē ... INDEX needs to examine 51 shared values and have
    # 51*2 + 26*2 = 154 computations"
    ds, p, _ = motivating
    res = index_detect_exact(ds, p, CFG)
    assert res.counter.pairs_considered == 26
    assert res.counter.shared_values_examined == 51
    assert res.counter.score_computations == 154


def test_index_skips_s0_s5(motivating):
    # "S0 and S5 share only values in Ē, so we do not need to consider this pair"
    ds, p, _ = motivating
    res = index_detect_exact(ds, p, CFG)
    assert res.pr_independent[0, 5] == 1.0
    assert not res.copying[0, 5]


@pytest.mark.parametrize("n_buckets", [4, 13, 64])
def test_bucketed_matches_pairwise_decisions(motivating, n_buckets):
    ds, p, ref = motivating
    res = bucketed_index_detect(ds, p, CFG, n_buckets=n_buckets)
    np.testing.assert_array_equal(res.copying, ref.copying)


def test_bucketed_counter_matches_exact(motivating):
    ds, p, _ = motivating
    exact = index_detect_exact(ds, p, CFG)
    buck = bucketed_index_detect(ds, p, CFG, n_buckets=13)
    assert buck.counter.pairs_considered == exact.counter.pairs_considered
    assert buck.counter.shared_values_examined == exact.counter.shared_values_examined


@pytest.mark.parametrize("coverage", ["book", "stock"])
def test_synthetic_decisions_match(coverage):
    spec = SyntheticSpec(n_sources=60, n_items=400, coverage=coverage,
                         n_cliques=5, clique_size=3, seed=7)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    ref = pairwise_detect(sc.dataset, p, CFG)
    exact = index_detect_exact(sc.dataset, p, CFG)
    buck = bucketed_index_detect(sc.dataset, p, CFG, n_buckets=32)
    np.testing.assert_array_equal(exact.copying, ref.copying)
    np.testing.assert_array_equal(buck.copying, ref.copying)


def test_synthetic_recovers_planted_cliques():
    spec = SyntheticSpec(n_sources=80, n_items=600, coverage="stock",
                         n_cliques=6, clique_size=3, seed=3)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    res = bucketed_index_detect(sc.dataset, p, CFG)
    detected = res.copying_pairs()
    # every planted copier–original edge should be detected
    planted_edges = {(min(a, b), max(a, b)) for a, b in sc.copy_edges}
    recall = len(detected & planted_edges) / len(planted_edges)
    assert recall >= 0.9
