"""Fault-injection harness for traffic-hardening tests (DESIGN.md §9).

Three injectable faults, matching the failure modes the serving layer
hardens against:

- ``failing_writes(svc, n)`` — the replica's ``commit``/``retract`` raise
  ``InjectedFault`` for the next ``n`` calls (a crashed disk, a wedged
  replica): drives the router's circuit breaker through closed → open →
  half-open → closed.
- ``slow_passes(delay_s)`` — every ``serve_batch`` engine pass takes at
  least ``delay_s`` longer (an overloaded accelerator): drives deadline
  misses, admission-control shedding, and adaptive batch shrinking.
- ``FakeClock`` / ``skewed_clock(svc, skew_s)`` — a deterministic manual
  clock, or a skewed offset over the real one, for the service's
  injectable ``_clock``: deadline logic is tested without real sleeps.

All helpers are context managers that restore the patched attribute on
exit, so tests compose them freely. ``benchmarks/run.py`` loads this
module by path for the ``overload`` scenario's degraded-replica leg.
"""
from __future__ import annotations

import contextlib
import time

import repro.core.serving as serving_mod


class InjectedFault(RuntimeError):
    """The exception injected faults raise — typed, so a test can tell an
    injected failure from a real one leaking out of the code under test."""


class FakeClock:
    """Deterministic, manually advanced monotonic clock.

    Drop-in for ``DetectionService._clock`` / ``CircuitBreaker``'s clock:
    calling it returns the current reading; ``advance`` moves time forward.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        """Move the clock forward by ``dt`` seconds; returns the new now."""
        self.now += float(dt)
        return self.now


@contextlib.contextmanager
def failing_writes(svc, n: int = 10 ** 9):
    """Make ``svc.commit`` / ``svc.retract`` raise for the next ``n`` calls.

    Yields the mutable state dict (``state["left"]`` is the remaining
    failure budget — a test can zero it to heal the replica mid-run, or
    read it to count injected failures). Restores the original methods on
    exit.
    """
    state = {"left": int(n), "injected": 0}
    orig = {"commit": svc.commit, "retract": svc.retract}

    def _make(op):
        def call(*args, **kw):
            if state["left"] > 0:
                state["left"] -= 1
                state["injected"] += 1
                raise InjectedFault(f"injected {op} fault")
            return orig[op](*args, **kw)
        return call

    svc.commit = _make("commit")
    svc.retract = _make("retract")
    try:
        yield state
    finally:
        svc.commit, svc.retract = orig["commit"], orig["retract"]


@contextlib.contextmanager
def slow_passes(delay_s: float):
    """Every ``serve_batch`` engine pass sleeps ``delay_s`` first.

    Patches the module-level ``serve_batch`` that ``_run_batch`` resolves
    at call time, so the added latency lands INSIDE the service's batch
    timing — the EWMA, deadline checks, and adaptive batch limit all see
    it, exactly like a genuinely slow engine.
    """
    orig = serving_mod.serve_batch

    def slow(*args, **kw):
        time.sleep(delay_s)
        return orig(*args, **kw)

    serving_mod.serve_batch = slow
    try:
        yield
    finally:
        serving_mod.serve_batch = orig


@contextlib.contextmanager
def skewed_clock(svc, skew_s: float):
    """Offset the service's deadline clock by ``skew_s`` seconds.

    Models a client whose deadline arithmetic disagrees with the server's
    clock — the service's admission and expiry decisions shift by the skew
    while wall time does not.
    """
    orig = svc._clock
    svc._clock = lambda: orig() + skew_s
    try:
        yield
    finally:
        svc._clock = orig
