"""Delta-aware incremental block-OR cache (DESIGN.md §11).

The load-bearing property: under ANY schedule of commits, retractions and
compactions, a ``BlockOrCache`` that followed the deltas (rebuilding when a
delta declares itself un-followable) is bit-equal to a fresh full build of
the store it tracks — so the engine's tile∘chunk pruning masks, and hence
its decisions, are identical whether they came from the cache or from a
from-scratch regather.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CopyConfig,
    DetectionEngine,
    build_index,
    commit_rows,
    rollback_commit,
)
from repro.core.index import retract_rows
from repro.core.shardplan import shard_store
from repro.core.tilecache import BlockOrCache, chunk_block_inc, cols_block_inc
from repro.core.types import ClaimsDataset

CFG = CopyConfig(alpha=0.1, s=0.8, n=50.0)
TILE = 16


def _world(seed=0, n_src=24, n_items=96):
    rng = np.random.default_rng(seed)
    values = np.where(rng.random((n_src, n_items)) < 0.4,
                      rng.integers(0, 4, (n_src, n_items)),
                      -1).astype(np.int32)
    ds = ClaimsDataset(values=values,
                       accuracy=rng.uniform(0.3, 0.95,
                                            n_src).astype(np.float32))
    p = np.where(values == 0, 0.9, 0.05).astype(np.float32)
    return ds, p


def _rows(rng, q, n_items):
    vals = np.where(rng.random((q, n_items)) < 0.3,
                    rng.integers(0, 4, (q, n_items)), -1).astype(np.int32)
    acc = rng.uniform(0.3, 0.95, q).astype(np.float32)
    pq = np.where(vals == 0, 0.9,
                  np.where(vals >= 0, 0.05, 0.0)).astype(np.float32)
    return vals, acc, pq


def _ds_of(values, acc, p):
    return ClaimsDataset(values=values, accuracy=acc), p


def _assert_cache_fresh(cache, store):
    fresh = BlockOrCache.build(store, TILE)
    assert cache.mseq == store.mseq
    assert cache.block_inc.shape == fresh.block_inc.shape
    np.testing.assert_array_equal(cache.block_inc, fresh.block_inc)


# ---------------------------------------------------------------------------
# property: any commit/retract/compact schedule, cache == fresh build
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       chunk_entries=st.sampled_from([8, 16, 32]),
       n_shards=st.sampled_from([1, 4]),
       n_ops=st.integers(2, 6))
def test_cache_tracks_any_mutation_schedule(seed, chunk_entries, n_shards,
                                            n_ops):
    """Random commit/retract/compact schedules over varying chunk widths:
    the delta-following cache stays bit-equal to a fresh full build, and the
    sharded fresh build agrees with the dense one at every shard count."""
    rng = np.random.default_rng(seed)
    ds, p = _world(seed)
    idx = build_index(ds, p, CFG, chunk_entries=chunk_entries,
                      row_capacity=96)
    values, acc = ds.values, ds.accuracy
    cache = BlockOrCache.build(idx.store, TILE)
    for _ in range(n_ops):
        op = rng.choice(["commit", "commit", "retract", "compact"])
        if op == "retract" and values.shape[0] <= 6:
            op = "commit"
        if op == "commit" or op == "compact":
            q = int(rng.integers(1, 5))
            vals, a, pq = _rows(rng, q, ds.n_items)
            values = np.concatenate([values, vals])
            acc = np.concatenate([acc, a])
            p = np.concatenate([p, pq])
            union, union_p = _ds_of(values, acc, p)
            idx.store.ensure_row_capacity(values.shape[0])
            info = commit_rows(idx, union, union_p, CFG, q,
                               compact=(op == "compact"),
                               compact_threshold=0.0)
        else:
            n_out = int(rng.integers(1, 3))
            row_ids = rng.choice(values.shape[0], n_out, replace=False)
            keep = np.setdiff1d(np.arange(values.shape[0]), row_ids)
            values, acc, p = values[keep], acc[keep], p[keep]
            after, after_p = _ds_of(values, acc, p)
            info = retract_rows(idx, after, CFG, row_ids)
        cache.apply(info.delta)
        if cache.stale:
            cache = BlockOrCache.build(idx.store, TILE)
        _assert_cache_fresh(cache, idx.store)
    if n_shards > 1 and idx.store.n_rows >= n_shards:
        sh = shard_store(idx.store, n_shards)
        dense = BlockOrCache.build(idx.store, TILE)
        np.testing.assert_array_equal(
            BlockOrCache.build(sh, TILE).block_inc, dense.block_inc)


# ---------------------------------------------------------------------------
# deterministic corners: undo, GC zeroing, column-restricted reductions
# ---------------------------------------------------------------------------

def test_commit_apply_undo_is_bit_exact():
    """apply(commit delta) → rollback_commit → undo lands back bit-equal to
    the pre-commit incidence, re-anchored on the fresh post-rollback mseq."""
    ds, p = _world(5)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=64)
    cache = BlockOrCache.build(idx.store, TILE)
    before = cache.block_inc.copy()
    rng = np.random.default_rng(6)
    vals, a, pq = _rows(rng, 4, ds.n_items)
    union, union_p = _ds_of(np.concatenate([ds.values, vals]),
                            np.concatenate([ds.accuracy, a]),
                            np.concatenate([p, pq]))
    idx.store.ensure_row_capacity(union.n_sources)
    info = commit_rows(idx, union, union_p, CFG, 4, compact=False)
    token = cache.apply(info.delta)
    assert token is not None and cache.mseq == idx.store.mseq
    _assert_cache_fresh(cache, idx.store)
    rollback_commit(idx, info)
    cache.undo(token)
    np.testing.assert_array_equal(cache.block_inc, before)
    assert cache.matches(idx.store, TILE)
    # and the chain continues: the same commit re-applies cleanly
    idx.store.ensure_row_capacity(union.n_sources)
    info2 = commit_rows(idx, union, union_p, CFG, 4, compact=False)
    assert cache.apply(info2.delta) is not None
    _assert_cache_fresh(cache, idx.store)


def test_retract_apply_zeroes_gc_columns_everywhere():
    """A retraction that GCs entries zeroes those columns in ALL block rows,
    including rows the tail recompute never touched."""
    ds, p = _world(7, n_src=40)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=48)
    cache = BlockOrCache.build(idx.store, TILE)
    # retract rows near the END so leading block rows are tail-untouched
    row_ids = np.array([38, 39])
    keep = np.setdiff1d(np.arange(40), row_ids)
    after, after_p = _ds_of(ds.values[keep], ds.accuracy[keep], p[keep])
    info = retract_rows(idx, after, CFG, row_ids)
    assert cache.apply(info.delta) is None
    _assert_cache_fresh(cache, idx.store)
    gc = info.delta.gc_entries
    if gc is not None and len(gc):
        assert not cache.block_inc[:, np.asarray(gc)].any()


def test_cols_block_inc_matches_full_reduction():
    """The column-restricted reduction (commit apply's new-column fill)
    equals slicing the full-chunk reduction, dense and sharded."""
    ds, p = _world(11, n_src=33)
    idx = build_index(ds, p, CFG, chunk_entries=16)
    store = idx.store
    nb = -(-store.n_rows // TILE)
    for s in (store, shard_store(store, 3)):
        for c in range(store.n_chunks):
            full = chunk_block_inc(s, c, TILE, nb)
            cols = np.array([0, full.shape[1] - 1, full.shape[1] // 2])
            np.testing.assert_array_equal(
                cols_block_inc(s, c, cols, TILE, nb), full[:, cols])


# ---------------------------------------------------------------------------
# engine: decisions bit-equal to exact across a mutation schedule
# ---------------------------------------------------------------------------

def test_engine_decisions_exact_across_commit_retract_commit():
    """bucketed + prefetch + mask cache == exact INDEX after each step of a
    commit → retract → commit schedule (fixed tile so the cache persists)."""
    ds, p = _world(13, n_src=40, n_items=160)
    idx = build_index(ds, p, CFG, chunk_entries=16, row_capacity=64)
    eng = DetectionEngine(CFG, mode="bucketed", tile=32, prefetch_depth=2)
    rng = np.random.default_rng(14)
    values, acc = ds.values, ds.accuracy

    def check(cur, cur_p):
        got = eng.detect(cur, cur_p, index=idx)
        ref = DetectionEngine(CFG, mode="exact").detect(
            cur, cur_p, index=build_index(cur, cur_p, CFG))
        np.testing.assert_array_equal(got.copying, ref.copying)

    check(*_ds_of(values, acc, p))
    assert eng.last_stats["mask_full_builds"] == 1
    # commit
    vals, a, pq = _rows(rng, 5, ds.n_items)
    values = np.concatenate([values, vals])
    acc = np.concatenate([acc, a])
    p = np.concatenate([p, pq])
    union, union_p = _ds_of(values, acc, p)
    idx.store.ensure_row_capacity(values.shape[0])
    eng.apply_mask_delta(commit_rows(idx, union, union_p, CFG, 5,
                                     compact=False).delta)
    check(union, union_p)
    assert eng.last_stats["mask_source"] == "cache"
    # retract
    row_ids = np.array([3, 17])
    keep = np.setdiff1d(np.arange(values.shape[0]), row_ids)
    values, acc, p = values[keep], acc[keep], p[keep]
    after, after_p = _ds_of(values, acc, p)
    eng.apply_mask_delta(retract_rows(idx, after, CFG, row_ids).delta)
    check(after, after_p)
    assert eng.last_stats["mask_source"] == "cache"
    # commit again — the chain survives the retraction
    vals, a, pq = _rows(rng, 3, ds.n_items)
    values = np.concatenate([values, vals])
    acc = np.concatenate([acc, a])
    p = np.concatenate([p, pq])
    union, union_p = _ds_of(values, acc, p)
    idx.store.ensure_row_capacity(values.shape[0])
    eng.apply_mask_delta(commit_rows(idx, union, union_p, CFG, 3,
                                     compact=False).delta)
    check(union, union_p)
    assert eng.last_stats["mask_source"] == "cache"
    assert eng.last_stats["mask_full_builds"] == 1   # never rebuilt
