"""Deterministic stand-in for the `hypothesis` API surface these tests use.

The CI image installs the real library (requirements-dev.txt); the bare
container does not ship it, and a missing import must not take the whole
tier-1 run down with a collection error. ``conftest.py`` registers this
module under ``sys.modules["hypothesis"]`` only when the real package is
absent, so test files keep their plain ``from hypothesis import ...``.

Semantics: ``@given`` draws a small fixed number of examples from a seeded
generator, so the property still gets exercised (smoke-level, reproducible);
the real randomized search runs wherever hypothesis is installed.
"""
from __future__ import annotations

import numpy as np

FALLBACK_MAX_EXAMPLES = 5


class _Assumption(Exception):
    """Raised by assume(False): discard the current example."""


def assume(condition) -> bool:
    if not condition:
        raise _Assumption
    return True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NOTE: no functools.wraps — copying fn's signature would make pytest
        # treat the drawn parameters as fixtures; the wrapper takes no args.
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", 10), FALLBACK_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            ran = 0
            while ran < n:
                example = {k: s.draw(rng) for k, s in strats.items()}
                try:
                    fn(**example)
                except _Assumption:
                    continue
                ran += 1
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
