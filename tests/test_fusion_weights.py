"""The paper's technique as an LM data layer: corpus → claims → weights."""
import numpy as np

from repro.core import CopyConfig
from repro.data.fusion_weights import corpus_to_claims, fusion_weights
from repro.data.tokens import Prefetcher, batches, synthetic_corpus


def test_corpus_to_claims_shares_items_across_copiers():
    corpus = synthetic_corpus(n_sources=12, docs_per_source=10, doc_len=96,
                              n_copiers=4, seed=0)
    ds = corpus_to_claims(corpus)
    # copier pairs share many items; unrelated pairs share none
    prov = ds.provided_mask.astype(int)
    l = prov @ prov.T
    for c, o in corpus.copy_edges:
        assert l[c, o] >= 5, (c, o, l[c, o])


def test_fusion_weights_find_copiers_and_quality():
    corpus = synthetic_corpus(n_sources=16, docs_per_source=12, doc_len=96,
                              n_copiers=5, seed=1)
    src_w, doc_w, fus = fusion_weights(corpus, CopyConfig(alpha=0.1, s=0.8,
                                                          n=100.0))
    planted = {(min(a, b), max(a, b)) for a, b in corpus.copy_edges}
    detected = fus.detection.copying_pairs()
    recall = len(detected & planted) / len(planted)
    assert recall >= 0.8, (recall, detected, planted)
    # duplicated documents get discounted mass
    assert doc_w.min() < 1.0
    assert np.isclose(doc_w.max(), 1.0)
    # estimated quality correlates with planted accuracy
    corr = np.corrcoef(src_w, corpus.source_accuracy)[0, 1]
    assert corr > 0.3, corr


def test_weighted_batches_downsample_low_quality_sources():
    corpus = synthetic_corpus(n_sources=10, docs_per_source=10, doc_len=64,
                              n_copiers=2, seed=2)
    w = np.ones(10)
    w[0] = 1e-6                                 # effectively ban source 0
    it = batches(corpus, batch_size=16, seq_len=32, source_weights=w, seed=0)
    b = next(it)
    assert b["tokens"].shape == (16, 32)
    # documents of source 0 never sampled: compare against its token rows
    banned = {hash(np.asarray(d[:32]).tobytes())
              for d, s in zip(corpus.docs, corpus.doc_source) if s == 0}
    drawn = {hash(np.asarray(row).tobytes()) for row in np.asarray(b["tokens"])}
    assert not (banned & drawn)


def test_prefetcher_yields_in_order():
    it = Prefetcher(iter(range(20)), depth=2)
    got = [next(it) for _ in range(20)]
    assert got == list(range(20))
    it.close()
