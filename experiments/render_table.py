"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables."""
import json
import sys


def render(path, mesh_filter="single"):
    r = json.load(open(path))
    lines = []
    hdr = (f"| {'arch':<22} | {'shape':<11} | {'compute s':>9} | {'memory s':>9} "
           f"| {'collect s':>9} | bottleneck | {'useful':>6} | {'GB/dev':>7} |")
    lines.append(hdr)
    lines.append("|" + "-" * (len(hdr) - 2) + "|")
    for k in sorted(r):
        v = r[k]
        arch, shape, mesh = k.split("|")
        if mesh != mesh_filter:
            continue
        if v.get("status") == "skipped":
            lines.append(f"| {arch:<22} | {shape:<11} | {'—':>9} | {'—':>9} "
                         f"| {'—':>9} | N/A (skip) | {'—':>6} | {'—':>7} |")
            continue
        if v.get("status") != "ok":
            lines.append(f"| {arch:<22} | {shape:<11} | {v['status']} |")
            continue
        gb = v.get("analytic_gb", {}).get("total",
                                          v.get("memory", {}).get("per_device_gb", 0))
        lines.append(
            f"| {arch:<22} | {shape:<11} | {v['compute_s']:>9.3f} "
            f"| {v['memory_s']:>9.3f} | {v['collective_s']:>9.3f} "
            f"| {v['bottleneck']:<10} | {v.get('useful_flops_ratio', 0):>6.3f} "
            f"| {gb:>7.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"
    for mesh in ("single", "multi"):
        print(f"\n### {mesh}-pod mesh\n")
        print(render(path, mesh))
