"""Synthetic multi-source token corpus + sharded, prefetching data pipeline.

The corpus mirrors the paper's world: many sources provide overlapping
documents; some sources are copiers of low-quality originals, so naive
uniform sampling over-trains on duplicated junk. ``fusion_weights`` turns
copy-detection output into sampling weights.

Documents are integer-sequence "facts": a clean document is a modular
arithmetic progression (learnable); a corrupted document has a fraction of
its tokens replaced with noise (the source's error rate = 1 − accuracy).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class TokenCorpus:
    docs: list                      # list of np.int32 arrays
    doc_source: np.ndarray          # (n_docs,) source id per document
    doc_topic: np.ndarray           # (n_docs,) shared topic id per document
    source_accuracy: np.ndarray     # (S,) planted quality
    copy_edges: list                # (copier, original)
    vocab_size: int = 512


def synthetic_corpus(n_sources=20, docs_per_source=40, doc_len=128,
                     vocab_size=512, n_copiers=6, seed=0) -> TokenCorpus:
    """Each source provides its own noisy *rendering* of shared topics —
    the paper's world: independent sources disagree on the corrupted spans;
    copiers re-host the original's rendering verbatim. Low-quality originals
    with copiers mean duplicated junk outweighs clean text under uniform
    sampling."""
    rng = np.random.default_rng(seed)
    acc = rng.uniform(0.4, 1.0, size=n_sources).astype(np.float32)
    originals = rng.choice(n_sources, size=n_copiers, replace=False)
    copier_of = {}
    pool = [s for s in range(n_sources) if s not in set(originals.tolist())]
    rng.shuffle(pool)
    for o in originals:
        if pool:
            copier_of[pool.pop()] = int(o)

    # shared topics: a clean base document each
    topics = []
    for _ in range(docs_per_source):
        start = rng.integers(0, vocab_size)
        stride = rng.integers(1, 5)
        topics.append(((start + stride * np.arange(doc_len)) % vocab_size
                       ).astype(np.int32))

    def render(t, s):
        noise = rng.random(doc_len) > acc[s]
        return np.where(noise, rng.integers(0, vocab_size, doc_len),
                        topics[t]).astype(np.int32)

    source_docs = {s: [render(t, s) for t in range(docs_per_source)]
                   for s in range(n_sources) if s not in copier_of}
    for c, o in copier_of.items():
        n_copy = int(0.8 * docs_per_source)
        source_docs[c] = ([source_docs[o][t].copy() for t in range(n_copy)]
                          + [render(t, c)
                             for t in range(n_copy, docs_per_source)])

    docs, doc_source, doc_topic = [], [], []
    for s in range(n_sources):
        for t, d in enumerate(source_docs[s]):
            docs.append(d)
            doc_source.append(s)
            doc_topic.append(t)
    return TokenCorpus(docs=docs, doc_source=np.asarray(doc_source),
                       doc_topic=np.asarray(doc_topic),
                       source_accuracy=acc,
                       copy_edges=list(copier_of.items()),
                       vocab_size=vocab_size)


def batches(corpus: TokenCorpus, batch_size: int, seq_len: int,
            source_weights: Optional[np.ndarray] = None,
            doc_weights: Optional[np.ndarray] = None,
            seed: int = 0) -> Iterator[dict]:
    """Weighted document sampling → (tokens, labels) batches, forever."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    n = len(corpus.docs)
    w = np.ones(n, dtype=np.float64)
    if source_weights is not None:
        w *= np.asarray(source_weights, np.float64)[corpus.doc_source]
    if doc_weights is not None:
        w *= np.asarray(doc_weights, np.float64)
    w /= w.sum()
    while True:
        idx = rng.choice(n, size=batch_size, p=w)
        rows = np.stack([corpus.docs[i][: seq_len + 1] for i in idx])
        yield {"tokens": jnp.asarray(rows[:, :-1]),
               "labels": jnp.asarray(rows[:, 1:])}


class Prefetcher:
    """Double-buffered host→device prefetch (overlap input with compute)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = False
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        for item in self.it:
            if self._stop:
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop = True
