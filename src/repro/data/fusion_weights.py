"""The paper's technique as a data-layer feature: copy-detection-derived
source weights and duplication discounts for LM training corpora.

Pipeline: documents are hashed into (item, value) claims — each document
span is a data item, the span's content hash is the value — so sources that
re-host the same documents share values exactly like the paper's sources
share attribute values. Truth finding then yields per-source accuracies and
pairwise copy probabilities, which become:

  * source_weight(s)  = accuracy(s)            (low-quality sources sampled less)
  * doc_weight(d)     = 1 / (1 + #copiers of d's providing clique)
                        (mass of a document split across its re-hosters)
"""
from __future__ import annotations

import numpy as np

from repro.core import CopyConfig, truth_finding
from repro.core.types import ClaimsDataset
from repro.data.tokens import TokenCorpus


def corpus_to_claims(corpus: TokenCorpus, span: int = 16) -> ClaimsDataset:
    """Content-hash each document's spans into claims.

    item = (topic, span index); value = hash of the span's tokens. Sources
    rendering the same topic independently disagree wherever either one
    corrupted a token (the value domain per item is effectively the paper's
    n false values); a copier re-hosting the original's rendering matches
    *exactly* on corrupted spans too — precisely the paper's sharing-false-
    values signal."""
    items = {}
    claims = {}
    for di, doc in enumerate(corpus.docs):
        s = int(corpus.doc_source[di])
        t = int(corpus.doc_topic[di])
        for sp in range(len(doc) // span):
            item_id = items.setdefault((t, sp), len(items))
            val = hash(doc[sp * span: (sp + 1) * span].tobytes()) & 0x7FFFFFFF
            claims[(s, item_id)] = val
    S = len(corpus.source_accuracy)
    D = len(items)
    values = -np.ones((S, D), dtype=np.int64)
    for (s, item_id), val in claims.items():
        values[s, item_id] = val
    # compress values per item to small ids
    out = -np.ones((S, D), dtype=np.int32)
    for d in range(D):
        vals = values[:, d]
        uniq = {v: i for i, v in enumerate(sorted(set(vals[vals >= 0])))}
        for s in range(S):
            if vals[s] >= 0:
                out[s, d] = uniq[vals[s]]
    return ClaimsDataset(values=out,
                         accuracy=np.full(S, 0.8, np.float32))


def fusion_weights(corpus: TokenCorpus, cfg: CopyConfig | None = None,
                   detector: str = "hybrid"):
    """→ (source_weights (S,), doc_weights (n_docs,), fusion result)."""
    cfg = cfg or CopyConfig(alpha=0.1, s=0.8, n=100.0)
    ds = corpus_to_claims(corpus)
    res = truth_finding(ds, cfg, detector=detector, max_rounds=6)

    src_w = np.clip(res.accuracy, 0.05, None).astype(np.float64)

    # duplication discount: documents re-hosted by a copier clique share mass
    copying = res.detection.copying
    n_dup = np.zeros(len(corpus.docs))
    seen: dict = {}
    for di, doc in enumerate(corpus.docs):
        key = hash(doc.tobytes())
        seen.setdefault(key, []).append(di)
    for key, dis in seen.items():
        if len(dis) > 1:
            for di in dis:
                n_dup[di] = len(dis) - 1
    doc_w = 1.0 / (1.0 + n_dup)
    return src_w, doc_w, res
