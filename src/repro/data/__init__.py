from repro.data.claims import (
    motivating_example,
    motivating_value_probs,
    synthetic_claims,
)

__all__ = ["motivating_example", "motivating_value_probs", "synthetic_claims"]
