"""Claims datasets: the paper's motivating example (Table I) and synthetic
generators shaped like the paper's four experimental datasets (Table V).

The synthetic generator plants a ground-truth copying structure so that
copy-detection precision/recall (Table VI) can be measured against a known
reference, and mirrors the two regimes the paper contrasts:

* *Book-like*  — many sources, low coverage (85% of sources cover ≤ 1% of
  items), long-tail; copying within small cliques.
* *Stock-like* — few sources, high coverage (80% cover ≥ 50%).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.types import ClaimsDataset

# ---------------------------------------------------------------------------
# Motivating example — Table I
# ---------------------------------------------------------------------------

_ITEMS = ["NJ", "AZ", "NY", "FL", "TX"]
_TABLE_I = {
    #        NJ          AZ         NY         FL         TX         acc
    "S0": (("Trenton", "Phoenix", "Albany", None, "Austin"), 0.99),
    "S1": (("Trenton", "Phoenix", "Albany", "Orlando", "Austin"), 0.99),
    "S2": (("Atlantic", "Phoenix", "NewYork", "Miami", "Houston"), 0.2),
    "S3": (("Atlantic", "Phoenix", "NewYork", "Miami", "Arlington"), 0.2),
    "S4": (("Atlantic", "Phoenix", "NewYork", "Orlando", "Houston"), 0.4),
    "S5": (("Union", "Tempe", "Albany", "Orlando", "Austin"), 0.6),
    "S6": ((None, "Tempe", "Buffalo", "PalmBay", "Dallas"), 0.01),
    "S7": (("Trenton", None, "Buffalo", "PalmBay", "Dallas"), 0.25),
    "S8": (("Trenton", "Tucson", "Buffalo", "PalmBay", "Dallas"), 0.2),
    "S9": (("Trenton", None, None, "Orlando", "Austin"), 0.99),
}

# Converged value-truth probabilities, Table III (plus singletons).
_TABLE_III_P = {
    ("AZ", "Tempe"): 0.02, ("NJ", "Atlantic"): 0.01, ("TX", "Houston"): 0.02,
    ("NY", "NewYork"): 0.02, ("TX", "Dallas"): 0.02, ("NY", "Buffalo"): 0.04,
    ("FL", "PalmBay"): 0.05, ("FL", "Miami"): 0.03, ("AZ", "Phoenix"): 0.95,
    ("NJ", "Trenton"): 0.97, ("FL", "Orlando"): 0.92, ("NY", "Albany"): 0.94,
    ("TX", "Austin"): 0.96,
    # singletons (not indexed; only used for claim-probability completeness)
    ("NJ", "Union"): 0.02, ("AZ", "Tucson"): 0.02, ("TX", "Arlington"): 0.02,
}

# Ground-truth capitals (for fusion-accuracy measurement).
TRUE_CAPITALS = {"NJ": "Trenton", "AZ": "Phoenix", "NY": "Albany",
                 "FL": "Tallahassee", "TX": "Austin"}
# (the paper treats Orlando as the popular-but-false FL value; no source has
#  the true value — fusion picks the most probable observed one)


def motivating_example() -> ClaimsDataset:
    """Table I as a ClaimsDataset. Value ids are per-item, assigned in first-
    appearance order over S0..S9 so tests can name them via value_names."""
    sources = list(_TABLE_I.keys())
    vmaps: list[dict] = [dict() for _ in _ITEMS]
    values = -np.ones((len(sources), len(_ITEMS)), dtype=np.int32)
    value_names = {}
    for si, s in enumerate(sources):
        row, _ = _TABLE_I[s]
        for d, v in enumerate(row):
            if v is None:
                continue
            if v not in vmaps[d]:
                vmaps[d][v] = len(vmaps[d])
                value_names[(d, vmaps[d][v])] = f"{_ITEMS[d]}.{v}"
            values[si, d] = vmaps[d][v]
    acc = np.array([_TABLE_I[s][1] for s in sources], dtype=np.float32)
    ds = ClaimsDataset(values=values, accuracy=acc, item_names=_ITEMS,
                       source_names=sources, value_names=value_names)
    ds._vmaps = vmaps  # convenience for tests
    return ds


def motivating_value_probs(ds: ClaimsDataset) -> np.ndarray:
    """The converged P(D.v) of Table III expanded to a (S, D) claim matrix."""
    p = np.zeros(ds.values.shape, dtype=np.float32)
    inv = {v: k for k, v in ds.value_names.items()}
    for (item, vname), prob in _TABLE_III_P.items():
        d = _ITEMS.index(item)
        key = inv.get(f"{item}.{vname}")
        if key is None:
            continue
        _, vid = key
        p[ds.values[:, d] == vid, d] = prob
    return p


GROUND_TRUTH_COPIES = {(2, 3), (2, 4), (3, 4), (6, 7), (6, 8), (7, 8)}
"""The paper: "There is copying between S2–S4 and between S6–S8"."""


# ---------------------------------------------------------------------------
# Synthetic generators (Table V regimes)
# ---------------------------------------------------------------------------

@dataclass
class SyntheticSpec:
    n_sources: int = 200
    n_items: int = 2000
    n_false: int = 50                  # domain size of false values per item
    coverage: str = "book"             # "book" (long-tail) | "stock" (dense)
    n_cliques: int = 10                # copying cliques planted
    clique_size: int = 3
    copy_selectivity: float = 0.8      # fraction of the original's items copied
    clique_items: int | None = None    # if set, clique sources provide exactly
                                       # this many items (the paper's Book-CS
                                       # regime: copiers with tiny coverage)
    acc_low: float = 0.35
    acc_high: float = 0.95
    seed: int = 0


@dataclass
class SyntheticClaims:
    dataset: ClaimsDataset
    true_values: np.ndarray            # (D,) int32 — value id 0 is always truth
    copies: set = field(default_factory=set)      # unordered pairs (i, j), i<j
    copy_edges: list = field(default_factory=list)  # (copier, original)


def synthetic_claims(spec: SyntheticSpec) -> SyntheticClaims:
    """Generate sources with planted accuracies, coverage profile, and
    copying cliques (each clique: one original + members that copy a random
    `copy_selectivity` fraction of its claims and independently fill the rest).

    Raises ``ValueError`` when the clique plan needs more distinct sources
    than exist — clique members are drawn without replacement, so
    ``n_cliques · clique_size > n_sources`` would spin the rejection loop
    below forever instead of ever returning.
    """
    needed = spec.n_cliques * spec.clique_size
    if needed > spec.n_sources:
        raise ValueError(
            f"spec needs {spec.n_cliques} cliques × {spec.clique_size} "
            f"distinct sources = {needed}, but n_sources={spec.n_sources}; "
            f"shrink the cliques or add sources")
    rng = np.random.default_rng(spec.seed)
    S, D = spec.n_sources, spec.n_items
    true_vals = np.zeros(D, dtype=np.int32)    # truth coded as value 0
    acc = rng.uniform(spec.acc_low, spec.acc_high, size=S).astype(np.float32)

    if spec.coverage == "book":
        # long-tail: most sources cover few items
        cov = np.clip(rng.pareto(1.2, size=S) * 0.01 + 0.005, 0.003, 0.9)
    else:
        cov = rng.uniform(0.5, 1.0, size=S)

    values = -np.ones((S, D), dtype=np.int32)
    for s in range(S):
        m = rng.random(D) < cov[s]
        idx = np.nonzero(m)[0]
        correct = rng.random(idx.size) < acc[s]
        v = np.where(correct, 0, rng.integers(1, spec.n_false + 1, size=idx.size))
        values[s, idx] = v

    # plant copying cliques: members overwrite a fraction of the original's claims
    copies: set = set()
    copy_edges: list = []
    originals = rng.choice(S, size=spec.n_cliques, replace=False)
    used = set(originals.tolist())
    for o in originals:
        if spec.clique_items is not None:
            # paper's Book-CS regime: clique sources have tiny coverage
            k = spec.clique_items
            values[o, :] = -1
            idx = rng.choice(D, size=k, replace=False)
            correct = rng.random(k) < acc[o]
            values[o, idx] = np.where(correct, 0, rng.integers(1, spec.n_false + 1, size=k))
        elif (values[o] >= 0).sum() < 20:
            # make sure the original has enough claims to copy from
            idx = rng.choice(D, size=20, replace=False)
            correct = rng.random(20) < acc[o]
            values[o, idx] = np.where(correct, 0, rng.integers(1, spec.n_false + 1, size=20))
        members = []
        for _ in range(spec.clique_size - 1):
            c = int(rng.integers(0, S))
            while c in used:
                c = int(rng.integers(0, S))
            used.add(c)
            members.append(c)
        o_idx = np.nonzero(values[o] >= 0)[0]
        for c in members:
            if spec.clique_items is not None:
                values[c, :] = -1          # copier's world is the original's
            take = o_idx[rng.random(o_idx.size) < spec.copy_selectivity]
            values[c, take] = values[o, take]
            copy_edges.append((c, int(o)))
            copies.add((min(c, int(o)), max(c, int(o))))
        # co-copiers share most of the original ⇒ also detected as dependent
        for a in members:
            for b in members:
                if a < b:
                    copies.add((a, b))

    ds = ClaimsDataset(values=values, accuracy=acc)
    return SyntheticClaims(dataset=ds, true_values=true_vals, copies=copies,
                           copy_edges=copy_edges)


def book_cs_spec(seed: int = 0) -> SyntheticSpec:
    """~Table V Book-CS scale: 894 sources × 2,528 items, long-tail."""
    return SyntheticSpec(n_sources=894, n_items=2528, coverage="book",
                         n_cliques=25, clique_size=3, seed=seed)


def stock_1day_spec(seed: int = 0) -> SyntheticSpec:
    """~Table V Stock-1day scale: 55 sources × 16,000 items, dense."""
    return SyntheticSpec(n_sources=55, n_items=16000, coverage="stock",
                         n_cliques=6, clique_size=3, seed=seed)


def book_full_spec(seed: int = 0) -> SyntheticSpec:
    """~Table V Book-full scale (reduced items for CPU benchmarks)."""
    return SyntheticSpec(n_sources=3182, n_items=20000, coverage="book",
                         n_cliques=60, clique_size=3, seed=seed)


def stock_2wk_spec(seed: int = 0) -> SyntheticSpec:
    """~Table V Stock-2wk scale (reduced items for CPU benchmarks)."""
    return SyntheticSpec(n_sources=55, n_items=80000, coverage="stock",
                         n_cliques=6, clique_size=3, seed=seed)


def _oracle_probs(values: np.ndarray) -> np.ndarray:
    """Oracle truth prior per claim: value 0 (truth) w.p. .95, others .02."""
    return np.where(values == 0, 0.95,
                    np.where(values > 0, 0.02, 0.0)).astype(np.float32)


def oracle_claim_probs(sc: SyntheticClaims) -> np.ndarray:
    """Claim-probability matrix assuming oracle knowledge of the truth
    (value 0 true w.p. .95, others .05/n) — used for single-round benches."""
    return _oracle_probs(sc.dataset.values)


def synthetic_query_rows(
    sc: SyntheticClaims,
    n_rows: int,
    copy_fraction: float = 0.7,
    p_copier: float = 0.6,
    items_per_row: int = 24,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Query-source rows for the serving layer (core/serving.py).

    Each row is either a *copier* (with probability ``p_copier``: it copies
    ``copy_fraction`` of a random corpus source's claims and fills the rest
    independently) or an independent source claiming ``items_per_row``
    random items. Value coding and claim probabilities match the corpus
    (``oracle_claim_probs``), so rows can be stacked straight under it.

    Returns ``(values, accuracy, p_claim, origins)`` with shapes
    ((n_rows, D), (n_rows,), (n_rows, D), (n_rows,)); ``origins[r]`` is the
    corpus source row r copies, or −1 for independent rows.
    """
    rng = np.random.default_rng(seed)
    ds = sc.dataset
    D = ds.n_items
    n_false = int(max(ds.values.max(), 1))
    values = -np.ones((n_rows, D), dtype=np.int32)
    accuracy = rng.uniform(0.35, 0.95, n_rows).astype(np.float32)
    origins = np.full(n_rows, -1, dtype=np.int32)
    for r in range(n_rows):
        if rng.random() < p_copier:
            o = int(rng.integers(0, ds.n_sources))
            o_idx = np.nonzero(ds.values[o] >= 0)[0]
            take = o_idx[rng.random(o_idx.size) < copy_fraction]
            values[r, take] = ds.values[o, take]
            origins[r] = o
            fill = rng.choice(D, size=min(6, D), replace=False)
        else:
            fill = rng.choice(D, size=min(items_per_row, D), replace=False)
        fill = fill[values[r, fill] < 0]
        correct = rng.random(fill.size) < accuracy[r]
        values[r, fill] = np.where(
            correct, 0, rng.integers(1, n_false + 1, size=fill.size))
    return values, accuracy, _oracle_probs(values), origins
