"""Platform setup + pipeline autotuning for launch and benchmarks.

Two concerns the tiled engine's async pipeline (DESIGN.md §11) pushes to
process startup:

* **XLA platform/flag setup** — ``set_platform`` selects the backend and,
  on GPU, turns on the latency-hiding scheduler + async collectives so the
  prefetcher's host→device copies overlap the running tile kernel at the
  XLA level too. Must run before the first JAX call (flags are read at
  backend init).
* **Per-backend pipeline autotuning** — the best (tile edge, chunk_group)
  point depends on the backend (CPU wants cache-sized groups, accelerators
  want dispatch-amortizing ones), so ``autotune`` sweeps a caller-provided
  timing function over a small grid once and caches the winner in
  ``<cache_dir>/<backend>.json``; ``load_autotune`` lets later runs (e.g.
  ``benchmarks.run scaling``) adopt it without re-sweeping.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Iterable, Optional

import jax

#: Default location of the per-backend autotune cache (relative to cwd).
AUTOTUNE_DIR = ".autotune"


def set_platform(platform: str = "cpu") -> None:
    """Select the JAX backend; on GPU, enable the latency-hiding flags.

    Only takes effect at the beginning of the program (XLA reads
    ``XLA_FLAGS`` when the backend initializes). The GPU flag set follows
    the upstream gpu_performance_tips guidance: async collectives and the
    latency-hiding scheduler let compiled collectives and host transfers
    overlap compute — the device-side complement of the engine's
    ``ChunkPrefetcher``.
    """
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        os.environ["XLA_FLAGS"] = (
            "--xla_gpu_enable_triton_softmax_fusion=true "
            "--xla_gpu_triton_gemm_any=True "
            "--xla_gpu_enable_async_collectives=true "
            "--xla_gpu_enable_latency_hiding_scheduler=true "
            "--xla_gpu_enable_highest_priority_async_stream=true "
        )


def set_host_device_count(n: int) -> None:
    """Expose ``n`` virtual devices on the host CPU platform.

    Appends (rather than overwrites) ``--xla_force_host_platform_device_
    count`` so it composes with ``set_platform``'s flag block. Only
    effective before the first JAX call.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if not f.startswith(
                         "--xla_force_host_platform_device_count"))
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={int(n)}".strip())


def _cache_path(cache_dir: str) -> str:
    """Per-backend cache file — CPU and accelerator winners never collide."""
    return os.path.join(cache_dir, f"{jax.default_backend()}.json")


def load_autotune(cache_dir: str = AUTOTUNE_DIR) -> Optional[dict]:
    """Return the cached winner for the current backend, or None.

    The dict carries ``tile``, ``chunk_group``, ``wall_s`` and the full
    ``sweep`` it won (see ``autotune``). Corrupt/partial cache files read
    as None — the caller just falls back to defaults.
    """
    try:
        with open(_cache_path(cache_dir)) as f:
            out = json.load(f)
        if "tile" in out and "chunk_group" in out:
            return out
    except (OSError, ValueError):
        pass
    return None


def autotune(
    run_fn: Callable[[int, int], float],
    tiles: Iterable[int] = (128, 256),
    groups: Iterable[int] = (1, 2),
    cache_dir: str = AUTOTUNE_DIR,
    force: bool = False,
) -> dict:
    """Sweep ``run_fn(tile, chunk_group) → wall seconds``; cache the winner.

    A deliberately small grid — the knobs interact with backend memory
    hierarchy, not with correctness (every point produces bit-identical
    decisions), so a handful of timed points per backend suffices. Returns
    ``{"backend", "tile", "chunk_group", "wall_s", "sweep": [...]}`` and
    persists it at ``<cache_dir>/<backend>.json`` unless an existing cache
    already answers (``force=True`` re-sweeps).
    """
    if not force:
        cached = load_autotune(cache_dir)
        if cached is not None:
            return cached
    sweep = []
    for tile in tiles:
        for group in groups:
            wall = float(run_fn(int(tile), int(group)))
            sweep.append({"tile": int(tile), "chunk_group": int(group),
                          "wall_s": round(wall, 4)})
    best = min(sweep, key=lambda r: r["wall_s"])
    out = {"backend": jax.default_backend(), "tile": best["tile"],
           "chunk_group": best["chunk_group"], "wall_s": best["wall_s"],
           "sweep": sweep}
    os.makedirs(cache_dir, exist_ok=True)
    with open(_cache_path(cache_dir), "w") as f:
        json.dump(out, f, indent=2)
    return out


__all__ = ["AUTOTUNE_DIR", "autotune", "load_autotune",
           "set_host_device_count", "set_platform"]
