"""The training runtime: jitted train step (grad-accum, clipping, schedule),
fault-tolerant driver loop (checkpoint/restart on failure), and straggler
monitoring.

Fault model (what a 1000-node run needs and what we can test on CPU):
  * hard step failure (device loss, preemption) → exception from the step →
    restore latest checkpoint, resume; bounded retries;
  * stragglers → per-step wall-time EMA watchdog; slow steps are recorded
    and surfaced (on a real cluster this feeds the scheduler's hot-spare
    swap; here the hook is pluggable);
  * elasticity → checkpoints are logical (see checkpoint.py) so a restart
    may bring a different data-axis size; shardings are re-derived from the
    new mesh at restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.optim import OPTIMIZERS
from repro.optim.schedule import clip_by_global_norm, warmup_cosine


# ---------------------------------------------------------------------------
# jitted step
# ---------------------------------------------------------------------------

def make_train_step(model, optimizer, lr_fn, *, grad_accum: int = 1,
                    max_grad_norm: float = 1.0, donate: bool = True):
    """Returns train_step(state, batch) → (state, metrics).

    state = {params, opt, step}; batch leaves have leading dim
    (grad_accum, micro_batch, ...) when grad_accum > 1.
    """

    def loss_fn(params, micro):
        return model.loss(params, micro)

    def compute_grads(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def body(carry, micro):
            acc_loss, acc_grads = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, micro)
            acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
            return (acc_loss + loss, acc_grads), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), batch)
        scale = 1.0 / grad_accum
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(state, batch):
        loss, grads = compute_grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_fn(state["step"])
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"], lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


def init_train_state(model, optimizer, key):
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_dims(model, optimizer):
    pd = model.param_dims()
    has_master = model.cfg.param_dtype == "bfloat16"
    return {"params": pd,
            "opt": optimizer.state_dims(pd, has_master=has_master),
            "step": ()}


# ---------------------------------------------------------------------------
# straggler monitoring
# ---------------------------------------------------------------------------

@dataclass
class StepMonitor:
    """EMA wall-time watchdog: flags steps slower than slack × EMA."""

    slack: float = 2.0
    ema_decay: float = 0.9
    ema: Optional[float] = None
    slow_steps: list = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def record(self, step: int, seconds: float) -> bool:
        is_slow = False
        if self.ema is not None and seconds > self.slack * self.ema:
            is_slow = True
            self.slow_steps.append((step, seconds, self.ema))
            if self.on_straggler:
                self.on_straggler(step, seconds, self.ema)
        # slow outliers shouldn't poison the baseline
        upd = min(seconds, (self.slack * self.ema) if self.ema else seconds)
        self.ema = upd if self.ema is None else (
            self.ema_decay * self.ema + (1 - self.ema_decay) * upd)
        return is_slow


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------

class FaultInjector:
    """Test hook: raises at scheduled steps (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected fault at step {step}")


def train(
    model,
    data_iter,
    *,
    steps: int,
    optimizer_name: Optional[str] = None,
    peak_lr: float = 3e-4,
    warmup: int = 20,
    grad_accum: int = 1,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 50,
    keep: int = 3,
    async_checkpoint: bool = True,
    seed: int = 0,
    fault_injector: Optional[FaultInjector] = None,
    max_retries: int = 3,
    monitor: Optional[StepMonitor] = None,
    log_every: int = 10,
    log_fn=print,
):
    """Run training with checkpoint/restart fault tolerance. Returns
    (final_state, history)."""
    optimizer = OPTIMIZERS[optimizer_name or model.cfg.optimizer]()
    lr_fn = warmup_cosine(peak_lr, warmup, steps)
    step_fn = jax.jit(make_train_step(model, optimizer, lr_fn,
                                      grad_accum=grad_accum))
    state = init_train_state(model, optimizer, jax.random.PRNGKey(seed))
    monitor = monitor or StepMonitor()
    mgr = (CheckpointManager(checkpoint_dir, keep=keep,
                             async_save=async_checkpoint)
           if checkpoint_dir else None)

    # resume if a checkpoint exists
    if mgr and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state)
        log_fn(f"[train] resumed from step {int(state['step'])}")

    history = []
    retries = 0
    step = int(state["step"])
    batches = iter(data_iter)
    pending = None
    while step < steps:
        try:
            if pending is None:
                pending = next(batches)
            if fault_injector:
                fault_injector.check(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, pending)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            pending = None
            retries = 0
            history.append({"step": step, "seconds": dt, **metrics})
            if log_every and step % log_every == 0:
                log_fn(f"[train] step {step} loss {metrics['loss']:.4f} "
                       f"({dt * 1e3:.0f} ms)")
            step = int(state["step"])
            if mgr and step % checkpoint_every == 0:
                mgr.save(step, state)
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:  # noqa: PERF203
            retries += 1
            log_fn(f"[train] step {step} failed ({e}); retry {retries}")
            if retries > max_retries:
                raise
            if mgr and mgr.latest_step() is not None:
                state, _ = mgr.restore(state)
                step = int(state["step"])
                log_fn(f"[train] restored checkpoint at step {step}")
    if mgr:
        mgr.save(step, state)
        mgr.wait()
    return state, history
