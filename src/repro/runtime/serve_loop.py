"""Batched serving loop with continuous batching (slot-based).

A fixed pool of B decode slots shares one jitted ``decode_step``; requests
attach to free slots and detach when finished, so short requests never wait
for long ones (continuous batching). Each slot keeps its own position
counter; the KV/SSM cache is allocated once for the pool. Per-slot position
masking uses the cache's absolute ``pos_ids``, so interleaved slots can't
see each other — but note the *cache layout* is shared, which is why slots
write disjoint batch rows.

This is the single-host core of a serving tier: on a real deployment each
model replica runs one ``ServeLoop``; routing/scheduling across replicas
lives above it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 16
    cond: Optional[np.ndarray] = None
    # filled by the loop:
    output: list = field(default_factory=list)
    done: bool = False
    submitted_s: float = 0.0
    finished_s: float = 0.0


class ServeLoop:
    """Slot-based continuous batching over Model.decode_step."""

    def __init__(self, model, params, n_slots: int = 4, max_seq: int = 256,
                 eos_id: Optional[int] = None, dtype=jnp.float32):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.cache = model.init_cache(n_slots, max_seq, dtype=dtype)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)       # next position
        self.slot_cursor = np.zeros(n_slots, np.int32)    # prompt cursor
        self.pending: list[Request] = []
        self._step = jax.jit(self._batched_step)
        self.steps = 0

    # one fused step: each slot consumes its own token at its own position
    def _batched_step(self, params, cache, tokens, positions, cond):
        # decode_step expects a shared scalar position; we step slots at
        # their own positions by running the shared step at each slot's pos
        # via per-slot masking of the cache update: the cache's absolute
        # pos_ids make interleaved writes safe. For the shared-pos fast path
        # (all slots aligned) a single call suffices; the general path loops
        # over distinct positions (≤ n_slots, usually 1-2 distinct).
        logits, cache = self.model.decode_step(params, cache, tokens,
                                               positions, cond=cond)
        return logits, cache

    def submit(self, req: Request):
        req.submitted_s = time.time()
        self.pending.append(req)

    def _attach(self):
        for i in range(self.n_slots):
            if self.slot_req[i] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                self.slot_cursor[i] = 0

    def _next_tokens(self, last_logits) -> np.ndarray:
        toks = np.zeros(self.n_slots, np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            cur = int(self.slot_cursor[i])
            if cur < len(req.prompt):
                toks[i] = req.prompt[cur]              # teacher-forced prefill
            else:
                toks[i] = int(np.argmax(last_logits[i]))
        return toks

    def run(self, idle_ok: bool = False):
        """Drive until all submitted requests finish."""
        last_logits = np.zeros((self.n_slots,
                                self.model.cfg.vocab_size), np.float32)
        while self.pending or any(r is not None for r in self.slot_req):
            self._attach()
            toks = self._next_tokens(last_logits)
            active = np.array([r is not None for r in self.slot_req])
            if not active.any():
                break
            cond = None
            if self.model.cfg.cond_len:
                cond = jnp.zeros((self.n_slots, self.model.cfg.cond_len,
                                  self.model.cfg.cond_dim), jnp.float32)
            # one fused step for ALL slots: per-row positions (the decode
            # path scatters each row's kv at its own slot — no grouping)
            logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.slot_pos), cond)
            logits = np.asarray(logits)
            last_logits[active] = logits[active]
            self.steps += 1

            # advance / retire slots
            for i, req in enumerate(self.slot_req):
                if req is None:
                    continue
                cur = int(self.slot_cursor[i])
                # the logits that follow the LAST prompt token are already
                # the first generated token
                if cur >= len(req.prompt) - 1:
                    tok = int(np.argmax(last_logits[i]))
                    req.output.append(tok)
                self.slot_cursor[i] += 1
                self.slot_pos[i] += 1
                prompt_done = self.slot_cursor[i] >= len(req.prompt)
                hit_eos = (self.eos_id is not None and req.output
                           and req.output[-1] == self.eos_id)
                out_full = len(req.output) >= req.max_new
                if (prompt_done and (out_full or hit_eos)) \
                        or self.slot_pos[i] >= self.max_seq:
                    req.done = True
                    req.finished_s = time.time()
                    self.slot_req[i] = None
