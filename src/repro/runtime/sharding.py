"""Sharding-rules engine: logical dimension names → PartitionSpec.

Every parameter / cache leaf carries a tuple of logical dim names (the
``*_dims`` functions in repro.models). The solver assigns at most one dim of
each leaf to the ``model`` axis (tensor parallelism) and at most one to the
``data`` axis (FSDP / batch), with a strict divisibility check and a
priority-ordered fallback — e.g. gemma's 8 q-heads don't divide a 16-way
model axis, so its attention shards fall through to head_dim (256/16 ✓),
and a 32001-entry vocab (hymba) is simply replicated.

Multi-pod: activations' ``batch`` shards over ('pod', 'data'); parameters
stay FSDP-over-data and replicated across pods by default (pure DP between
pods; cross-pod ZeRO is a §Perf option — see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority order for the tensor-parallel ('model') axis.
# PARAMS never shard head_dim: a hd-sharded QK/PV contraction psums full
# logits every layer (measured +25 s/step collective on hymba — §Perf H1b);
# odd-head archs (hymba 25H, gemma 8H on a 16-way axis) replicate their
# small attention weights instead.
MODEL_PRIORITY = ("d_ff", "heads", "kv_heads", "vocab", "d_inner", "d_inner2",
                  "dt_plus")
# ACTIVATIONS/CACHES: kv heads first, then the cache's sequence dim (a
# seq-sharded KV cache turns decode attention into a psum of (B,H,1) —
# bytes ∝ B·H instead of B·H·S), head_dim as last resort.
MODEL_PRIORITY_ACT = ("kv_heads", "d_inner", "d_inner2", "seq", "head_dim")
# priority order for the FSDP/data axis on parameters
DATA_PRIORITY_PARAM = ("d_model", "cond_dim")
# priority order for the data axis on activations/caches
DATA_PRIORITY_ACT = ("batch",)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]   # works for Mesh and AbstractMesh


def _pick(dims: Sequence[str], sizes: Sequence[int], priority, axis_len,
          taken: set) -> Optional[int]:
    for want in priority:
        for pos, d in enumerate(dims):
            if d == want and pos not in taken and sizes[pos] % axis_len == 0:
                return pos
    return None


def spec_for(dims: Sequence[str], sizes: Sequence[int], mesh: Mesh,
             kind: str = "param") -> P:
    """kind: 'param' (TP + FSDP) | 'act' (batch over pod+data, TP on model)."""
    has_pod = "pod" in mesh.axis_names
    model_len = _axis_size(mesh, "model")
    data_len = _axis_size(mesh, "data")
    assign: dict[int, object] = {}
    taken: set[int] = set()

    m_priority = MODEL_PRIORITY if kind == "param" else MODEL_PRIORITY_ACT
    m = _pick(dims, sizes, m_priority, model_len, taken)
    if m is not None:
        assign[m] = "model"
        taken.add(m)

    if kind == "param":
        d = _pick(dims, sizes, DATA_PRIORITY_PARAM, data_len, taken)
        if d is not None:
            assign[d] = "data"
            taken.add(d)
    else:
        batch_axes = ("pod", "data") if has_pod else ("data",)
        batch_len = data_len * (_axis_size(mesh, "pod") if has_pod else 1)
        d = _pick(dims, sizes, DATA_PRIORITY_ACT, batch_len, taken)
        if d is not None:
            assign[d] = batch_axes if has_pod else "data"
            taken.add(d)
        else:
            # batch not divisible by pod×data — try data alone (long_500k B=1
            # stays fully replicated on the batch dim)
            d = _pick(dims, sizes, DATA_PRIORITY_ACT, data_len, taken)
            if d is not None:
                assign[d] = "data"
                taken.add(d)

    return P(*[assign.get(i) for i in range(len(dims))])


def tree_specs(tree_shapes, tree_dims, mesh: Mesh, kind: str = "param"):
    """Map (ShapeDtypeStruct tree, dims tree) → PartitionSpec tree."""
    def leaf(shape_leaf, dims_leaf):
        return spec_for(dims_leaf, shape_leaf.shape, mesh, kind=kind)

    return jax.tree.map(leaf, tree_shapes, tree_dims,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(d, str) for d in x))


def named(tree_spec, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                        is_leaf=lambda x: isinstance(x, P))


def model_shardings(model, mesh: Mesh, batch: int = 0, seq_len: int = 0):
    """Convenience bundle: (param_specs, cache_specs|None) for a Model."""
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = _dims_tree_specs(param_shapes, model.param_dims(), mesh, "param")
    c_specs = None
    if batch:
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(batch, seq_len))
        c_specs = _dims_tree_specs(cache_shapes, model.cache_dims(), mesh, "act")
    return p_specs, c_specs


def _dims_tree_specs(shapes, dims, mesh, kind):
    """tree.map over two trees whose leaves are ShapeDtypeStruct / str-tuple."""
    flat_s, treedef = jax.tree_util.tree_flatten(shapes)
    flat_d = treedef.flatten_up_to(dims)
    out = [spec_for(d, s.shape, mesh, kind=kind) for s, d in zip(flat_s, flat_d)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_input_specs(specs: dict, mesh: Mesh) -> dict:
    """PartitionSpecs for input_specs() stand-ins: leading dim = batch."""
    has_pod = "pod" in mesh.axis_names
    out = {}
    for name, sds in specs.items():
        if sds.ndim == 0:
            out[name] = P()
            continue
        dims = ("batch",) + ("seq",) * (sds.ndim - 1)
        if name == "cond":
            dims = ("batch", "seq", "d_model_like")
        out[name] = spec_for(dims, sds.shape, mesh, kind="act")
    return out
