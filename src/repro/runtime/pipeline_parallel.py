"""GPipe-style pipeline parallelism over a mesh axis via shard_map + ppermute.

Each device on the pipeline axis holds one contiguous stage of layers.
Microbatches stream through: at tick t, stage s computes microbatch t−s and
passes its activation to stage s+1 with ``collective_permute``; total ticks =
n_micro + n_stages − 1 (the classic bubble). This is the cross-pod option
for models whose layer stacks exceed one pod's HBM; the default multi-pod
config uses the pod axis as DP instead (launch/mesh.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax ≥ 0.6 exposes shard_map at the top level (with check_vma)
    from jax import shard_map as _shard_map
    _SM_NOCHECK = {"check_vma": False}
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_NOCHECK = {"check_rep": False}


def _mark_varying(tree, axis):
    """pcast-to-varying where the API exists (jax ≥ 0.7); no-op before."""
    if hasattr(jax.lax, "pcast"):
        return jax.tree.map(
            lambda z: jax.lax.pcast(z, (axis,), to="varying"), tree)
    return tree


def pipeline_apply(stage_fn, stage_params, x_micro, mesh: Mesh, axis: str):
    """Run a pipelined stack.

    stage_fn(params_for_one_stage, x) → x  (same shape)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``)
    x_micro: (n_micro, mb, ...) microbatched inputs (replicated)
    Returns (n_micro, mb, ...) outputs of the last stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1

    def local(params_local, x_all):
        # params_local: leading dim 1 (this stage); x_all replicated
        p_stage = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t; others take the permuted input
            feed = jnp.where(t < n_micro, t, 0)
            injected = x_all[feed]
            state = jnp.where(stage_id == 0, injected, state)
            out = stage_fn(p_stage, state)
            # last stage records its finished microbatch (t - (n_stages-1))
            done_idx = t - (n_stages - 1)
            do_write = (stage_id == n_stages - 1) & (done_idx >= 0)
            outputs = jax.lax.cond(
                do_write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(done_idx, 0), 0),
                lambda o: o,
                outputs)
            # shift downstream: stage s → s+1
            state = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (state, outputs), None

        init = (jnp.zeros(mb_shape, x_all.dtype),
                jnp.zeros((n_micro,) + mb_shape, x_all.dtype))
        init = _mark_varying(init, axis)
        (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(n_ticks))
        # every stage holds an `outputs` buffer; only the last stage's is
        # real — zero the rest and psum to replicate it everywhere
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs, 0.0), axis)
        return outputs

    fn = _shard_map(
        local, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params,
                               is_leaf=lambda x: hasattr(x, "shape")), P()),
        out_specs=P(),
        **_SM_NOCHECK,
    )
    return fn(stage_params, x_micro)
