from repro.utils.timing import Timer, timed
from repro.utils.counters import ComputeCounter

__all__ = ["Timer", "timed", "ComputeCounter"]
