"""Lightweight wall-clock timing helpers used by benchmarks and the runtime."""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Accumulating named timer. ``with timer.section("x"): ...``"""

    totals: dict = field(default_factory=dict)
    counts: dict = field(default_factory=dict)

    @contextlib.contextmanager
    def section(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self.totals[name] = self.totals.get(name, 0.0) + dt
            self.counts[name] = self.counts.get(name, 0) + 1

    def total(self, name: str) -> float:
        return self.totals.get(name, 0.0)

    def summary(self) -> str:
        return " ".join(f"{k}={v:.3f}s" for k, v in sorted(self.totals.items()))


@contextlib.contextmanager
def timed(out: dict, name: str):
    t0 = time.perf_counter()
    try:
        yield
    finally:
        out[name] = out.get(name, 0.0) + time.perf_counter() - t0
