"""Computation-count accounting.

The paper measures efficiency with two metrics: wall time and the number of
"computations" (per-pair per-value score evaluations; examples in §III-V:
PAIRWISE on the motivating example conducts 366 computations, INDEX 154,
BOUND 116). Wall time on this CPU container is not comparable with the
paper's Java/TPU numbers, so every detection algorithm in ``repro.core``
additionally reports these hardware-independent counts, computed with the
paper's own accounting rules:

* examining a shared value for a pair costs 2 computations (one for C→,
  one for C←);
* the per-pair different-value adjustment (step 3 of INDEX) costs 2;
* evaluating a min/max bound for a pair costs 1 per bound (Ex. 4.2 counts
  4 + 1 = 5 for two bound evaluations plus ... consistent with §IV examples);
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class ComputeCounter:
    pairs_considered: int = 0
    shared_values_examined: int = 0
    score_computations: int = 0
    bound_computations: int = 0
    index_entries: int = 0

    @property
    def total(self) -> int:
        return self.score_computations + self.bound_computations

    def merge(self, other: "ComputeCounter") -> "ComputeCounter":
        return ComputeCounter(
            pairs_considered=self.pairs_considered + other.pairs_considered,
            shared_values_examined=self.shared_values_examined + other.shared_values_examined,
            score_computations=self.score_computations + other.score_computations,
            bound_computations=self.bound_computations + other.bound_computations,
            index_entries=max(self.index_entries, other.index_entries),
        )

    def as_dict(self) -> dict:
        return {
            "pairs_considered": self.pairs_considered,
            "shared_values_examined": self.shared_values_examined,
            "score_computations": self.score_computations,
            "bound_computations": self.bound_computations,
            "total_computations": self.total,
        }
