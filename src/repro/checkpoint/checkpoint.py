"""Checkpointing: atomic, async-capable, keep-K, elastic-restore.

Format: one ``step_<n>/`` directory per checkpoint with
  * ``arrays.npz``   — flattened leaves keyed by tree path
  * ``manifest.json``— step, leaf paths, shapes/dtypes, user metadata
Writes go to ``step_<n>.tmp/`` and are renamed into place (atomic on POSIX),
so a host failure mid-save never corrupts the latest checkpoint. Restore
re-places leaves onto whatever mesh/sharding the *current* run uses — the
saved arrays are logical (unsharded), which is what makes elastic restarts
(different data-axis size) work: test_checkpoint.py exercises a 4→8 device
resize.

At real scale the arrays.npz leaf store would be swapped for a sharded
tensorstore/OCDBT backend; the manager API (save/restore/latest/keep-K,
async) is the production surface.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(k) for k, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in host_leaves],
        "dtypes": [str(a.dtype) for a in host_leaves],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(directory: str, template, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``template``. If ``shardings`` (a tree of
    NamedSharding) is given, leaves are placed sharded (elastic restore)."""
    step_dir = (os.path.join(directory, f"step_{step:08d}") if step is not None
                else latest_checkpoint(directory))
    if step_dir is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
    t_paths, t_leaves, treedef = _flatten(template)
    assert t_paths == manifest["paths"], "checkpoint/template structure mismatch"
    if shardings is not None:
        s_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        placed = [jax.device_put(a.astype(t.dtype), s)
                  for a, t, s in zip(leaves, t_leaves, s_leaves)]
    else:
        placed = [jax.numpy.asarray(a.astype(t.dtype)) for a, t in zip(leaves, t_leaves)]
    return jax.tree_util.tree_unflatten(treedef, placed), manifest


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, steps[-1]) if steps else None


class CheckpointManager:
    """keep-K rotation + optional async saves."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        # snapshot to host synchronously (cheap); write in the background
        paths, leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            save_checkpoint(self.directory, step, snapshot, metadata)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, template, shardings=None, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.directory, template, step=step,
                               shardings=shardings)

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        p = latest_checkpoint(self.directory)
        return int(os.path.basename(p).split("_")[1]) if p else None
