"""Flash attention (fwd + bwd) as Pallas TPU kernels.

Used by the LM stack for training and prefill. Supports:
  * causal masking,
  * sliding-window attention (hymba's SWA layers),
  * GQA/MQA — k/v blocks are indexed through head_q // group so kv heads are
    never materialized per q head in the forward pass.

Tiling: q blocks (block_q × head_dim) stream against k/v blocks
(block_k × head_dim) with the online-softmax running (m, l, acc) state held
in VMEM scratch across the innermost k grid dimension. Both matmul dims are
multiples of the MXU tile for head_dim ∈ {64, 128, 256}.

VMEM per step (block_q = block_k = 128, D = 128, bf16):
  q,k,v,o tiles ≈ 4·128·128·2 B = 128 KiB; scratch acc 64 KiB; ≪ 16 MiB.

Backward follows the standard two-kernel split (dq over k-blocks; dk/dv over
q-blocks) with the forward's logsumexp as residual; dk/dv are produced per
q-head and group-summed outside (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _visible(qi, ki, block_q, block_k, causal, window):
    """Is any (q, k) pair in this block pair unmasked?"""
    ok = jnp.bool_(True)
    if causal:
        ok &= (qi + 1) * block_q - 1 >= ki * block_k
    if window is not None:
        ok &= qi * block_q - ((ki + 1) * block_k - 1) < window
    return ok


def _block_mask(qi, ki, block_q, block_k, causal, window):
    qids = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kids = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= qids >= kids
    if window is not None:
        mask &= (qids - kids) < window
    return mask


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr,
                *, sm_scale, causal, window, block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_visible(qi, ki, block_q, block_k, causal, window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = _block_mask(qi, ki, block_q, block_k, causal, window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(safe_l))[:, 0]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_fwd(q, k, v, *, causal=True, sm_scale=None, window=None,
                        block_q=128, block_k=128, interpret=False):
    """q (B,Hq,Sq,D); k,v (B,Hkv,Sk,D). Returns (o, lse)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    n_q = Sq // block_q
    n_k = Sk // block_k

    kernel = functools.partial(
        _fwd_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    grid = (B * Hq, n_q, n_k)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda g, qi, ki: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda g, qi, ki: (g // Hq, (g % Hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda g, qi, ki: (g // Hq, (g % Hq) // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda g, qi, ki: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda g, qi, ki: (g // Hq, g % Hq, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, sm_scale, causal, window, block_q, block_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    @pl.when(_visible(qi, ki, block_q, block_k, causal, window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = _block_mask(qi, ki, block_q, block_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * sm_scale
        dq_ref[0, 0] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32
                                    ).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref,
                    *, sm_scale, causal, window, block_q, block_k):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    @pl.when(_visible(qi, ki, block_q, block_k, causal, window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = _block_mask(qi, ki, block_q, block_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)     # (bq, bk)
        dv_ref[0, 0] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dv_ref.dtype)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * sm_scale               # (bq, bk)
        dk_ref[0, 0] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dk_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, sm_scale=None,
                        window=None, block_q=128, block_k=128, interpret=False):
    """Flash-attention backward pass: (dq, dk, dv) from the saved (o, lse).

    Shapes mirror the forward: q (B, Hq, S, D); k, v (B, Hkv, S, D) with
    Hq % Hkv == 0 (GQA); do like o. Three pallas_calls (dq; dk+dv fused)
    over the same (batch·head, q-block, k-block) grid as the forward."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    n_q, n_k = Sq // block_q, Sk // block_k
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    q_spec = pl.BlockSpec((1, 1, block_q, D), lambda g, a, b: (g // Hq, g % Hq, 0, 0))
    common = dict(sm_scale=scale, causal=causal, window=window,
                  block_q=block_q, block_k=block_k)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **common),
        grid=(B * Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda g, qi, ki: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda g, qi, ki: (g // Hq, (g % Hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda g, qi, ki: (g // Hq, (g % Hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda g, qi, ki: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda g, qi, ki: (g // Hq, g % Hq, qi)),
            pl.BlockSpec((1, 1, block_q), lambda g, qi, ki: (g // Hq, g % Hq, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda g, qi, ki: (g // Hq, g % Hq, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv per q-head, then group-sum → kv heads (GQA)
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **common),
        grid=(B * Hq, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda g, ki, qi: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda g, ki, qi: (g // Hq, (g % Hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda g, ki, qi: (g // Hq, (g % Hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda g, ki, qi: (g // Hq, g % Hq, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda g, ki, qi: (g // Hq, g % Hq, qi)),
            pl.BlockSpec((1, 1, block_q), lambda g, ki, qi: (g // Hq, g % Hq, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda g, ki, qi: (g // Hq, g % Hq, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda g, ki, qi: (g // Hq, g % Hq, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sk, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq, Sk, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk = dk_h.reshape(B, Hkv, group, Sk, D).sum(axis=2)
    dv = dv_h.reshape(B, Hkv, group, Sk, D).sum(axis=2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
