from repro.kernels.ops import copyscore, copyscore_tile_fused, flash_attention

__all__ = ["copyscore", "copyscore_tile_fused", "flash_attention"]
