from repro.kernels.ops import copyscore, flash_attention

__all__ = ["copyscore", "flash_attention"]
