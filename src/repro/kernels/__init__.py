"""Pallas TPU kernels with jnp oracles: copyscore (DESIGN.md §3.3) and
flash attention; ``repro.kernels.ops`` holds the dispatching wrappers."""
from repro.kernels.ops import copyscore, copyscore_tile_fused, flash_attention

__all__ = ["copyscore", "copyscore_tile_fused", "flash_attention"]
