"""Pallas TPU kernels for the bucketed copy-score accumulation (DESIGN.md §2.1).

The hot loop of scalable copy detection is

    C_same→[i,j] = Σ_e V[i,e]·V[j,e]·f→(A_i, A_j, p_e)
    n[i,j]       = Σ_e V[i,e]·V[j,e]

with entries pre-sorted so that every contiguous block of ``block_e`` entries
shares one representative probability p̂ (bucket-aligned padding done by
``ops.copyscore``). Within a block the pair score f→ is constant per (i,j),
so each grid step is ONE (block_i × block_e) @ (block_e × block_j) MXU matmul
plus one VPU elementwise combine — arithmetic intensity ≈ block_e FLOPs/byte
on the C tiles instead of the O(1) a naive gather implementation would get.

Two kernel families:

``copyscore_pallas``        — single-direction (C_same→, n[, err]); kept for
                              the full-square ``ops.copyscore`` wrapper and as
                              the legacy baseline the kernel microbenchmark
                              compares against.
``copyscore_fused_pallas``  — the production dual-direction kernel (DESIGN.md
                              §3). Copy detection is symmetric at heart: every
                              unordered pair needs both C→ and C← before a
                              decision, and the count matmul is shared. One
                              matmul per entry block feeds FIVE accumulators —
                              C_same→, C_same← (f→/f← only swap the a1/a2
                              roles in the VPU combine), the shared count, the
                              non-Ē count (a per-block 0/1 mask channel that
                              replaces the separate full-incidence matmul the
                              tiled path used to do), and the p̂-error bound.
                              int8 incidence takes the exact int32 MXU
                              accumulation path (counts are ≤ block_e ≪ 2³¹),
                              halving HBM traffic vs bf16.

Grid: (S/bi, S/bj, E/be) with the entry dimension innermost so the output
tiles live in VMEM across the whole reduction (revisited-output accumulation).

VMEM budget per step (defaults bi=bj=128, be=512, int8 V, fused):
  V_i, V_j tiles:    2 · 128·512·1 B = 128 KiB
  5 accum tiles:     5 · 128·128·4 B = 320 KiB
  A_i, A_j, scalars: ~1 KiB                        → ≈ 0.45 MiB ≪ 16 MiB VMEM.
MXU work per step: 128·512·128 MACs with both matmul dims multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_matmul(vi, vj):
    """The shared count matmul. int8 incidence accumulates exactly on the MXU
    in int32 (0/1 products, partial sums ≤ block_e); floats accumulate in f32."""
    if vi.dtype == jnp.int8:
        return jax.lax.dot_general(
            vi, vj, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
    return jax.lax.dot_general(
        vi, vj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _copyscore_kernel(p_ref, vi_ref, vj_ref, ai_ref, aj_ref,
                      c_ref, n_ref, *, s: float, n_false: float):
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    count = _count_matmul(vi_ref[...], vj_ref[...])    # (bi, bj) on the MXU

    p = p_ref[0, 0]
    a1 = ai_ref[...].astype(jnp.float32)               # (bi, 1) copier accuracy
    a2 = aj_ref[...].astype(jnp.float32).reshape(1, -1)  # (1, bj) source accuracy
    pr_src = p * a2 + (1.0 - p) * (1.0 - a2)
    pr_ind = p * a1 * a2 + (1.0 - p) * (1.0 - a1) * (1.0 - a2) / n_false
    f = jnp.log(1.0 - s + s * pr_src / pr_ind)         # Eq. (6), per pair

    c_ref[...] += f * count
    n_ref[...] += count


def _copyscore_err_kernel(p_ref, d_ref, vi_ref, vj_ref, ai_ref, aj_ref,
                          c_ref, n_ref, err_ref, *, s: float, n_false: float):
    """copyscore + an error-bound channel: err += δ_block · count, where
    δ_block bounds |f(·,·,p) − f(·,·,p̂)| over the block's true p range. The
    engine exactly rescores every pair whose decision margin is inside its
    accumulated bound, keeping binary decisions equal to the exact INDEX."""
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        err_ref[...] = jnp.zeros_like(err_ref)

    count = _count_matmul(vi_ref[...], vj_ref[...])

    p = p_ref[0, 0]
    a1 = ai_ref[...].astype(jnp.float32)
    a2 = aj_ref[...].astype(jnp.float32).reshape(1, -1)
    pr_src = p * a2 + (1.0 - p) * (1.0 - a2)
    pr_ind = p * a1 * a2 + (1.0 - p) * (1.0 - a1) * (1.0 - a2) / n_false
    f = jnp.log(1.0 - s + s * pr_src / pr_ind)

    c_ref[...] += f * count
    n_ref[...] += count
    err_ref[...] += d_ref[0, 0] * count


@functools.partial(
    jax.jit,
    static_argnames=("s", "n_false", "block_i", "block_j", "block_e", "interpret"),
)
def copyscore_pallas(
    v: jnp.ndarray,          # (S_i, E) incidence, bf16/f32; E % block_e == 0
    p_blk: jnp.ndarray,      # (E // block_e,) representative p̂ per entry block
    acc: jnp.ndarray,        # (S_i,) source accuracies, f32
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    block_e: int = 512,
    interpret: bool = False,
    v_cols: jnp.ndarray | None = None,    # (S_j, E) column-block incidence
    acc_cols: jnp.ndarray | None = None,  # (S_j,)
    delta_blk: jnp.ndarray | None = None,  # (E // block_e,) error bound δ
):
    """Returns (C_same→ (S_i,S_j) f32, n (S_i,S_j) f32)[, err (S_i,S_j) f32].

    Square by default (v vs itself); passing ``v_cols``/``acc_cols`` computes
    a rectangular pair tile — rows copy from columns — which is how the
    DetectionEngine feeds one pruned tile of the S×S pair space at a time.
    With ``delta_blk``, a third output accumulates the per-pair score-error
    bound Σ δ_blk·count (the engine's exact-rescore trigger). Row/column
    counts must divide by their block sizes.
    """
    vj = v if v_cols is None else v_cols
    accj = acc if acc_cols is None else acc_cols
    S_i, E = v.shape
    S_j = vj.shape[0]
    assert S_i % block_i == 0 and S_j % block_j == 0, (S_i, S_j, block_i, block_j)
    assert E % block_e == 0, (E, block_e)
    n_e = E // block_e

    p2 = p_blk.reshape(n_e, 1).astype(jnp.float32)
    a_i = acc.reshape(S_i, 1).astype(jnp.float32)
    a_j = accj.reshape(S_j, 1).astype(jnp.float32)

    grid = (S_i // block_i, S_j // block_j, n_e)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, e: (e, 0))
    in_specs = [
        scalar_spec,                                             # p̂
        pl.BlockSpec((block_i, block_e), lambda i, j, e: (i, e)),  # V rows
        pl.BlockSpec((block_j, block_e), lambda i, j, e: (j, e)),  # V cols
        pl.BlockSpec((block_i, 1), lambda i, j, e: (i, 0)),      # A_i
        pl.BlockSpec((block_j, 1), lambda i, j, e: (j, 0)),      # A_j
    ]
    out_spec = pl.BlockSpec((block_i, block_j), lambda i, j, e: (i, j))
    out_sds = jax.ShapeDtypeStruct((S_i, S_j), jnp.float32)

    if delta_blk is None:
        kernel = functools.partial(_copyscore_kernel, s=float(s),
                                   n_false=float(n_false))
        c, n = pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs,
            out_specs=[out_spec, out_spec], out_shape=[out_sds, out_sds],
            interpret=interpret,
        )(p2, v, vj, a_i, a_j)
        return c, n

    d2 = delta_blk.reshape(n_e, 1).astype(jnp.float32)
    kernel = functools.partial(_copyscore_err_kernel, s=float(s),
                               n_false=float(n_false))
    c, n, err = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[in_specs[0], scalar_spec] + in_specs[1:],
        out_specs=[out_spec, out_spec, out_spec],
        out_shape=[out_sds, out_sds, out_sds],
        interpret=interpret,
    )(p2, d2, v, vj, a_i, a_j)
    return c, n, err


def _copyscore_fused_kernel(p_ref, d_ref, m_ref, vi_ref, vj_ref, ai_ref, aj_ref,
                            cf_ref, cb_ref, n_ref, o_ref, e_ref,
                            *, s: float, n_false: float):
    """Dual-direction copyscore: ONE count matmul per entry block feeds both
    tile orientations plus the count / non-Ē-count / error-bound channels.

    f→ scores rows-copy-from-columns; f← scores columns-copy-from-rows, which
    only swaps which accuracy plays the copied-source role in Pr(Φ_D(S2))
    (Pr-independent is symmetric in A1/A2). So C←[i,j] = f←·count accumulates
    the (col, row) orientation of the same tile — the engine scatters its
    transpose at the mirrored tile coordinate and never schedules (c, r).
    """
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        cf_ref[...] = jnp.zeros_like(cf_ref)
        cb_ref[...] = jnp.zeros_like(cb_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        o_ref[...] = jnp.zeros_like(o_ref)
        e_ref[...] = jnp.zeros_like(e_ref)

    count = _count_matmul(vi_ref[...], vj_ref[...])    # (bi, bj)

    p = p_ref[0, 0]
    a1 = ai_ref[...].astype(jnp.float32)               # (bi, 1) row accuracy
    a2 = aj_ref[...].astype(jnp.float32).reshape(1, -1)  # (1, bj) col accuracy
    # pr_ind associates the accuracy products symmetrically (a1·a2 first), so
    # it is bitwise invariant under a1↔a2 — on a diagonal tile C← == C→ᵀ
    # exactly, which the engine relies on when scattering both orientations
    pr_ind = p * (a1 * a2) + (1.0 - p) * ((1.0 - a1) * (1.0 - a2)) / n_false
    f_fwd = jnp.log(1.0 - s + s * (p * a2 + (1.0 - p) * (1.0 - a2)) / pr_ind)
    f_bwd = jnp.log(1.0 - s + s * (p * a1 + (1.0 - p) * (1.0 - a1)) / pr_ind)

    cf_ref[...] += f_fwd * count
    cb_ref[...] += f_bwd * count
    n_ref[...] += count
    o_ref[...] += m_ref[0, 0] * count                  # non-Ē blocks only
    e_ref[...] += d_ref[0, 0] * count


@functools.partial(
    jax.jit,
    static_argnames=("s", "n_false", "block_i", "block_j", "block_e", "interpret"),
)
def copyscore_fused_pallas(
    v: jnp.ndarray,          # (S_i, E) incidence, int8/bf16/f32; E % block_e == 0
    p_blk: jnp.ndarray,      # (E // block_e,) representative p̂ per entry block
    acc: jnp.ndarray,        # (S_i,) source accuracies, f32
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    block_e: int = 512,
    interpret: bool = False,
    v_cols: jnp.ndarray | None = None,    # (S_j, E) column-block incidence
    acc_cols: jnp.ndarray | None = None,  # (S_j,)
    delta_blk: jnp.ndarray | None = None,  # (E // block_e,) error bound δ
    nout_blk: jnp.ndarray | None = None,   # (E // block_e,) 1.0 ⇔ block ∉ Ē
):
    """Fused dual-direction copyscore over one (rectangular) pair tile.

    Returns five (S_i, S_j) f32 arrays: (C_same→, C_same←, n, n_out, err).
    C_same← is the columns-copy-from-rows orientation — its transpose is the
    mirrored tile's C_same→, so a triangular (r ≤ c) schedule covers the full
    pair space. ``nout_blk`` masks which entry blocks count toward n_out (the
    engine's considered test: blocks before the Ē boundary); default all.
    ``delta_blk`` defaults to zero (no error channel accumulation).
    """
    vj = v if v_cols is None else v_cols
    accj = acc if acc_cols is None else acc_cols
    S_i, E = v.shape
    S_j = vj.shape[0]
    assert S_i % block_i == 0 and S_j % block_j == 0, (S_i, S_j, block_i, block_j)
    assert E % block_e == 0, (E, block_e)
    n_e = E // block_e

    p2 = p_blk.reshape(n_e, 1).astype(jnp.float32)
    d_blk = jnp.zeros(n_e) if delta_blk is None else delta_blk
    m_blk = jnp.ones(n_e) if nout_blk is None else nout_blk
    d2 = d_blk.reshape(n_e, 1).astype(jnp.float32)
    m2 = m_blk.reshape(n_e, 1).astype(jnp.float32)
    a_i = acc.reshape(S_i, 1).astype(jnp.float32)
    a_j = accj.reshape(S_j, 1).astype(jnp.float32)

    grid = (S_i // block_i, S_j // block_j, n_e)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, e: (e, 0))
    in_specs = [
        scalar_spec,                                             # p̂
        scalar_spec,                                             # δ
        scalar_spec,                                             # non-Ē mask
        pl.BlockSpec((block_i, block_e), lambda i, j, e: (i, e)),  # V rows
        pl.BlockSpec((block_j, block_e), lambda i, j, e: (j, e)),  # V cols
        pl.BlockSpec((block_i, 1), lambda i, j, e: (i, 0)),      # A_i
        pl.BlockSpec((block_j, 1), lambda i, j, e: (j, 0)),      # A_j
    ]
    out_spec = pl.BlockSpec((block_i, block_j), lambda i, j, e: (i, j))
    out_sds = jax.ShapeDtypeStruct((S_i, S_j), jnp.float32)

    kernel = functools.partial(_copyscore_fused_kernel, s=float(s),
                               n_false=float(n_false))
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs,
        out_specs=[out_spec] * 5, out_shape=[out_sds] * 5,
        interpret=interpret,
    )(p2, d2, m2, v, vj, a_i, a_j)
