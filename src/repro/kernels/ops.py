"""Jit'd public wrappers for the Pallas kernels.

``copyscore``      — pads sources/entries to block multiples, dispatches to
                     the Pallas kernel (TPU) or its jnp oracle (CPU/dry-run).
``copyscore_store``— the chunked-store dispatch (DESIGN.md §6): streams a
                     ``CorpusStore``'s entry chunks through the kernel one
                     at a time, accumulating on the host — peak incidence
                     residency is one chunk, results bit-equal to the dense
                     ``copyscore`` (f32 additions happen in the same order).
``flash_attention``— differentiable (custom_vjp) flash attention; dispatches
                     to the Pallas kernels on TPU, interpret mode in tests,
                     and the jnp reference on CPU otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.kernels.copyscore import copyscore_fused_pallas, copyscore_pallas
from repro.kernels.flash_attention import flash_attention_bwd, flash_attention_fwd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# copyscore
# ---------------------------------------------------------------------------

def pad_for_copyscore(v: np.ndarray, p_blk: np.ndarray, block_i: int,
                      block_e: int, bucket_sizes=None):
    """Pad the incidence matrix to kernel block multiples.

    If ``bucket_sizes`` is given (entries grouped by representative p), each
    bucket is padded independently to a ``block_e`` multiple so every entry
    block has one p̂; otherwise entries must already be block-aligned.
    Zero columns/rows are inert. Returns (v_pad, p_blk_pad, S_orig).
    """
    S, E = v.shape
    if bucket_sizes is not None:
        cols, pb = [], []
        off = 0
        for k, size in enumerate(bucket_sizes):
            blk = v[:, off: off + size]
            pad = (-size) % block_e
            if pad:
                blk = np.pad(blk, ((0, 0), (0, pad)))
            cols.append(blk)
            pb.extend([p_blk[k]] * (blk.shape[1] // block_e))
            off += size
        v = np.concatenate(cols, axis=1) if cols else v
        p_blk = np.asarray(pb, dtype=np.float32)
    s_pad = (-S) % block_i
    if s_pad:
        v = np.pad(v, ((0, s_pad), (0, 0)))
    return v, p_blk, S


def copyscore(
    v,                      # (S, E) incidence (entries block-aligned in p)
    p_blk,                  # (E // block_e,) representative p̂ per block
    acc,                    # (S,) accuracies
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    block_e: int = 512,
    impl: str = "auto",     # auto | pallas | interpret | ref
):
    """C_same→ and shared counts over the whole index. See copyscore.py."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return kref.copyscore_ref(jnp.asarray(v), jnp.asarray(p_blk),
                                  jnp.asarray(acc), s=s, n_false=n_false,
                                  block_e=block_e)
    S = v.shape[0]
    pad = (-S) % block_i
    if pad:
        v = jnp.pad(jnp.asarray(v), ((0, pad), (0, 0)))
        acc = jnp.pad(jnp.asarray(acc), (0, pad), constant_values=0.5)
    c, n = copyscore_pallas(
        jnp.asarray(v), jnp.asarray(p_blk), jnp.asarray(acc),
        s=s, n_false=n_false, block_i=block_i, block_j=block_j,
        block_e=block_e, interpret=(impl == "interpret"))
    return c[:S, :S], n[:S, :S]


def copyscore_store(
    store,                  # core.store.CorpusStore — entry-chunked incidence
    p_hat,                  # (n_chunks,) representative p̂ per chunk
    acc,                    # (S,) accuracies
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    impl: str = "auto",     # auto | pallas | interpret | ref
):
    """Full-square C_same→ / shared counts streamed from a chunked store.

    Each chunk is one kernel entry block carrying one representative p̂ —
    the chunked twin of ``copyscore`` over a dense, bucket-aligned
    incidence, with the incidence only ever resident one chunk at a time.
    The per-chunk outputs are accumulated on the host in float32 in chunk
    order: counts are BIT-equal to one dense call (0/1 sums stay integer-
    exact), scores agree to f32 round-off (same addition order, but each
    chunk's elementwise score math compiles separately and may fuse
    differently than inside the dense scan). Asserted by
    tests/test_store.py.

    Chunks with no live entry (all-padding columns — a committed store's
    region alignment can produce them, DESIGN.md §7) contribute zero to
    every channel and are skipped without a kernel launch.
    """
    S = store.n_rows
    p_hat = np.asarray(p_hat, np.float32)
    c = np.zeros((S, S), np.float32)
    n = np.zeros((S, S), np.float32)
    for k, ch in enumerate(store.iter_chunks()):
        if ch.item.size and not (ch.item >= 0).any():
            continue
        ck, nk = copyscore(
            ch.V.astype(np.float32), p_hat[k: k + 1], acc,
            s=s, n_false=n_false, block_i=block_i, block_j=block_j,
            block_e=ch.width, impl=impl)
        c += np.asarray(ck, np.float32)
        n += np.asarray(nk, np.float32)
    return c, n


def copyscore_tile(
    v_rows,                 # (T_r, E) row-block incidence, entries bucket-aligned
    v_cols,                 # (T_c, E) column-block incidence
    p_blk,                  # (E // block_e,) representative p̂ per entry block
    acc_rows,               # (T_r,) copier accuracies
    acc_cols,               # (T_c,) source accuracies
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    block_e: int = 512,
    impl: str = "auto",     # auto | pallas | interpret | ref
    delta_blk=None,         # (E // block_e,) per-block score-error bound
):
    """One rectangular tile of the pair space: C_same→ and counts, rows→cols.

    The DetectionEngine calls this once per surviving pair tile (inside a
    shard_mapped scan), with each bucket zero-padded to ``block_e`` so every
    kernel entry-block carries a single p̂. With ``delta_blk`` a third output
    accumulates the per-pair approximation-error bound Σ δ·count. Tile edges
    must divide by the pair blocks (the engine pads the source axis once,
    up front).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    delta = None if delta_blk is None else jnp.asarray(delta_blk)
    if impl == "ref":
        return kref.copyscore_ref(
            jnp.asarray(v_rows), jnp.asarray(p_blk), jnp.asarray(acc_rows),
            v_cols=jnp.asarray(v_cols), acc_cols=jnp.asarray(acc_cols),
            s=s, n_false=n_false, block_e=block_e, delta_blk=delta)
    return copyscore_pallas(
        jnp.asarray(v_rows), jnp.asarray(p_blk), jnp.asarray(acc_rows),
        v_cols=jnp.asarray(v_cols), acc_cols=jnp.asarray(acc_cols),
        s=s, n_false=n_false, block_i=block_i, block_j=block_j,
        block_e=block_e, interpret=(impl == "interpret"), delta_blk=delta)


def copyscore_tile_fused(
    v_rows,                 # (T_r, E) row-block incidence, entries bucket-aligned
    v_cols,                 # (T_c, E) column-block incidence
    p_blk,                  # (E // block_e,) representative p̂ per entry block
    acc_rows,               # (T_r,) row accuracies
    acc_cols,               # (T_c,) column accuracies
    *,
    s: float,
    n_false: float,
    block_i: int = 128,
    block_j: int = 128,
    block_e: int = 512,
    impl: str = "auto",     # auto | pallas | interpret | ref
    delta_blk=None,         # (E // block_e,) per-block score-error bound
    nout_blk=None,          # (E // block_e,) 1.0 ⇔ block outside Ē
):
    """One unordered pair tile, both directions: (C→, C←, n, n_out, err).

    The production dataflow (DESIGN.md §3): the DetectionEngine schedules only
    upper-triangular (r ≤ c) surviving tiles and scatters C← transposed at the
    mirrored coordinate, so each unordered tile is computed exactly once —
    one count matmul per entry block feeds all five channels (the n_out mask
    channel replaces the legacy separate non-Ē incidence matmul).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    delta = None if delta_blk is None else jnp.asarray(delta_blk)
    nout = None if nout_blk is None else jnp.asarray(nout_blk)
    if impl == "ref":
        return kref.copyscore_fused_ref(
            jnp.asarray(v_rows), jnp.asarray(p_blk), jnp.asarray(acc_rows),
            v_cols=jnp.asarray(v_cols), acc_cols=jnp.asarray(acc_cols),
            s=s, n_false=n_false, block_e=block_e, delta_blk=delta,
            nout_blk=nout)
    return copyscore_fused_pallas(
        jnp.asarray(v_rows), jnp.asarray(p_blk), jnp.asarray(acc_rows),
        v_cols=jnp.asarray(v_cols), acc_cols=jnp.asarray(acc_cols),
        s=s, n_false=n_false, block_i=block_i, block_j=block_j,
        block_e=block_e, interpret=(impl == "interpret"), delta_blk=delta,
        nout_blk=nout)


# ---------------------------------------------------------------------------
# flash attention (differentiable)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, sm_scale, window, block_q, block_k, interpret):
    o, _ = flash_attention_fwd(q, k, v, causal=causal, sm_scale=sm_scale,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, sm_scale, window, block_q, block_k, interpret):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, sm_scale=sm_scale,
                                 window=window, block_q=block_q,
                                 block_k=block_k, interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, sm_scale, window, block_q, block_k, interpret,
               res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, sm_scale=sm_scale, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, sm_scale=None, window=None,
                    block_q=128, block_k=128, impl="auto"):
    """Differentiable attention. q (B,Hq,S,D); k,v (B,Hkv,S,D).

    impl: auto → Pallas on TPU, jnp reference elsewhere;
          pallas / interpret / ref force a path (tests use interpret).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl in ("ref", "reference"):
        if q.shape[2] >= 8192:
            # long sequences: O(chunk·S) memory instead of O(S²)
            return kref.attention_chunked(q, k, v, causal=causal,
                                          sm_scale=sm_scale, window=window)
        return kref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale,
                                  window=window)
    if impl == "chunked":
        return kref.attention_chunked(q, k, v, causal=causal,
                                      sm_scale=sm_scale, window=window)
    if impl == "chunked_unroll":
        return kref.attention_chunked(q, k, v, causal=causal,
                                      sm_scale=sm_scale, window=window,
                                      unroll=True)
    return _flash(q, k, v, causal, sm_scale, window, block_q, block_k,
                  impl == "interpret")
