"""Pure-jnp oracles for every Pallas kernel in this package.

Each function has identical semantics (including block-constant
approximations) to its kernel so tests can assert allclose.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# copyscore
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("s", "n_false", "block_e"))
def copyscore_ref(v, p_blk, acc, *, s, n_false, block_e=512,
                  v_cols=None, acc_cols=None, delta_blk=None):
    """Block-constant-p copy-score accumulation; oracle for copyscore_pallas.

    Like the kernel, ``v_cols``/``acc_cols`` select a rectangular pair tile
    (rows copy from columns); omitted, it computes the full square S×S.
    ``delta_blk`` adds the error-bound channel err = Σ δ_blk·count.
    """
    vj = v if v_cols is None else v_cols
    accj = acc if acc_cols is None else acc_cols
    S_i, E = v.shape
    S_j = vj.shape[0]
    n_e = E // block_e
    vi_f = v.astype(jnp.float32).reshape(S_i, n_e, block_e)
    vj_f = vj.astype(jnp.float32).reshape(S_j, n_e, block_e)
    a1 = acc.astype(jnp.float32)[:, None]
    a2 = accj.astype(jnp.float32)[None, :]
    with_err = delta_blk is not None
    d_blk = (delta_blk if with_err else jnp.zeros(n_e)).astype(jnp.float32)

    def body(carry, xs):
        c, n, err = carry
        vi_k, vj_k, p_k, d_k = xs                      # (S_i, be), (S_j, be), scalars
        count = jnp.dot(vi_k, vj_k.T, preferred_element_type=jnp.float32)
        pr_src = p_k * a2 + (1.0 - p_k) * (1.0 - a2)
        pr_ind = p_k * a1 * a2 + (1.0 - p_k) * (1.0 - a1) * (1.0 - a2) / n_false
        f = jnp.log(1.0 - s + s * pr_src / pr_ind)
        return (c + f * count, n + count, err + d_k * count), None

    zero = jnp.zeros((S_i, S_j), jnp.float32)
    (c, n, err), _ = jax.lax.scan(body, (zero, zero, zero),
                                  (jnp.moveaxis(vi_f, 1, 0),
                                   jnp.moveaxis(vj_f, 1, 0),
                                   p_blk.astype(jnp.float32), d_blk))
    if with_err:
        return c, n, err
    return c, n


@partial(jax.jit, static_argnames=("s", "n_false", "block_e"))
def copyscore_fused_ref(v, p_blk, acc, *, s, n_false, block_e=512,
                        v_cols=None, acc_cols=None, delta_blk=None,
                        nout_blk=None):
    """Dual-direction oracle for ``copyscore_fused_pallas``.

    Returns (C_same→, C_same←, n, n_out, err), all (S_i, S_j) f32, from one
    shared count per entry block. C_same←[i,j] scores column j copying from
    row i — only the copied-source accuracy role swaps in f; its transpose is
    the mirrored tile's C_same→. ``nout_blk`` (default all-ones) masks which
    blocks count toward n_out; ``delta_blk`` (default zero) feeds err.
    """
    vj = v if v_cols is None else v_cols
    accj = acc if acc_cols is None else acc_cols
    S_i, E = v.shape
    S_j = vj.shape[0]
    n_e = E // block_e
    vi_f = v.astype(jnp.float32).reshape(S_i, n_e, block_e)
    vj_f = vj.astype(jnp.float32).reshape(S_j, n_e, block_e)
    a1 = acc.astype(jnp.float32)[:, None]
    a2 = accj.astype(jnp.float32)[None, :]
    d_blk = (jnp.zeros(n_e) if delta_blk is None else delta_blk).astype(jnp.float32)
    m_blk = (jnp.ones(n_e) if nout_blk is None else nout_blk).astype(jnp.float32)

    def body(carry, xs):
        cf, cb, n, n_out, err = carry
        vi_k, vj_k, p_k, d_k, m_k = xs
        count = jnp.dot(vi_k, vj_k.T, preferred_element_type=jnp.float32)
        # symmetric association (a1·a2 first): bitwise invariant under a1↔a2,
        # matching the kernel — on a diagonal tile C← == C→ᵀ exactly
        pr_ind = p_k * (a1 * a2) + (1.0 - p_k) * ((1.0 - a1) * (1.0 - a2)) / n_false
        f_fwd = jnp.log(1.0 - s + s * (p_k * a2 + (1.0 - p_k) * (1.0 - a2)) / pr_ind)
        f_bwd = jnp.log(1.0 - s + s * (p_k * a1 + (1.0 - p_k) * (1.0 - a1)) / pr_ind)
        return (cf + f_fwd * count, cb + f_bwd * count, n + count,
                n_out + m_k * count, err + d_k * count), None

    zero = jnp.zeros((S_i, S_j), jnp.float32)
    carry, _ = jax.lax.scan(body, (zero,) * 5,
                            (jnp.moveaxis(vi_f, 1, 0), jnp.moveaxis(vj_f, 1, 0),
                             p_blk.astype(jnp.float32), d_blk, m_blk))
    return carry


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def attention_chunked(q, k, v, *, causal=True, sm_scale=None, window=None,
                      chunk=2048, unroll=False):
    """Flash-style attention in pure XLA: scan over q chunks so peak memory
    is O(chunk·S) instead of O(S²). Numerically ≡ attention_ref. ``unroll``
    inlines the chunk loop (used by the dry-run probes so cost_analysis
    counts every chunk — XLA tallies a while body once).

    Memory design (EXPERIMENTS.md §Perf H1): kv heads are never repeated to
    q heads (grouped einsum over the GQA group dim), k/v stay in their input
    dtype with f32 accumulation, and sliding-window layers slice only the
    window+chunk keys each q chunk can see instead of all S of them.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    Sk = k.shape[2]
    n_chunks = Sq // chunk
    assert Sq % chunk == 0, (Sq, chunk)
    qg = q.reshape(B, Hkv, group, Sq, D)
    qc = jnp.moveaxis(qg.reshape(B, Hkv, group, n_chunks, chunk, D), 3, 0)
    kwin = min(window + chunk, Sk) if window is not None else Sk

    def one_chunk(_, qi_pair):
        qi, ci = qi_pair                                   # (B,Hkv,g,chunk,D)
        q_pos = ci * chunk + jnp.arange(chunk)[:, None]
        if window is not None:
            start = jnp.clip(ci * chunk + chunk - kwin, 0, Sk - kwin)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kwin, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kwin, axis=2)
            k_pos = start + jnp.arange(kwin)[None, :]
        else:
            ks, vs = k, v
            k_pos = jnp.arange(Sk)[None, :]
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ks,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones(q_pos.shape[:1] + k_pos.shape[1:], bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vs.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(one_chunk, None,
                           (qc, jnp.arange(n_chunks)),
                           unroll=n_chunks if unroll else 1)
    # (n_chunks, B, Hkv, g, chunk, D) → (B, Hq, Sq, D)
    outs = jnp.moveaxis(outs, 0, 3)
    return outs.reshape(B, Hq, Sq, D)


def attention_ref(q, k, v, *, causal=True, sm_scale=None, window=None):
    """Reference attention. q (B,Hq,S,D); k,v (B,Hkv,S,D) with Hq % Hkv == 0.

    window (int): sliding-window size — key j visible from query i iff
    0 ≤ i − j < window (combined with causal).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, group, Sq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    Sk = k.shape[2]
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)
