"""Adafactor (factored second moment) — the optimizer-state footprint that
keeps grok-1-314b inside HBM: ≥2-D weights store row+col factors instead of
a full second-moment tensor (O(n+m) vs O(n·m))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(decay=0.99, eps=1e-30, clip_threshold=1.0, weight_decay=0.0):
    def init(params):
        def factors(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),      # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        state = {"f": jax.tree.map(factors, params)}
        if any(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params)):
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32),
                                           params)
        return state

    def update(grads, state, params, step, lr):
        def upd(g, f, p, master):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = decay * f["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * f["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                nf = {"vr": vr, "vc": vc}
            else:
                v = decay * f["v"] + (1 - decay) * g2
                u = g * jax.lax.rsqrt(v + eps)
                nf = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * master.astype(jnp.float32)
            new_master = master.astype(jnp.float32) - lr * u
            return new_master.astype(p.dtype), nf, new_master

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        flat_p = tdef.flatten_up_to(params)
        flat_m = tdef.flatten_up_to(state.get("master", params))
        outs = [upd(g, f, p, m)
                for g, f, p, m in zip(flat_g, flat_f, flat_p, flat_m)]
        new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_f = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        new_state = {"f": new_f}
        if "master" in state:
            new_state["master"] = jax.tree_util.tree_unflatten(
                tdef, [o[2] for o in outs])
        return new_params, new_state

    def state_dims(param_dims, has_master=False):
        def fdims(d):
            if len(d) >= 2:
                return {"vr": tuple(d[:-1]), "vc": tuple(d[:-2]) + (d[-1],)}
            return {"v": tuple(d)}
        mapped = jax.tree.map(fdims, param_dims,
                              is_leaf=lambda x: isinstance(x, tuple) and
                              all(isinstance(s, str) for s in x))
        d = {"f": mapped}
        if has_master:
            d["master"] = param_dims
        return d

    return Optimizer(init=init, update=update, state_dims=state_dims)
