from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import warmup_cosine

OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}

__all__ = ["adamw", "adafactor", "warmup_cosine", "OPTIMIZERS"]
