"""Gradient compression for cross-pod reduction: int8 quantized all-gather
with error feedback.

XLA gives no control over the wire format of ``psum``, so true 4× wire
compression is expressed as: quantize locally (per-leaf scale) → all_gather
the int8 payload (+ f32 scales) over the compressed axis → dequantize-sum
locally. The quantization residual is carried as *error feedback* into the
next step, which keeps SGD convergence (tested in test_compression.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_allreduce(x, err, axis: str):
    """One leaf: (x + err) → int8 all-gather-sum over ``axis``.

    Returns (summed f32 mean?, new_err). Sum (not mean) semantics, matching
    psum.
    """
    y = x.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(y)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    new_err = y - q.astype(jnp.float32) * scale

    q_all = jax.lax.all_gather(q, axis)                  # (n_axis, ...) int8 wire
    s_all = jax.lax.all_gather(scale, axis)              # (n_axis,) f32
    summed = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=([0], [0]))
    return summed, new_err


def compressed_grad_sum(grads, err_tree, axis: str):
    """Tree-wise int8 error-feedback all-reduce over one mesh axis."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(err_tree)
    outs = [compress_allreduce(g, e, axis) for g, e in zip(flat_g, flat_e)]
    summed = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    errs = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return summed, errs


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
