"""LR schedules + gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm
