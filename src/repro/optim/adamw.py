"""AdamW on parameter pytrees, optimizer state sharded like the params."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable        # (grads, state, params, step, lr) → (new_params, new_state)
    state_dims: Callable    # param_dims tree → state dims tree


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    """AdamW with automatic mixed precision: when the model keeps bf16
    params (so FSDP all-gathers move half the bytes — EXPERIMENTS.md §Perf
    H2b), a float32 master copy lives in the optimizer state."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {"m": jax.tree.map(zeros, params),
                 "v": jax.tree.map(zeros, params)}
        if any(p.dtype == jnp.bfloat16 for p in jax.tree.leaves(params)):
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params, step, lr):
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        masters = state.get("master", params)

        def upd(g, m, v, p, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            u = u + weight_decay * master.astype(jnp.float32)
            new_master = master.astype(jnp.float32) - lr * u
            return new_master.astype(p.dtype), m, v, new_master

        out = jax.tree.map(upd, grads, state["m"], state["v"], params, masters)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": pick(1), "v": pick(2)}
        if "master" in state:
            new_state["master"] = pick(3)
        return pick(0), new_state

    def state_dims(param_dims, has_master=False):
        d = {"m": param_dims, "v": param_dims}
        if has_master:
            d["master"] = param_dims
        return d

    return Optimizer(init=init, update=update, state_dims=state_dims)
