"""llama-3.2-vision-11b [vlm] — text decoder with cross-attention image
layers every 5th layer. The vision tower is a STUB: input_specs() provides
precomputed patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    mlp_type="swiglu", rope_theta=500000.0,
    layer_plan=(("dense", 4), ("cross", 1)) * 8,
    cond_len=1024, cond_dim=4096,
)
