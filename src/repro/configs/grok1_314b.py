"""grok-1-314b [moe] — 8 experts, top-2. [hf:xai-org/grok-1; unverified].

Adafactor (factored second moment) keeps optimizer state within HBM at
314B params on 256 chips (launch/mesh.py production mesh).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2, mlp_type="swiglu",
    optimizer="adafactor",
)
