"""musicgen-large [audio] — decoder-only over EnCodec tokens with text-
conditioning cross-attention every layer. The EnCodec/T5 frontends are STUBS:
input_specs() provides token ids + precomputed conditioning embeddings.
[arXiv:2306.05284; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    mlp_type="gelu",
    layer_plan=(("cross", 48),),
    cond_len=64, cond_dim=1024,
)
