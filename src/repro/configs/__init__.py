"""Architecture registry — one config per assigned architecture.

``get_config(arch_id)`` returns the full-size ModelConfig;
``get_config(arch_id).reduced()`` is the smoke-test size.
"""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.llama3_2_1b import CONFIG as llama3_2_1b
from repro.configs.qwen2_5_3b import CONFIG as qwen2_5_3b
from repro.configs.gemma_2b import CONFIG as gemma_2b
from repro.configs.starcoder2_15b import CONFIG as starcoder2_15b
from repro.configs.phi3_5_moe import CONFIG as phi3_5_moe
from repro.configs.grok1_314b import CONFIG as grok1_314b
from repro.configs.falcon_mamba_7b import CONFIG as falcon_mamba_7b
from repro.configs.musicgen_large import CONFIG as musicgen_large
from repro.configs.hymba_1_5b import CONFIG as hymba_1_5b
from repro.configs.llama3_2_vision_11b import CONFIG as llama3_2_vision_11b

REGISTRY = {
    c.name: c for c in [
        llama3_2_1b, qwen2_5_3b, gemma_2b, starcoder2_15b, phi3_5_moe,
        grok1_314b, falcon_mamba_7b, musicgen_large, hymba_1_5b,
        llama3_2_vision_11b,
    ]
}

ARCH_IDS = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return REGISTRY[name]


__all__ = ["get_config", "REGISTRY", "ARCH_IDS", "SHAPES", "ModelConfig",
           "ShapeConfig", "shape_applicable"]
