"""llama3.2-1b [dense] — hf:meta-llama/Llama-3.2-1B (unverified tier)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256,
    mlp_type="swiglu", tie_embeddings=True, rope_theta=500000.0,
)
