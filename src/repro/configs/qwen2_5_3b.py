"""qwen2.5-3b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5; hf tier]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936,
    mlp_type="swiglu", qkv_bias=True, tie_embeddings=True,
    rope_theta=1000000.0,
)
