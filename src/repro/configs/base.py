"""Config schema: model architectures, input shapes, and the layer plan.

A model is described by a ``ModelConfig`` plus a *layer plan*: a list of
(block_kind, count) segments. Layers inside a segment are homogeneous and
stacked for ``lax.scan``; heterogeneous architectures (cross-attention
interleave, hymba's global/SWA mix) become a few segments instead of one.

Block kinds:
  dense        — self-attn + MLP
  moe          — self-attn + mixture-of-experts FFN
  cross        — self-attn + cross-attn (conditioning) + MLP
  ssm          — Mamba1 mixer + (optional) MLP
  hybrid_swa   — parallel attn(SWA) + Mamba heads, then MLP
  hybrid_full  — parallel attn(full) + Mamba heads, then MLP
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // n_heads
    # layer plan: tuple of (block_kind, count); () → [("dense"|..., n_layers)]
    layer_plan: Tuple[Tuple[str, int], ...] = ()
    # activations / details
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_routing: str = "local"       # local (collective-free dispatch) | global
    # SSM (mamba1)
    ssm_state: int = 0
    d_inner: int = 0                 # 0 → 2 * d_model
    conv_kernel: int = 4
    dt_rank: int = 0                 # 0 → ceil(d_model / 16)
    ssm_chunk: int = 64              # chunked-scan granularity
    # attention windows (hybrid)
    swa_window: Optional[int] = None
    # conditioning (audio text-cond / vlm image layers)
    cond_len: int = 0
    cond_dim: int = 0
    # numerics / impl
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    attention_impl: str = "reference"   # reference | pallas | interpret
    optimizer: str = "adamw"            # adamw | adafactor
    # long-context capability (sub-quadratic decode)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def plan(self) -> Tuple[Tuple[str, int], ...]:
        if self.layer_plan:
            return self.layer_plan
        default = {"dense": "dense", "moe": "moe", "ssm": "ssm",
                   "hybrid": "hybrid_swa", "audio": "cross", "vlm": "dense"}
        return ((default[self.family], self.n_layers),)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, n_layers: int = 2, d_model: int = 64, d_ff: int = 128,
                vocab: int = 512, n_experts: Optional[int] = None) -> "ModelConfig":
        """A smoke-test-sized config of the same family/plan shape."""
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(heads, self.n_kv_heads))
        while heads % kv:
            kv -= 1
        plan = ()
        if self.layer_plan:
            # shrink the plan but keep its structure (≥1 of each segment kind)
            kinds = []
            for kind, _ in self.layer_plan:
                if not kinds or kinds[-1][0] != kind:
                    kinds.append([kind, 1])
                else:
                    kinds[-1][1] += 1
            plan = tuple((k, 1) for k, _ in kinds[:n_layers]) or ()
        ne = self.n_experts and (n_experts if n_experts is not None else min(4, self.n_experts))
        return self.replace(
            n_layers=max(n_layers, len(plan) or 0) if not plan else sum(c for _, c in plan),
            d_model=d_model, d_ff=d_ff, vocab_size=vocab,
            n_heads=heads, n_kv_heads=kv, head_dim=0,
            layer_plan=plan, n_experts=ne or 0,
            d_inner=2 * d_model if self.family in ("ssm", "hybrid") else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            dt_rank=0, cond_len=min(self.cond_len, 8) if self.cond_len else 0,
            cond_dim=d_model if self.cond_dim else 0,
            swa_window=min(self.swa_window, 32) if self.swa_window else None,
            dtype="float32", param_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md shape-skip notes)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k dense KV decode is "
                       "quadratic and unshardable at batch=1 — skipped per "
                       "DESIGN.md")
    return True, ""
