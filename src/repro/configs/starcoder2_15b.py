"""starcoder2-15b [dense] — GQA, RoPE, GELU MLP w/ bias convention.
[arXiv:2402.19173; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    mlp_type="gelu", qkv_bias=True, rope_theta=100000.0,
)
