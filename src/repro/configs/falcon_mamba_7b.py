"""falcon-mamba-7b [ssm] — attention-free Mamba-1; O(1)-state decode makes
long_500k runnable. [arXiv:2410.05355; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=65024,
    ssm_state=16, d_inner=8192, conv_kernel=4,
    supports_long_context=True,
)
