"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer; SWA
(window 1024) everywhere except 3 full-attention layers (first/middle/last).
Sub-quadratic decode ⇒ long_500k runs. [arXiv:2411.13676; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, d_inner=3200, swa_window=1024,
    layer_plan=(("hybrid_full", 1), ("hybrid_swa", 14), ("hybrid_full", 1),
                ("hybrid_swa", 15), ("hybrid_full", 1)),
    supports_long_context=True,
)
