"""Self/cross attention with GQA, RoPE, sliding windows, and KV caching.

Layouts:
  weights  wq (D, H, hd) · wk/wv (D, KV, hd) · wo (H, hd, D)
  cache    k/v (B, KV, S_cache, hd) + pos_ids (S_cache,) absolute positions
           (pos_ids makes rotating sliding-window caches maskable).
Attention impl is selected by cfg.attention_impl: the Pallas flash kernel on
TPU, interpret mode in kernel tests, or the jnp reference (CPU, dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ops import flash_attention
from repro.models.common import apply_rope, dense_init, make_rope


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    D = cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kv_in = cfg.cond_dim if cross else D
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), in_axis_size=D),
        "wk": dense_init(ks[1], (kv_in, KV, hd), in_axis_size=kv_in),
        "wv": dense_init(ks[2], (kv_in, KV, hd), in_axis_size=kv_in),
        "wo": dense_init(ks[3], (H, hd, D), in_axis_size=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd))
        p["bk"] = jnp.zeros((KV, hd))
        p["bv"] = jnp.zeros((KV, hd))
    return p


def attention_dims(cfg: ModelConfig, cross: bool = False):
    d = {
        "wq": ("d_model", "heads", "head_dim"),
        "wk": ("cond_dim" if cross else "d_model", "kv_heads", "head_dim"),
        "wv": ("cond_dim" if cross else "d_model", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "d_model"),
    }
    if cfg.qkv_bias:
        d["bq"] = ("heads", "head_dim")
        d["bk"] = ("kv_heads", "head_dim")
        d["bv"] = ("kv_heads", "head_dim")
    return d


def _project_qkv(p, x, kv_src, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", kv_src, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)[None, :, None, :]
        k = k + p["bk"].astype(x.dtype)[None, :, None, :]
        v = v + p["bv"].astype(x.dtype)[None, :, None, :]
    return q, k, v


def self_attention(p, x, rope, cfg: ModelConfig, window: Optional[int] = None):
    """Training/prefill forward. x (B, S, D) → (B, S, D), causal."""
    cos, sin = rope
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = flash_attention(q, k, v, causal=True, window=window,
                        impl=cfg.attention_impl if cfg.attention_impl != "pallas"
                        else "pallas")
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))


def cross_attention(p, x, cond, cfg: ModelConfig):
    """x (B, S, D) attends over cond (B, T, cond_dim); not causal, no rope."""
    q, k, v = _project_qkv(p, x, cond, cfg)
    o = flash_attention(q, k, v, causal=False, impl=cfg.attention_impl
                        if cfg.attention_impl != "pallas" else "pallas")
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decoding with a KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, seq_len: int,
                  window: Optional[int] = None, dtype=jnp.bfloat16):
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(seq_len, window) if window else seq_len
    return {
        "k": jnp.zeros((n_layers, batch, KV, S, hd), dtype),
        "v": jnp.zeros((n_layers, batch, KV, S, hd), dtype),
        # per-row absolute positions: rows may decode at different positions
        # (continuous batching, runtime/serve_loop.py)
        "pos_ids": jnp.full((n_layers, batch, S), -1, jnp.int32),
    }


def kv_cache_dims():
    return {
        "k": ("layer", "batch", "kv_heads", "seq", "head_dim"),
        "v": ("layer", "batch", "kv_heads", "seq", "head_dim"),
        "pos_ids": ("layer", "batch", "seq"),
    }


def decode_self_attention(p, x, cache_l, pos, rope_tables, cfg: ModelConfig,
                          window: Optional[int] = None):
    """One-token decode. x (B, 1, D); cache_l holds this layer's k/v/pos_ids.

    Returns (out (B,1,D), new cache_l). The cache slot is pos % S_cache
    (rotating for sliding windows, identity otherwise); masking uses the
    stored absolute positions so SWA and full caches share one code path.
    rope_tables is unused (rope is computed from ``pos`` directly, keeping
    500k-long tables out of the HLO); kept for signature stability.
    """
    del rope_tables
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, cfg)     # (B,H,1,hd), (B,KV,1,hd)
    S_c = cache_l["k"].shape[2]
    per_row = jnp.ndim(pos) > 0                      # continuous batching

    if per_row:                                      # pos (B,) — per-slot
        cos, sin = make_rope(pos, cfg.resolved_head_dim, cfg.rope_theta)
        cos = cos[:, None, None, :]                  # (B,1,1,hd/2)
        sin = sin[:, None, None, :]
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
        rows = jnp.arange(B)
        slot = pos % S_c
        k = cache_l["k"].at[rows, :, slot].set(
            k_new[:, :, 0].astype(cache_l["k"].dtype))
        v = cache_l["v"].at[rows, :, slot].set(
            v_new[:, :, 0].astype(cache_l["v"].dtype))
        pos_ids = cache_l["pos_ids"].at[rows, slot].set(pos)
        pos_b = pos[:, None]                         # (B,1)
    else:                                            # scalar pos (dry-run path)
        cos, sin = make_rope(jnp.asarray(pos)[None], cfg.resolved_head_dim,
                             cfg.rope_theta)         # (1, hd/2)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
        slot = pos % S_c
        k = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k_new.astype(cache_l["k"].dtype), slot, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v_new.astype(cache_l["v"].dtype), slot, axis=2)
        pos_ids = jax.lax.dynamic_update_slice_in_dim(
            cache_l["pos_ids"], jnp.full((cache_l["pos_ids"].shape[0], 1),
                                         pos, jnp.int32), slot, axis=1)
        pos_b = jnp.full((B, 1), pos, jnp.int32)

    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    H, KV = cfg.n_heads, cfg.n_kv_heads
    group = H // KV
    qg = q.reshape(B, KV, group, cfg.resolved_head_dim)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = (pos_ids >= 0) & (pos_ids <= pos_b)      # (B, S_c)
    if window is not None:
        valid &= (pos_b - pos_ids) < window
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", probs, v.astype(jnp.float32))
    o = o.reshape(B, H, 1, cfg.resolved_head_dim).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, {"k": k, "v": v, "pos_ids": pos_ids}
