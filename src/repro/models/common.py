"""Shared model components: norms, RoPE, initializers, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def make_rope(positions, head_dim: int, theta: float = 10000.0):
    """(cos, sin) of shape (len(positions), head_dim // 2). ``positions`` may
    be traced (jnp) — no giant constant tables end up in the HLO."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.outer(jnp.asarray(positions, jnp.float32), freqs)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, head_dim); cos/sin (S, head_dim/2) or broadcastable."""
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * scale


def stacked(init_fn, key, n: int, *args, **kw):
    """Initialize a weight stacked over a leading layer dimension."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args, **kw))(keys)


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
