"""Mamba-1 selective-state-space mixer (falcon-mamba, hymba's SSM heads).

Training uses a chunked scan: an outer ``lax.scan`` over sequence chunks
carries only the (B, d_inner, state) boundary state, and the inner per-step
scan is wrapped in ``jax.checkpoint`` so the backward pass recomputes within
a chunk instead of materializing (B, S, d_inner, state) — the difference
between ~34 GB and ~34 MB of live state at the 4k×global-batch-256 dry-run
shape. Decoding carries (h, conv window) explicitly, O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_mamba(key, cfg: ModelConfig):
    D = cfg.d_model
    di = cfg.resolved_d_inner
    n = cfg.ssm_state
    dtr = cfg.resolved_dt_rank
    K = cfg.conv_kernel
    ks = jax.random.split(key, 6)
    # S4-style A init: -(1..n) per channel
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di)),
        "conv_w": dense_init(ks[1], (K, di), in_axis_size=K),
        "conv_b": jnp.zeros((di,)),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n)),
        "dt_proj": dense_init(ks[3], (dtr, di), in_axis_size=dtr),
        "dt_bias": jnp.full((di,), -4.6),              # softplus ≈ 0.01
        "A_log": jnp.log(a),
        "D": jnp.ones((di,)),
        "out_proj": dense_init(ks[4], (di, D)),
    }


def mamba_dims(cfg: ModelConfig):
    return {
        "in_proj": ("d_model", "d_inner2"),
        "conv_w": ("conv_k", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", "dt_plus"),
        "dt_proj": ("dt_rank", "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", "ssm_state"),
        "D": ("d_inner",),
        "out_proj": ("d_inner", "d_model"),
    }


def _ssm_inputs(p, x, cfg: ModelConfig):
    """Shared pre-scan computation. x (B,S,D) → (xr, z, dt, Bc, Cc)."""
    di, n, dtr = cfg.resolved_d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)                  # (B,S,2di)
    xr, z = jnp.split(xz, 2, axis=-1)
    return xr, z


def _post_conv(p, xr, cfg):
    di, n, dtr = cfg.resolved_d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    dt_ = xr.dtype
    xr = jax.nn.silu(xr)
    proj = xr @ p["x_proj"].astype(dt_)                # (..., dtr+2n)
    dt_r = proj[..., :dtr]
    Bc = proj[..., dtr: dtr + n].astype(jnp.float32)
    Cc = proj[..., dtr + n:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"].astype(dt_)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return xr, dt, Bc, Cc


def _scan_step(A, h, xt, dtt, Bt, Ct):
    """h (B,di,n); xt/dtt (B,di); Bt/Ct (B,n)."""
    da = jnp.exp(dtt[..., None] * A)                   # (B,di,n)
    h = da * h + dtt[..., None] * Bt[:, None, :] * xt[..., None]
    y = jnp.einsum("bdn,bn->bd", h, Ct)
    return h, y


def mamba_forward(p, x, cfg: ModelConfig, h0=None):
    """Training/prefill forward. x (B,S,D) → (B,S,D)."""
    B, S, D = x.shape
    di, n = cfg.resolved_d_inner, cfg.ssm_state
    xr, z = _ssm_inputs(p, x, cfg)

    # causal depthwise conv along S
    K = cfg.conv_kernel
    xr_pad = jnp.pad(xr, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(xr_pad[:, i: i + S, :] * p["conv_w"][i].astype(x.dtype)
               for i in range(K))
    xr = conv + p["conv_b"].astype(x.dtype)

    xr, dt, Bc, Cc = _post_conv(p, xr, cfg)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (di,n)

    chunk = min(cfg.ssm_chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0, (S, chunk)

    def inner(h, inp):
        def step(h, i):
            xt, dtt, Bt, Ct = i
            return _scan_step(A, h, xt.astype(jnp.float32), dtt, Bt, Ct)
        return jax.lax.scan(step, h, inp)

    inner_ckpt = jax.checkpoint(inner)

    def outer(h, inp):
        h, ys = inner_ckpt(h, inp)
        return h, ys

    reshape = lambda a: jnp.moveaxis(
        a.reshape(B, n_chunks, chunk, -1), 1, 0).swapaxes(1, 2)  # (n_chunks, chunk, B, ·)
    xs = (reshape(xr), reshape(dt), reshape(Bc), reshape(Cc))
    h0 = jnp.zeros((B, di, n), jnp.float32) if h0 is None else h0
    hT, ys = jax.lax.scan(outer, h0, xs)               # ys (n_chunks, chunk, B, di)
    y = jnp.moveaxis(ys.reshape(n_chunks * chunk, B, di), 0, 1)  # (B,S,di)

    y = y.astype(x.dtype) + xr * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decoding
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, n_layers: int, batch: int,
                   dtype=jnp.float32):
    di, n, K = cfg.resolved_d_inner, cfg.ssm_state, cfg.conv_kernel
    return {
        "h": jnp.zeros((n_layers, batch, di, n), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, K - 1, di), dtype),
    }


def ssm_cache_dims():
    return {"h": ("layer", "batch", "d_inner", "ssm_state"),
            "conv": ("layer", "batch", "conv_k", "d_inner")}


def mamba_decode_step(p, x, cache_l, cfg: ModelConfig):
    """x (B, 1, D) → (out (B,1,D), new cache_l {h, conv})."""
    B = x.shape[0]
    di, n, K = cfg.resolved_d_inner, cfg.ssm_state, cfg.conv_kernel
    xr, z = _ssm_inputs(p, x, cfg)                     # (B,1,di)
    xr = xr[:, 0]
    window = jnp.concatenate([cache_l["conv"],
                              xr[:, None, :].astype(cache_l["conv"].dtype)], axis=1)
    conv = jnp.einsum("bkd,kd->bd", window.astype(x.dtype),
                      p["conv_w"].astype(x.dtype)) + p["conv_b"].astype(x.dtype)
    xc, dt, Bc, Cc = _post_conv(p, conv[:, None, :], cfg)
    xc, dt, Bc, Cc = xc[:, 0], dt[:, 0], Bc[:, 0], Cc[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h, y = _scan_step(A, cache_l["h"], xc.astype(jnp.float32), dt, Bc, Cc)
    y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0])
    out = (y @ p["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"h": h, "conv": window[:, 1:]}
