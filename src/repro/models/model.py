"""The Model API: init / forward / loss / caches / decode, plus the logical
dimension trees the sharding-rules engine consumes (runtime/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import cast_tree, make_rope, rms_norm
from repro.models.transformer import (
    init_segment,
    init_segment_cache,
    run_segment,
    run_segment_decode,
    segment_cache_dims,
    segment_dims,
)

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, len(cfg.plan) + 3)
        params = {
            "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02,
            "final_norm": jnp.zeros((cfg.d_model,)),
            "segments": [init_segment(ks[2 + i], kind, count, cfg)
                         for i, (kind, count) in enumerate(cfg.plan)],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(
                ks[1], (cfg.d_model, cfg.vocab_size)) * 0.02
        return cast_tree(params, _DTYPES[cfg.param_dtype])

    def param_dims(self):
        cfg = self.cfg
        dims = {
            "embed": ("vocab", "d_model"),
            "final_norm": ("d_model",),
            "segments": [segment_dims(kind, cfg) for kind, _ in cfg.plan],
        }
        if not cfg.tie_embeddings:
            dims["lm_head"] = ("d_model", "vocab")
        return dims

    # --------------------------------------------------------------- forward
    def _stack(self, params, tokens, cond=None):
        cfg = self.cfg
        dt = _DTYPES[cfg.dtype]
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        if cond is not None:
            cond = cond.astype(dt)
        rope = make_rope(jnp.arange(tokens.shape[1]), cfg.resolved_head_dim,
                         cfg.rope_theta)
        for seg_params, (kind, _) in zip(params["segments"], cfg.plan):
            x = run_segment(kind, seg_params, x, rope, cfg, cond=cond)
        return rms_norm(x, params["final_norm"])

    def forward(self, params, tokens, cond=None):
        """tokens (B, S) int32 → logits (B, S, vocab) f32."""
        x = self._stack(params, tokens, cond=cond)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        return (x.astype(jnp.float32) @ head.astype(jnp.float32))

    def prefill(self, params, tokens, cond=None):
        """Serving prefill: last-position logits only — the (B, S, vocab)
        logits tensor never exists (it dominates 32k-prefill memory)."""
        x = self._stack(params, tokens, cond=cond)[:, -1]
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        return (x.astype(jnp.float32) @ head.astype(jnp.float32))

    def loss(self, params, batch):
        """batch: {tokens (B,S), labels (B,S), cond?} → mean xent (f32)."""
        logits = self.forward(params, batch["tokens"], cond=batch.get("cond"))
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        return [init_segment_cache(kind, count, cfg, batch, seq_len, dtype)
                for kind, count in cfg.plan]

    def cache_dims(self):
        return [segment_cache_dims(kind) for kind, _ in self.cfg.plan]

    def decode_step(self, params, cache, tokens, pos, cond=None):
        """tokens (B,) int32, pos () int32 → (logits (B, vocab), new cache)."""
        cfg = self.cfg
        dt = _DTYPES[cfg.dtype]
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(dt)
        if cond is not None:
            cond = cond.astype(dt)
        new_cache = []
        for seg_params, seg_cache, (kind, _) in zip(params["segments"], cache,
                                                    cfg.plan):
            x, c = run_segment_decode(kind, seg_params, x, seg_cache, pos, cfg,
                                      cond=cond)
            new_cache.append(c)
        x = rms_norm(x, params["final_norm"])
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x[:, 0].astype(jnp.float32) @ head.astype(jnp.float32))
        return logits, new_cache

    # ---------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig, per_host_batch: Optional[int] = None):
        """ShapeDtypeStruct stand-ins for every model input (dry-run §2)."""
        cfg = self.cfg
        B = per_host_batch or shape.global_batch
        specs = {}
        if shape.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
            specs["labels"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
        elif shape.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)
        else:  # decode
            specs["tokens"] = jax.ShapeDtypeStruct((B,), jnp.int32)
            specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        if cfg.cond_len:
            # modality frontend STUB: precomputed frame/patch embeddings
            specs["cond"] = jax.ShapeDtypeStruct(
                (B, cfg.cond_len, cfg.cond_dim), _DTYPES[cfg.dtype])
        return specs


def greedy_decode(model: Model, params, prompt_tokens, n_new: int, cond=None,
                  cache_len: Optional[int] = None):
    """Reference serving loop: prefill via forward, then token-by-token."""
    cfg = model.cfg
    B, S0 = prompt_tokens.shape
    total = S0 + n_new
    cache = model.init_cache(B, cache_len or total,
                             dtype=_DTYPES[cfg.dtype])
    # prefill by stepping (simple, exercises the decode path end to end)
    tok = prompt_tokens[:, 0]
    out = [tok]
    for t in range(total - 1):
        logits, cache = model.decode_step(params, cache, tok, jnp.int32(t),
                                          cond=cond)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = jnp.where(t + 1 < S0, prompt_tokens[:, min(t + 1, S0 - 1)], nxt)
        out.append(tok)
    return jnp.stack(out, axis=1)
