"""Mixture-of-experts FFN with top-k routing and per-expert capacity.

Dispatch is gather-based (no T×E×C one-hot tensors): tokens are assigned
positional slots within their expert's capacity buffer via a cumulative
count; overflow tokens are dropped (capacity_factor controls slack). The
expert loop is a ``lax.scan`` so activation memory is one expert's buffer
(C × d_model), not E of them — this is what keeps 1M-token MoE steps inside
HBM at the dry-run shapes ("TP-experts": tokens stay
data-sharded, expert FFN dims are tensor-sharded; no all-to-all needed).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, stacked


def init_moe(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (D, E)),
        "wg": stacked(dense_init, ks[1], E, (D, F)),
        "wu": stacked(dense_init, ks[2], E, (D, F)),
        "wd": stacked(dense_init, ks[3], E, (F, D)),
    }


def moe_dims(cfg: ModelConfig):
    return {
        "router": ("d_model", "experts"),
        "wg": ("experts", "d_model", "d_ff"),
        "wu": ("experts", "d_model", "d_ff"),
        "wd": ("experts", "d_ff", "d_model"),
    }


def moe_forward(p, x, cfg: ModelConfig):
    """x (B, S, D) → (B, S, D). Dispatches to row-local routing (default —
    no cross-shard gathers; see EXPERIMENTS.md §Perf H2) or the flat global
    routing kept as the measured baseline."""
    if getattr(cfg, "moe_routing", "local") == "global":
        return _moe_forward_global(p, x, cfg)
    return _moe_forward_local(p, x, cfg)


def _moe_forward_local(p, x, cfg: ModelConfig):
    """Row-local top-k routing: every gather/scatter runs along the
    *sequence* axis of one batch row, so with batch sharded over (pod, data)
    the dispatch is collective-free; the only collectives left are the TP
    psum of the expert FFN contraction and the FSDP weight gathers.
    Capacity is per row (⌈cf·k·S/E⌉) — the standard per-shard-capacity
    approximation of global top-k dropping."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    capacity = min(int(math.ceil(cfg.capacity_factor * k * S / E)), S)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                      # (B,S,k)
    top_vals = top_vals / top_vals.sum(axis=-1, keepdims=True)

    def route_row(w_row, x_row):
        """w_row (S,), x_row (S,D) → (xe (C,D), buf (C,), w_sel (C,1))."""
        mask = w_row > 0.0
        pos = jnp.cumsum(mask) - 1
        keep = mask & (pos < capacity)
        slot = jnp.where(keep, pos, capacity)
        buf = jnp.zeros((capacity + 1,), jnp.int32).at[slot].set(
            jnp.arange(S, dtype=jnp.int32), mode="drop")[:capacity]
        n_keep = jnp.minimum(keep.sum(), capacity)
        valid = (jnp.arange(capacity) < n_keep)[:, None]
        w_sel = jnp.where(valid, w_row[buf][:, None], 0.0)
        return x_row[buf], buf, w_sel

    def expert_body(y, ep):
        w_tok = jnp.where(top_idx == ep["eid"], top_vals, 0.0).sum(-1)  # (B,S)
        xe, buf, w_sel = jax.vmap(route_row)(w_tok, x)          # (B,C,D)…
        dt = x.dtype
        act = jax.nn.silu(jnp.einsum("bcd,df->bcf", xe, ep["wg"].astype(dt))) \
            * jnp.einsum("bcd,df->bcf", xe, ep["wu"].astype(dt))
        ye = jnp.einsum("bcf,fd->bcd", act, ep["wd"].astype(dt))
        contrib = ye * w_sel.astype(dt)
        return jax.vmap(lambda yr, br, cr: yr.at[br].add(cr, mode="drop"))(
            y, buf, contrib), None

    xs = {"eid": jnp.arange(E, dtype=jnp.int32),
          "wg": p["wg"], "wu": p["wu"], "wd": p["wd"]}
    y, _ = jax.lax.scan(expert_body, jnp.zeros_like(x), xs,
                        unroll=E if PROBE_UNROLL else 1)
    return y


def _moe_forward_global(p, x, cfg: ModelConfig):
    """Baseline: flat global-token routing (gathers cross data shards)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    capacity = int(math.ceil(cfg.capacity_factor * k * T / E))
    capacity = min(capacity, T)

    xt = x.reshape(T, D)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_vals, top_idx = jax.lax.top_k(probs, k)                  # (T, k)
    top_vals = top_vals / top_vals.sum(axis=-1, keepdims=True)

    def expert_body(y, ep):
        eid = ep["eid"]
        w_tok = jnp.where(top_idx == eid, top_vals, 0.0).sum(axis=-1)  # (T,)
        mask = w_tok > 0.0
        pos = jnp.cumsum(mask) - 1
        keep = mask & (pos < capacity)
        slot = jnp.where(keep, pos, capacity)                    # overflow → trash
        buf = jnp.zeros((capacity + 1,), jnp.int32).at[slot].set(
            jnp.arange(T, dtype=jnp.int32), mode="drop")[:capacity]
        n_keep = jnp.minimum(keep.sum(), capacity)

        xe = xt[buf]                                             # (C, D)
        dt = x.dtype
        act = jax.nn.silu(xe @ ep["wg"].astype(dt)) * (xe @ ep["wu"].astype(dt))
        ye = act @ ep["wd"].astype(dt)                           # (C, D)
        valid = (jnp.arange(capacity) < n_keep)[:, None]
        contrib = jnp.where(valid, ye * w_tok[buf][:, None].astype(dt), 0.0)
        return y.at[buf].add(contrib, mode="drop"), None

    xs = {"eid": jnp.arange(E, dtype=jnp.int32),
          "wg": p["wg"], "wu": p["wu"], "wd": p["wd"]}
    y, _ = jax.lax.scan(expert_body, jnp.zeros_like(xt), xs,
                        unroll=E if PROBE_UNROLL else 1)
    return y.reshape(B, S, D)


# dry-run probes flip this so cost_analysis counts every expert (a while
# body is tallied once by XLA) — see launch/probes.py
PROBE_UNROLL = False
