"""Block definitions and segment runners for every block kind.

A model is a sequence of homogeneous *segments* (configs/base.py layer plan);
each segment's per-layer params are stacked on a leading dim and executed
with ``lax.scan`` (+ ``jax.checkpoint`` when cfg.remat) so the HLO stays
small at 64-layer scale and live activations are one layer deep.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    attention_dims,
    cross_attention,
    decode_self_attention,
    init_attention,
    init_kv_cache,
    kv_cache_dims,
    self_attention,
)
from repro.models.common import rms_norm, stacked
from repro.models.mamba import (
    init_mamba,
    init_ssm_cache,
    mamba_decode_step,
    mamba_dims,
    mamba_forward,
    ssm_cache_dims,
)
from repro.models.mlp import init_mlp, mlp_dims, mlp_forward
from repro.models.moe import init_moe, moe_dims, moe_forward

ATTN_KINDS = {"dense", "moe", "cross", "hybrid_swa", "hybrid_full"}
SSM_KINDS = {"ssm", "hybrid_swa", "hybrid_full"}


def _window(kind: str, cfg: ModelConfig) -> Optional[int]:
    return cfg.swa_window if kind == "hybrid_swa" else None


# ---------------------------------------------------------------------------
# per-layer init / dims
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    p = {"norm1": jnp.zeros((cfg.d_model,))}
    if kind in ATTN_KINDS:
        p["attn"] = init_attention(ks[0], cfg)
    if kind == "cross":
        p["xattn"] = init_attention(ks[1], cfg, cross=True)
        p["norm_x"] = jnp.zeros((cfg.d_model,))
    if kind in SSM_KINDS:
        p["mamba"] = init_mamba(ks[2], cfg)
    if kind.startswith("hybrid"):
        p["norm_a"] = jnp.zeros((cfg.d_model,))
        p["norm_m"] = jnp.zeros((cfg.d_model,))
    if kind == "moe":
        p["moe"] = init_moe(ks[3], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,))
    elif kind != "ssm":                                  # dense/cross/hybrid MLP
        p["mlp"] = init_mlp(ks[4], cfg)
        p["norm2"] = jnp.zeros((cfg.d_model,))
    return p


def block_dims(kind: str, cfg: ModelConfig):
    d = {"norm1": ("d_model",)}
    if kind in ATTN_KINDS:
        d["attn"] = attention_dims(cfg)
    if kind == "cross":
        d["xattn"] = attention_dims(cfg, cross=True)
        d["norm_x"] = ("d_model",)
    if kind in SSM_KINDS:
        d["mamba"] = mamba_dims(cfg)
    if kind.startswith("hybrid"):
        d["norm_a"] = ("d_model",)
        d["norm_m"] = ("d_model",)
    if kind == "moe":
        d["moe"] = moe_dims(cfg)
        d["norm2"] = ("d_model",)
    elif kind != "ssm":
        d["mlp"] = mlp_dims(cfg)
        d["norm2"] = ("d_model",)
    return d


def init_segment(key, kind: str, count: int, cfg: ModelConfig):
    return stacked(lambda k: init_block(k, kind, cfg), key, count)


def segment_dims(kind: str, cfg: ModelConfig):
    return jax.tree.map(lambda dims: ("layer",) + dims, block_dims(kind, cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def block_forward(kind: str, p, x, rope, cfg: ModelConfig, cond=None):
    h = rms_norm(x, p["norm1"])
    if kind == "ssm":
        return x + mamba_forward(p["mamba"], h, cfg)
    if kind.startswith("hybrid"):
        a = self_attention(p["attn"], h, rope, cfg, window=_window(kind, cfg))
        m = mamba_forward(p["mamba"], h, cfg)
        x = x + 0.5 * (rms_norm(a, p["norm_a"]) + rms_norm(m, p["norm_m"]))
    else:
        x = x + self_attention(p["attn"], h, rope, cfg)
    if kind == "cross":
        x = x + cross_attention(p["xattn"], rms_norm(x, p["norm_x"]), cond, cfg)
    ff_in = rms_norm(x, p["norm2"])
    if kind == "moe":
        return x + moe_forward(p["moe"], ff_in, cfg)
    return x + mlp_forward(p["mlp"], ff_in, cfg)


def run_segment(kind: str, seg_params, x, rope, cfg: ModelConfig, cond=None):
    def body(x, p_l):
        return block_forward(kind, p_l, x, rope, cfg, cond=cond), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, seg_params)
    return x


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def init_segment_cache(kind: str, count: int, cfg: ModelConfig, batch: int,
                       seq_len: int, dtype=jnp.bfloat16):
    c = {}
    if kind in ATTN_KINDS:
        c["kv"] = init_kv_cache(cfg, count, batch, seq_len,
                                window=_window(kind, cfg), dtype=dtype)
    if kind in SSM_KINDS:
        c["ssm"] = init_ssm_cache(cfg, count, batch, dtype=dtype)
    return c


def segment_cache_dims(kind: str):
    c = {}
    if kind in ATTN_KINDS:
        c["kv"] = kv_cache_dims()
    if kind in SSM_KINDS:
        c["ssm"] = ssm_cache_dims()
    return c


def block_decode(kind: str, p, x, cache_l, pos, cfg: ModelConfig, cond=None):
    """x (B,1,D) one-token step. cache_l: this layer's slice (no leading L)."""
    new_cache = {}
    h = rms_norm(x, p["norm1"])
    if kind == "ssm":
        o, new_cache["ssm"] = mamba_decode_step(p["mamba"], h, cache_l["ssm"], cfg)
        return x + o, new_cache
    if kind.startswith("hybrid"):
        a, new_cache["kv"] = decode_self_attention(
            p["attn"], h, cache_l["kv"], pos, None, cfg, window=_window(kind, cfg))
        m, new_cache["ssm"] = mamba_decode_step(p["mamba"], h, cache_l["ssm"], cfg)
        x = x + 0.5 * (rms_norm(a, p["norm_a"]) + rms_norm(m, p["norm_m"]))
    else:
        a, new_cache["kv"] = decode_self_attention(
            p["attn"], h, cache_l["kv"], pos, None, cfg)
        x = x + a
    if kind == "cross":
        x = x + cross_attention(p["xattn"], rms_norm(x, p["norm_x"]), cond, cfg)
    ff_in = rms_norm(x, p["norm2"])
    if kind == "moe":
        return x + moe_forward(p["moe"], ff_in, cfg), new_cache
    return x + mlp_forward(p["mlp"], ff_in, cfg), new_cache


def run_segment_decode(kind: str, seg_params, x, cache, pos, cfg: ModelConfig,
                       cond=None):
    def body(x, inp):
        p_l, c_l = inp
        y, c_new = block_decode(kind, p_l, x, c_l, pos, cfg, cond=cond)
        return y, c_new

    x, new_cache = jax.lax.scan(body, x, (seg_params, cache))
    return x, new_cache
