from repro.models.model import Model, greedy_decode

__all__ = ["Model", "greedy_decode"]
