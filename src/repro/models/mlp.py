"""Feed-forward blocks: SwiGLU (llama/qwen/grok), GeGLU (gemma), GELU."""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models.common import dense_init


def init_mlp(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wg": dense_init(ks[0], (D, F)),
            "wu": dense_init(ks[1], (D, F)),
            "wd": dense_init(ks[2], (F, D)),
        }
    return {"w1": dense_init(ks[0], (D, F)), "w2": dense_init(ks[1], (F, D))}


def mlp_dims(cfg: ModelConfig):
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"wg": ("d_model", "d_ff"), "wu": ("d_model", "d_ff"),
                "wd": ("d_ff", "d_model")}
    return {"w1": ("d_model", "d_ff"), "w2": ("d_ff", "d_model")}


def mlp_forward(p, x, cfg: ModelConfig):
    dt = x.dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = x @ p["wg"].astype(dt)
        u = x @ p["wu"].astype(dt)
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ p["wd"].astype(dt)
    return jax.nn.gelu(x @ p["w1"].astype(dt)) @ p["w2"].astype(dt)
