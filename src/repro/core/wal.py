"""Durability layer: commit log + snapshots (DESIGN.md §8, OPERATIONS.md).

PR 5 made the corpus live-mutable with bit-exact commit/rollback, but the
state only ever lived in process memory: a restarted ``DetectionService``
lost every commit, the ``ResultCache``, and the epoch history — forcing the
exact rebuild-from-scratch cost the paper's INCREMENTAL algorithm exists to
avoid. This module is the on-disk half of the fix:

  * ``CommitLog`` — an append-only, schema-versioned, checksummed log with
    one fsync'd record per ``DetectionService.commit()``. A record carries
    the accepted rows (values/accuracy/p_claim), the commit's touched claim
    keys, the post-commit epoch, and the compaction marker. Reading stops at
    the first invalid record (short header, bad magic, short payload, CRC
    mismatch) and ``recover`` truncates the file back to the last valid
    record — the torn-tail contract a SIGKILL mid-write demands.
  * Snapshots — periodic serializations of the full service state (resident
    corpus, committed index via ``InvertedIndex.state_dict``, epoch,
    touched-key log, result-cache entries, stats counters) framed with the
    same version + CRC header. ``latest_valid_snapshot`` walks candidates
    newest-first and skips corrupt files, so a crash mid-snapshot-write can
    never strand a state dir (writes are atomic tmp+rename anyway).
  * ``DurabilityOptions`` — the per-service config knob bag
    (``core/serving.py`` consumes it).

``DetectionService.restore`` composes the two: load the newest valid
snapshot, replay the log tail to the current epoch, resume serving with a
warm cache. The formats are deliberately minimal — framed ``npz`` payloads —
and carry explicit version fields so the sharded-corpus roadmap item can
extend them without breaking old state dirs. File-format details and the
operator's recovery procedure live in OPERATIONS.md.
"""
from __future__ import annotations

import io
import json
import os
import re
import struct
import zlib
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

#: Log-record schema version. Readers reject records from a NEWER major
#: version (they cannot know the framing changed compatibly); bump when the
#: record payload keys or header layout change.
WAL_VERSION = 1

#: Snapshot container version — versions the FRAME (header + npz payload
#: envelope). The payload's store chunk layout carries its own version
#: (``store.STORE_LAYOUT_VERSION``) so the two can evolve independently.
SNAPSHOT_VERSION = 1

#: Manifest schema version (the small JSON file describing the service).
MANIFEST_VERSION = 1

_REC_MAGIC = b"CDWR"            # per-record magic, commit log
_SNAP_MAGIC = b"CDSN"           # snapshot file magic
SPILL_MAGIC = b"CDSP"           # spilled-chunk file magic (core/shardplan.py)
#: Record header: magic, version u16, record type u16, payload bytes u32,
#: CRC32 of the payload u32 — 16 bytes, little-endian.
_REC_HEADER = struct.Struct("<4sHHII")
#: Snapshot header: magic, version u16, reserved u16, payload bytes u64,
#: CRC32 of the payload u32 — 20 bytes, little-endian.
_SNAP_HEADER = struct.Struct("<4sHHQI")

#: Record types. The type field lets markers (retraction, shard handoff)
#: extend the log without re-versioning; readers skip unknown types.
REC_COMMIT = 1
REC_RETRACT = 2

LOG_NAME = "commits.wal"
MANIFEST_NAME = "manifest.json"
_SNAP_RE = re.compile(r"^snapshot-(\d{8})\.snap$")


class WalError(RuntimeError):
    """Base class for durability-layer failures."""


class ReplayDivergenceError(WalError):
    """Replaying a log record did not reproduce the recorded outcome.

    Raised by ``DetectionService.restore`` when a replayed commit lands on a
    different epoch or compaction outcome than the record logged — the
    deterministic-replay invariant (DESIGN.md §8) is broken, so serving from
    this state would silently diverge from the pre-crash service.
    """


class NoValidSnapshotError(WalError):
    """A restore found no loadable snapshot in the state dir."""


@dataclass(frozen=True)
class DurabilityOptions:
    """Config for a durable ``DetectionService`` (all knobs in one place)."""

    # Directory holding the manifest, the commit log, and the snapshots.
    # One service per state dir — concurrent writers would interleave log
    # records. ``ReplicaRouter`` derives per-replica ``replica-<i>/``
    # subdirectories automatically.
    state_dir: str
    # Snapshot cadence in commits: a snapshot is written after every commit
    # whose post-commit epoch is a multiple of this. 0 disables periodic
    # snapshots (only the initial epoch-0 snapshot is written — restore then
    # replays the whole log; the durability benchmark uses this to measure
    # the raw replay rate). Smaller values shorten restore at the cost of
    # snapshot write time (O(corpus bytes)) on the commit path.
    snapshot_every: int = 16
    # fsync policy for log appends: "commit" fsyncs after every record —
    # a commit is durable the moment ``commit()`` returns; "none" leaves
    # flushing to the OS page cache — faster, but commits since the last
    # OS flush can vanish on power loss (a clean process kill still keeps
    # them; torn-tail recovery handles either case).
    fsync: str = "commit"
    # Number of snapshot files kept on disk. Older snapshots are pruned
    # after each successful write; ≥ 2 keeps a fallback if the newest file
    # is corrupt. The commit log itself is never pruned (see OPERATIONS.md
    # for disk-space expectations).
    retention: int = 2


@dataclass
class RecoveryInfo:
    """What log recovery found (and possibly discarded) on open."""

    records: int                  # valid records in the log
    valid_bytes: int              # log length after truncating the torn tail
    discarded_bytes: int = 0      # torn/corrupt tail bytes dropped


@dataclass
class RestoreInfo:
    """Receipt of one ``DetectionService.restore`` (timings + provenance)."""

    snapshot_epoch: int           # epoch of the snapshot that seeded state
    snapshot_path: str            # file the state was loaded from
    replayed_commits: int         # log records applied on top of it
    discarded_bytes: int          # torn-tail bytes dropped by log recovery
    skipped_snapshots: int = 0    # corrupt snapshot files skipped
    snapshot_load_s: float = 0.0  # wall time to load + deserialize
    replay_s: float = 0.0         # wall time replaying the log tail
    wall_s: float = 0.0           # total restore wall time


def _encode_arrays(arrays: dict) -> bytes:
    """Serialize a ``{name: ndarray}`` dict to npz bytes (the one payload
    codec shared by log records and snapshots)."""
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _decode_arrays(payload: bytes) -> dict:
    """Inverse of ``_encode_arrays`` (materialized — no open file handles)."""
    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


@dataclass
class CommitRecord:
    """One decoded commit-log record (see ``CommitLog`` for the framing)."""

    rec_type = REC_COMMIT         # header type field for this record class

    epoch: int                    # service epoch AFTER this commit applied
    values: np.ndarray            # (q, D) int32 — the accepted rows
    accuracy: np.ndarray          # (q,) float32
    p_claim: np.ndarray           # (q, D) float32
    touched_keys: np.ndarray      # sorted int64 claim keys of the rows
    compact: bool                 # the compact= flag the commit ran with
    compacted: bool               # compaction marker: did deltas fold back?
    # Shard-owner routing (DESIGN.md §12): the corpus row range [lo, hi)
    # these rows landed in, so a restoring owner replica knows whether the
    # record mutates ITS index slice or only the shared claims state.
    # -1/-1 = unscoped (single-host service or pre-§12 log).
    owner_lo: int = -1
    owner_hi: int = -1

    def payload(self) -> bytes:
        """Encode this record's fields to the framed npz payload."""
        return _encode_arrays({
            "values": np.asarray(self.values, np.int32),
            "accuracy": np.asarray(self.accuracy, np.float32),
            "p_claim": np.asarray(self.p_claim, np.float32),
            "touched_keys": np.asarray(self.touched_keys, np.int64),
            "meta": np.array([self.epoch, int(self.compact),
                              int(self.compacted), self.owner_lo,
                              self.owner_hi], np.int64),
        })

    @classmethod
    def from_payload(cls, payload: bytes) -> "CommitRecord":
        """Decode a framed npz payload back into a record."""
        d = _decode_arrays(payload)
        meta = d["meta"]
        # Older logs carry a 3-int meta (no owner range) — decode as -1/-1.
        lo, hi = (int(meta[3]), int(meta[4])) if len(meta) >= 5 else (-1, -1)
        return cls(epoch=int(meta[0]), values=d["values"],
                   accuracy=d["accuracy"], p_claim=d["p_claim"],
                   touched_keys=d["touched_keys"], compact=bool(meta[1]),
                   compacted=bool(meta[2]), owner_lo=lo, owner_hi=hi)


@dataclass
class RetractRecord:
    """One decoded retraction record (``REC_RETRACT``, DESIGN.md §9).

    A retraction drops committed sources; replay applies it through the
    exact live path (``DetectionService._retract_locked``), so the record
    only needs the row identities — ``row_ids`` in the corpus row coordinates
    of the PRE-retraction epoch — plus the invariants replay asserts against
    (``n_before``) and the invalidation currency (``touched_keys``).
    """

    rec_type = REC_RETRACT        # header type field for this record class

    epoch: int                    # service epoch AFTER this retraction
    row_ids: np.ndarray           # (k,) int64 — retracted corpus rows
    touched_keys: np.ndarray      # sorted int64 claim keys of those rows
    n_before: int                 # corpus rows BEFORE the retraction
    # Shard-owner routing (DESIGN.md §12): the [lo, hi) row span covering
    # the retracted ids; -1/-1 = unscoped (see CommitRecord).
    owner_lo: int = -1
    owner_hi: int = -1

    def payload(self) -> bytes:
        """Encode this record's fields to the framed npz payload."""
        return _encode_arrays({
            "row_ids": np.asarray(self.row_ids, np.int64),
            "touched_keys": np.asarray(self.touched_keys, np.int64),
            "meta": np.array([self.epoch, self.n_before, self.owner_lo,
                              self.owner_hi], np.int64),
        })

    @classmethod
    def from_payload(cls, payload: bytes) -> "RetractRecord":
        """Decode a framed npz payload back into a record."""
        d = _decode_arrays(payload)
        meta = d["meta"]
        lo, hi = (int(meta[2]), int(meta[3])) if len(meta) >= 4 else (-1, -1)
        return cls(epoch=int(meta[0]), row_ids=d["row_ids"],
                   touched_keys=d["touched_keys"], n_before=int(meta[1]),
                   owner_lo=lo, owner_hi=hi)


class CommitLog:
    """The append-only commit log (one file, ``commits.wal``).

    Record framing (little-endian)::

        ┌──────────┬─────────┬────────┬─────────┬───────┬─────────────┐
        │ magic    │ version │ type   │ length  │ crc32 │ payload     │
        │ "CDWR"   │ u16     │ u16    │ u32     │ u32   │ npz bytes   │
        └──────────┴─────────┴────────┴─────────┴───────┴─────────────┘

    Appends are atomic at the record level through the CRC: a reader accepts
    a record only when the header parses, the payload is fully present, and
    its CRC32 matches — anything else is a torn tail and reading stops at
    the last valid record boundary. ``fsync="commit"`` makes each append
    durable before ``append`` returns.
    """

    def __init__(self, path: str, fsync: str = "commit"):
        """Open (creating if absent) the log at ``path`` for appending.

        The caller should run ``CommitLog.recover(path)`` first when the
        file may carry a torn tail (restore does) — appending after a torn
        tail would bury the corruption mid-file.
        """
        if fsync not in ("commit", "none"):
            raise ValueError(f"fsync must be 'commit' or 'none', got {fsync!r}")
        self.path = path
        self.fsync = fsync
        self._f = open(path, "ab")
        self._last_offset: Optional[int] = None

    def append(self, record) -> int:
        """Append one record (``CommitRecord`` or ``RetractRecord``); returns
        bytes written. Durable per the fsync policy before returning (the
        mutation's durability point)."""
        payload = record.payload()
        header = _REC_HEADER.pack(_REC_MAGIC, WAL_VERSION, record.rec_type,
                                  len(payload), zlib.crc32(payload))
        self._last_offset = self._f.tell()
        self._f.write(header)
        self._f.write(payload)
        self._f.flush()
        if self.fsync == "commit":
            os.fsync(self._f.fileno())
        return len(header) + len(payload)

    def rollback_last(self) -> None:
        """Truncate the record appended by the LAST ``append`` on this handle.

        The log-side half of ``DetectionService.rollback_last_commit`` (LIFO,
        like ``rollback_commit``): the router's broadcast recovery must not
        leave a record for a commit it rolled back, or a restore would
        replay it. Only the immediately-preceding append can be unwound.
        """
        if self._last_offset is None:
            raise WalError("no append to roll back on this log handle")
        self._f.truncate(self._last_offset)
        self._f.seek(self._last_offset)
        if self.fsync == "commit":
            os.fsync(self._f.fileno())
        self._last_offset = None

    def close(self) -> None:
        """Close the underlying file handle."""
        self._f.close()

    # -- reading ------------------------------------------------------------

    @staticmethod
    def scan(path: str) -> tuple[list, int, int]:
        """Parse the log: ``(records, valid_bytes, discarded_bytes)``.

        Reads records until EOF or the first invalid one (short header, bad
        magic, newer version, short payload, CRC mismatch). ``valid_bytes``
        is the offset of the last valid record boundary; everything after it
        counts as ``discarded_bytes`` — the torn tail a crash mid-append (or
        mid-payload flush) leaves behind. Missing file ⇒ ``([], 0, 0)``.
        """
        records: list = []
        if not os.path.exists(path):
            return records, 0, 0
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        n = len(data)
        while off + _REC_HEADER.size <= n:
            magic, version, rec_type, length, crc = _REC_HEADER.unpack_from(
                data, off)
            if magic != _REC_MAGIC or version > WAL_VERSION:
                break
            start = off + _REC_HEADER.size
            end = start + length
            if end > n:
                break
            payload = data[start:end]
            if zlib.crc32(payload) != crc:
                break
            if rec_type == REC_COMMIT:
                records.append(CommitRecord.from_payload(payload))
            elif rec_type == REC_RETRACT:
                records.append(RetractRecord.from_payload(payload))
            # unknown record types from same-version writers are skipped,
            # not fatal — forward-compatible markers
            off = end
        return records, off, n - off

    @staticmethod
    def recover(path: str) -> RecoveryInfo:
        """Truncate the log to its last valid record; returns what happened.

        Idempotent; a no-op on a clean log or a missing file. This is the
        torn-tail recovery step ``DetectionService.restore`` runs before
        replaying and before reopening the log for appends.
        """
        records, valid, discarded = CommitLog.scan(path)
        if discarded:
            with open(path, "rb+") as f:
                f.truncate(valid)
        return RecoveryInfo(records=len(records), valid_bytes=valid,
                            discarded_bytes=discarded)

    @staticmethod
    def read(path: str) -> Iterator:
        """Iterate the valid records of the log (torn tail silently ignored —
        run ``recover`` first when the truncation must be made durable)."""
        records, _, _ = CommitLog.scan(path)
        return iter(records)


# ---------------------------------------------------------------------------
# Framed containers (snapshots + spilled shard chunks share one format)
# ---------------------------------------------------------------------------

def write_framed(path: str, arrays: dict, magic: bytes = _SNAP_MAGIC,
                 version: int = SNAPSHOT_VERSION, fsync: bool = True) -> str:
    """Atomically write a checksummed framed npz container at ``path``.

    One header (magic, version, payload length, CRC32) followed by the npz
    payload — the same frame snapshots use, parameterized on ``magic`` so
    other single-blob files (``core/shardplan.py``'s spilled chunks) reuse
    the torn-write/bit-rot detection instead of inventing a format. The
    write goes through a temp file + ``os.replace`` so a crash mid-write
    never leaves a half-written file under the canonical name. Returns
    ``path``.
    """
    payload = _encode_arrays(arrays)
    header = _SNAP_HEADER.pack(magic, version, 0,
                               len(payload), zlib.crc32(payload))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(payload)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_framed(path: str, magic: bytes = _SNAP_MAGIC,
                version: int = SNAPSHOT_VERSION) -> dict:
    """Load one framed container; raises ``WalError`` when the frame is
    invalid (bad magic, newer version, truncation, CRC mismatch)."""
    with open(path, "rb") as f:
        header = f.read(_SNAP_HEADER.size)
        if len(header) < _SNAP_HEADER.size:
            raise WalError(f"{path}: truncated frame header")
        got_magic, got_version, _, length, crc = _SNAP_HEADER.unpack(header)
        if got_magic != magic:
            raise WalError(f"{path}: bad frame magic {got_magic!r}")
        if got_version > version:
            raise WalError(
                f"{path}: frame version {got_version} is newer than this "
                f"reader ({version})")
        payload = f.read(length)
    if len(payload) < length:
        raise WalError(f"{path}: truncated frame payload")
    if zlib.crc32(payload) != crc:
        raise WalError(f"{path}: frame checksum mismatch")
    return _decode_arrays(payload)


# ---------------------------------------------------------------------------
# Snapshots
# ---------------------------------------------------------------------------

def snapshot_path(state_dir: str, epoch: int) -> str:
    """Canonical snapshot filename for a given epoch."""
    return os.path.join(state_dir, f"snapshot-{epoch:08d}.snap")


def write_snapshot(state_dir: str, epoch: int, arrays: dict,
                   retention: int = 0) -> str:
    """Serialize ``arrays`` as the epoch's snapshot file, atomically.

    A ``write_framed`` container (``SNAPSHOT_VERSION`` + CRC32) under the
    canonical epoch filename; ``retention > 0`` prunes older snapshots down
    to that many afterwards. Returns the written path.
    """
    path = write_framed(snapshot_path(state_dir, epoch), arrays)
    if retention > 0:
        for _, old in list_snapshots(state_dir)[:-retention]:
            try:
                os.remove(old)
            except OSError:
                pass
    return path


def load_snapshot(path: str) -> dict:
    """Load one snapshot file; raises ``WalError`` when the frame is invalid
    (bad magic, newer version, truncation, CRC mismatch)."""
    return load_framed(path)


def list_snapshots(state_dir: str) -> list:
    """``[(epoch, path)]`` of snapshot files present, sorted by epoch."""
    out = []
    for name in os.listdir(state_dir):
        m = _SNAP_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(state_dir, name)))
    return sorted(out)


def latest_valid_snapshot(state_dir: str) -> tuple[int, str, dict, int]:
    """Newest snapshot that loads cleanly: ``(epoch, path, arrays, skipped)``.

    Walks candidates newest-first, skipping any file whose frame fails to
    validate (``skipped`` counts them) — a crash between snapshot writes or
    a bit-rotted newest file falls back to the previous one, whose log tail
    is still replayable because the log is never pruned. Raises
    ``NoValidSnapshotError`` when nothing loads.
    """
    skipped = 0
    for epoch, path in reversed(list_snapshots(state_dir)):
        try:
            return epoch, path, load_snapshot(path), skipped
        except (WalError, OSError):
            skipped += 1
    raise NoValidSnapshotError(f"no valid snapshot under {state_dir}")


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

def write_manifest(state_dir: str, manifest: dict) -> None:
    """Atomically write the service manifest (idempotent config JSON)."""
    manifest = dict(manifest)
    manifest["format"] = MANIFEST_VERSION
    path = os.path.join(state_dir, MANIFEST_NAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def read_manifest(state_dir: str) -> dict:
    """Read the service manifest; raises ``WalError`` when missing or from a
    newer format version."""
    path = os.path.join(state_dir, MANIFEST_NAME)
    if not os.path.exists(path):
        raise WalError(f"{state_dir}: no {MANIFEST_NAME} — not a state dir?")
    with open(path) as f:
        manifest = json.load(f)
    if manifest.get("format", 0) > MANIFEST_VERSION:
        raise WalError(
            f"{path}: manifest format {manifest.get('format')} is newer "
            f"than this reader ({MANIFEST_VERSION})")
    return manifest


__all__ = [
    "CommitLog", "CommitRecord", "DurabilityOptions", "NoValidSnapshotError",
    "RecoveryInfo", "ReplayDivergenceError", "RestoreInfo", "RetractRecord",
    "WalError", "LOG_NAME", "MANIFEST_NAME", "MANIFEST_VERSION",
    "SNAPSHOT_VERSION", "SPILL_MAGIC", "WAL_VERSION",
    "latest_valid_snapshot", "list_snapshots", "load_framed",
    "load_snapshot", "read_manifest", "snapshot_path", "write_framed",
    "write_manifest", "write_snapshot",
]
