"""Sampling strategies (§VI-A, §VI-E).

BYITEM (SAMPLE1)   — uniform random item columns at a fixed rate.
BYCELL (SAMPLE2)   — add random items until the fraction of non-empty cells
                     reaches a target.
SCALESAMPLE        — random items at a rate, but guarantee at least N=4
                     sampled items per source when possible; this is what
                     keeps copy-detection F-measure high on long-tail data
                     (Table IX).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import ClaimsDataset


def sample_by_item(ds: ClaimsDataset, rate: float, seed: int = 0) -> np.ndarray:
    """BYITEM (SAMPLE1): uniform random item columns at a fixed rate.

    Args:
      ds: the (S, D) claims dataset.
      rate: fraction of the D item columns to keep (at least 1 is kept).
      seed: RNG seed — the sample is a pure function of (ds shape, rate,
        seed), so detection runs are replayable (property-tested).

    Returns sorted unique item indices, shape (max(round(rate·D), 1),).
    """
    rng = np.random.default_rng(seed)
    D = ds.n_items
    k = max(int(round(rate * D)), 1)
    return np.sort(rng.choice(D, size=k, replace=False))


def sample_by_cell(ds: ClaimsDataset, cell_fraction: float, seed: int = 0) -> np.ndarray:
    """BYCELL (SAMPLE2): add random items until enough cells are covered.

    Args:
      ds: the (S, D) claims dataset.
      cell_fraction: target fraction of non-empty (source, item) cells the
        sampled columns must cover (≥, by construction).
      seed: RNG seed (deterministic, as for ``sample_by_item``).

    Returns sorted unique item indices (size data-dependent: long-tail data
    needs few dense columns, uniform data ≈ cell_fraction·D).
    """
    rng = np.random.default_rng(seed)
    prov = ds.provided_mask
    total_cells = int(prov.sum())
    target = cell_fraction * total_cells
    perm = rng.permutation(ds.n_items)
    cells_per_item = prov.sum(axis=0)
    csum = np.cumsum(cells_per_item[perm])
    k = int(np.searchsorted(csum, target)) + 1
    return np.sort(perm[:k])


def scale_sample(
    ds: ClaimsDataset, rate: float, min_per_source: int = 4, seed: int = 0
) -> np.ndarray:
    """SCALESAMPLE: ≥ ``min_per_source`` items per source, then fill to rate."""
    rng = np.random.default_rng(seed)
    S, D = ds.values.shape
    prov = ds.provided_mask
    chosen = np.zeros(D, dtype=bool)
    counts = np.zeros(S, dtype=np.int64)

    # pass 1: cover low-coverage sources first
    order = np.argsort(prov.sum(axis=1))
    for s in order:
        need = min_per_source - counts[s]
        if need <= 0:
            continue
        avail = np.nonzero(prov[s] & ~chosen)[0]
        if avail.size == 0:
            continue
        take = rng.choice(avail, size=min(need, avail.size), replace=False)
        chosen[take] = True
        counts += prov[:, take].sum(axis=1)

    # pass 2: random fill to the requested item rate
    target = max(int(round(rate * D)), int(chosen.sum()))
    remaining = np.nonzero(~chosen)[0]
    extra = target - int(chosen.sum())
    if extra > 0 and remaining.size:
        take = rng.choice(remaining, size=min(extra, remaining.size), replace=False)
        chosen[take] = True
    return np.nonzero(chosen)[0]
