"""Iterative truth finding with copy-aware vote discounting (§II, [6]).

Each round: (1) copy detection → Pr(copy) per pair; (2) value-probability
computation where each source's vote is discounted by the probability that it
provided the value independently; (3) source-accuracy update. Repeat until
accuracies converge (the motivating example converges in 5 rounds, Table II).

Vote model (ACCU of Dong et al. [6], vectorized):
  vote weight      σ_s = ln(n·A_s / (1−A_s))
  independence     I_{s,e} = Π_{t ∈ S̄(e), (A_t,t) ≻ (A_s,s)} (1 − c·Pr(copy)[s,t])
                   (each provider discounted by higher-accuracy co-providers,
                    the paper's ordering trick to count each pair once)
  value vote       vote_e = Σ_{s ∈ S̄(e)} σ_s · I_{s,e}
  probability      P(e) = e^{vote_e} / (Σ_{e' ∈ item(e)} e^{vote_e'} + n₀·e⁰)
                   with n₀ = max(n − |observed values|, 0) unobserved false
                   values at vote 0
  accuracy         A_s = mean_e∈claims(s) P(e), clipped to [.01, .99]

The independence matmul (L ⊙ H) @ V_all is MXU work — see DESIGN.md §2.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DetectionEngine
from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult


# ---------------------------------------------------------------------------
# Value groups: one entry per (item, value) INCLUDING singletons
# ---------------------------------------------------------------------------

@dataclass
class ValueGroups:
    """All distinct (item, value) claims, for vote computation."""

    V_all: np.ndarray        # (S, E_all) uint8
    entry_item: np.ndarray   # (E_all,)
    claim_entry: np.ndarray  # (S, D) int32 — entry id of each claim, −1 missing
    n_values_per_item: np.ndarray  # (D,)


def build_value_groups(ds: ClaimsDataset) -> ValueGroups:
    """Group every claim by (item, value) — singletons included.

    Unlike the inverted index (shared values only, §III), truth finding
    votes over ALL distinct values, so this builds the full (S, E_all)
    incidence plus the (S, D) claim→entry map used to expand entry
    probabilities back to per-claim probabilities each round."""
    values = ds.values
    S, D = values.shape
    prov = values >= 0
    max_v = int(values.max()) + 1 if prov.any() else 1
    key = np.where(prov, np.arange(D, dtype=np.int64)[None, :] * max_v + values, -1)
    uniq, inv = np.unique(key, return_inverse=True)
    inv = inv.reshape(S, D)
    has_missing = uniq[0] == -1
    offset = 1 if has_missing else 0
    E_all = len(uniq) - offset
    claim_entry = np.where(prov, inv - offset, -1).astype(np.int32)
    V_all = np.zeros((S, E_all), dtype=np.uint8)
    rows, cols = np.nonzero(prov)
    V_all[rows, claim_entry[rows, cols]] = 1
    entry_item = ((uniq[offset:]) // max_v).astype(np.int32)
    n_vals = np.bincount(entry_item, minlength=D).astype(np.int32)
    return ValueGroups(V_all=V_all, entry_item=entry_item,
                       claim_entry=claim_entry, n_values_per_item=n_vals)


# ---------------------------------------------------------------------------
# One fusion round, jitted
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n", "c", "n_items"))
def _vote_round(V_all, entry_item, acc, pr_copy, n, c, n_items, n_vals_per_item):
    """→ (entry probability P(e), new accuracy A)."""
    S = acc.shape[0]
    sigma = jnp.log(n * acc / (1.0 - acc))                       # (S,)
    # H[s,t] = 1 iff provider t ranks above s (accuracy, index tiebreak)
    rank = acc * S + jnp.arange(S, dtype=acc.dtype)              # strict total order
    H = (rank[None, :] > rank[:, None]).astype(jnp.float32)
    L = jnp.log1p(-jnp.clip(c * pr_copy, 0.0, 0.999))            # ln(1 − c·Pcp)
    logI = jnp.dot(L * H, V_all.astype(jnp.float32))             # (S, E_all)
    votes = jnp.sum(V_all * sigma[:, None] * jnp.exp(logI), axis=0)   # (E_all,)

    # per-item normalization incl. unobserved false values at vote 0
    seg_max = jax.ops.segment_max(votes, entry_item, num_segments=n_items)
    seg_max = jnp.maximum(seg_max, 0.0)                          # include e⁰ mass
    ex = jnp.exp(votes - seg_max[entry_item])
    denom_obs = jax.ops.segment_sum(ex, entry_item, num_segments=n_items)
    n_unobs = jnp.maximum(n - n_vals_per_item.astype(jnp.float32), 0.0)
    denom = denom_obs + n_unobs * jnp.exp(-seg_max)
    p_entry = ex / denom[entry_item]

    claims_per_src = jnp.maximum(jnp.sum(V_all, axis=1).astype(jnp.float32), 1.0)
    new_acc = jnp.dot(V_all.astype(jnp.float32), p_entry) / claims_per_src
    return p_entry, jnp.clip(new_acc, 0.01, 0.99)


# ---------------------------------------------------------------------------
# The iterative driver
# ---------------------------------------------------------------------------

# every detector is a DetectionEngine mode — the engine is the single entry
# point for detection compute (DESIGN.md §3); keyword args go to EngineOptions
_ENGINE_MODE = {
    "pairwise": "pairwise",
    "index_exact": "exact",
    "index": "bucketed",
    "bound": "bound",
    "bound+": "bound+",
    "hybrid": "hybrid",
}


def _engine_detector(mode: str) -> Callable:
    def run(ds, p_claim, cfg, **kw):
        return DetectionEngine(cfg, mode=mode, **kw).detect(ds, p_claim)
    return run


DETECTORS: dict[str, Callable] = {
    name: _engine_detector(mode) for name, mode in _ENGINE_MODE.items()
}


@dataclass
class FusionResult:
    """Converged truth-finding state plus per-round history/diagnostics."""

    accuracy: np.ndarray            # (S,) final accuracies
    p_entry: np.ndarray             # (E_all,) final value probabilities
    p_claim: np.ndarray             # (S, D) final claim probabilities
    groups: ValueGroups
    detection: DetectionResult
    rounds: int = 0
    accuracy_history: list = field(default_factory=list)
    p_history: list = field(default_factory=list)
    counters: list = field(default_factory=list)
    wall_time_s: float = 0.0
    detect_time_s: float = 0.0


def truth_finding(
    ds: ClaimsDataset,
    cfg: CopyConfig,
    detector: str | Callable = "hybrid",
    max_rounds: int = 12,
    tol: float = 5e-4,
    init_accuracy: float = 0.8,
    detector_kwargs: Optional[dict] = None,
    track_history: bool = False,
) -> FusionResult:
    """Iterative copy detection + truth finding + accuracy update (§II-A)."""
    t0 = time.perf_counter()
    kw = dict(detector_kwargs or {})
    inc_engine = None
    if detector == "incremental":
        detect = None
        inc_engine = DetectionEngine(cfg, mode="incremental", **kw)
    else:
        detect = DETECTORS[detector] if isinstance(detector, str) else detector
    groups = build_value_groups(ds)
    S, D = ds.values.shape

    work = ClaimsDataset(values=ds.values,
                         accuracy=np.full(S, init_accuracy, np.float32))
    # round 0: no copy knowledge yet — votes with Pr(copy)=0
    pr_copy = np.zeros((S, S), np.float32)
    p_entry, acc = _vote_round(
        jnp.asarray(groups.V_all), jnp.asarray(groups.entry_item),
        jnp.asarray(work.accuracy), jnp.asarray(pr_copy),
        cfg.n, cfg.c, D, jnp.asarray(groups.n_values_per_item),
    )
    acc_np = np.array(acc)
    history, p_hist, counters = [], [], []
    detection = None
    detect_time = 0.0

    for rnd in range(1, max_rounds + 1):
        work = ClaimsDataset(values=ds.values, accuracy=acc_np)
        p_claim = np.where(ds.values >= 0,
                           np.array(p_entry)[np.maximum(groups.claim_entry, 0)],
                           0.0).astype(np.float32)
        td0 = time.perf_counter()
        if inc_engine is not None:
            # §VI: HYBRID in the first round; round 2 bootstraps the engine's
            # incremental bookkeeping, later rounds apply per-round deltas
            if rnd < 2:
                detection = DetectionEngine(cfg, mode="hybrid", **kw).detect(
                    work, p_claim)
            else:
                detection = inc_engine.detect(work, p_claim)
        else:
            detection = detect(work, p_claim, cfg, **kw)
        detect_time += time.perf_counter() - td0
        counters.append(detection.counter)
        pr_copy = (1.0 - detection.pr_independent).astype(np.float32)

        p_entry, acc = _vote_round(
            jnp.asarray(groups.V_all), jnp.asarray(groups.entry_item),
            jnp.asarray(acc_np), jnp.asarray(pr_copy),
            cfg.n, cfg.c, D, jnp.asarray(groups.n_values_per_item),
        )
        new_acc = np.array(acc)
        if track_history:
            history.append(new_acc.copy())
            p_hist.append(np.array(p_entry).copy())
        delta = float(np.max(np.abs(new_acc - acc_np)))
        acc_np = new_acc
        if delta < tol:
            break

    p_claim = np.where(ds.values >= 0,
                       np.array(p_entry)[np.maximum(groups.claim_entry, 0)],
                       0.0).astype(np.float32)
    return FusionResult(
        accuracy=acc_np, p_entry=np.array(p_entry), p_claim=p_claim,
        groups=groups, detection=detection, rounds=rnd,
        accuracy_history=history, p_history=p_hist, counters=counters,
        wall_time_s=time.perf_counter() - t0, detect_time_s=detect_time,
    )


def fusion_accuracy(result: FusionResult, ds: ClaimsDataset,
                    true_values: np.ndarray) -> float:
    """Fraction of items whose top-probability value is the true one."""
    D = ds.n_items
    best = np.full(D, -1, np.int64)
    best_p = np.full(D, -np.inf)
    for e in range(len(result.p_entry)):
        d = result.groups.entry_item[e]
        if result.p_entry[e] > best_p[d]:
            best_p[d] = result.p_entry[e]
            best[d] = e
    # map entry back to a value id via any provider
    correct = 0
    total = 0
    V = result.groups.V_all
    for d in range(D):
        if best[d] < 0:
            continue
        providers = np.nonzero(V[:, best[d]])[0]
        if providers.size == 0:
            continue
        v = ds.values[providers[0], d]
        total += 1
        correct += int(v == true_values[d])
    return correct / max(total, 1)
