"""The INDEX algorithm (§III) in two forms.

``index_detect_exact``  — entry-sequential reference with the paper's exact
    computation accounting (Ex. 3.6: 26 pairs, 51 shared values, 154
    computations on the motivating example). NumPy; the oracle for the
    production path and the source of the paper-metric counters.

``bucketed_index_detect`` — compatibility wrapper over the production path,
    which now lives in the pair-tiled, sharded ``DetectionEngine``
    (core/engine.py, DESIGN.md §3). The bucket machinery stays here:
    entries sorted by contribution score are partitioned into K contiguous
    buckets with representative probability p̂_k (``pad_buckets``), the
    same-value accumulation becomes co-occurrence matmuls ``V_k V_kᵀ``
    combined with per-pair score tables ``f(A_i, A_j, p̂_k)``, and the
    different-value penalty is recovered from ``(l − n)·ln(1−s)`` exactly as
    the paper's step 3. Pairs within ``rescore_margin`` of the decision
    boundary are exactly rescored, so binary decisions match the exact
    algorithm. ``_bucketed_accumulate`` remains as the single-device oracle
    the distributed/tiled paths are tested against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import BucketedIndex, InvertedIndex, build_index
from repro.core.scoring import (
    decide_copying,
    posterior_independence,
    score_same,
    score_same_np,
)
from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult
from repro.utils.counters import ComputeCounter


# ---------------------------------------------------------------------------
# Exact INDEX (reference + paper-metric accounting)
# ---------------------------------------------------------------------------

def index_detect_exact(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    index: InvertedIndex | None = None,
) -> DetectionResult:
    """Algorithm INDEX, steps 1–3 (§III), entry-sequential."""
    t0 = time.perf_counter()
    idx = index if index is not None else build_index(ds, p_claim, cfg)
    S = ds.n_sources
    acc = ds.accuracy.astype(np.float64)

    c_same = np.zeros((S, S), dtype=np.float64)
    n_counts = np.zeros((S, S), dtype=np.int32)
    considered = np.zeros((S, S), dtype=bool)
    values_examined = 0

    # Scan non-Ē entries first, then Ē entries: for a fresh index this IS
    # the physical 0..E−1 order (Ē is the score suffix); for a committed
    # index (base + delta chunks, Ē as a mask — DESIGN.md §7) the split
    # restores the invariant step 2 relies on — every Ē entry sees the
    # FINAL considered set, exactly as in the score-ordered scan.
    nonebar = idx.nonebar_mask
    live = idx.live_mask
    scan_order = np.concatenate([np.nonzero(nonebar)[0],
                                 np.nonzero(live & ~nonebar)[0]])
    n_nonebar = int(nonebar.sum())
    for rank, e in enumerate(scan_order):
        srcs = idx.providers(e)
        if len(srcs) < 2:
            continue
        in_ebar = rank >= n_nonebar
        a = acc[srcs]
        # f[i, j] = C→ contribution for (copier=srcs[i], source=srcs[j])
        f = score_same_np(float(idx.entry_p[e]), a[:, None], a[None, :], cfg.s, cfg.n)
        sub = np.ix_(srcs, srcs)
        if not in_ebar:
            # Step 1: every provider pair
            pairmask = np.ones((len(srcs), len(srcs)), dtype=bool)
            np.fill_diagonal(pairmask, False)
            considered[sub] |= pairmask
        else:
            # Step 2: only pairs encountered before
            pairmask = considered[sub].copy()
            np.fill_diagonal(pairmask, False)
        c_same[sub] += np.where(pairmask, f, 0.0)
        n_counts[sub] += pairmask.astype(np.int32)
        values_examined += int(np.triu(pairmask, 1).sum())

    # Step 3: different-value adjustment for considered pairs
    c_fwd = np.where(
        considered, c_same + (idx.l_counts - n_counts) * cfg.ln_1ms, 0.0
    ).astype(np.float32)
    np.fill_diagonal(c_fwd, 0.0)

    pr_ind = np.array(posterior_independence(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    copying = np.array(decide_copying(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    # pairs never considered ⇒ no-copying with Pr⊥ > .5 (paper's Ē argument)
    pr_ind = np.where(considered, pr_ind, 1.0)
    copying = copying & considered
    np.fill_diagonal(pr_ind, 1.0)
    np.fill_diagonal(copying, False)

    n_pairs = int(np.triu(considered, 1).sum())
    counter = ComputeCounter(
        pairs_considered=n_pairs,
        shared_values_examined=values_examined,
        score_computations=2 * values_examined + 2 * n_pairs,
        index_entries=idx.n_entries,
    )
    return DetectionResult(c_fwd=c_fwd, pr_independent=pr_ind, copying=copying,
                           counter=counter, wall_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Bucketed INDEX (production)
# ---------------------------------------------------------------------------

@dataclass
class PaddedBuckets:
    """Score-ordered index padded to (K, S, w) for fixed-shape bucket scans."""

    v_ksw: jnp.ndarray        # (K, S, w) — incidence per bucket, zero-padded
    p_hat: jnp.ndarray        # (K,)
    m_suffix: jnp.ndarray     # (K+1,)
    ebar_bucket: int
    width: int

    @property
    def n_buckets(self) -> int:
        """K — number of buckets (leading axis of v_ksw)."""
        return self.v_ksw.shape[0]


def pad_buckets(b: BucketedIndex, dtype=None) -> PaddedBuckets:
    """dtype defaults to bf16 on TPU (halves HBM traffic) and f32 on CPU
    (bf16 matmuls are emulated ~10× slower there).

    NOTE: this materializes the full (K, S, w) bucket tensor — it remains
    only as the single-device oracle / legacy-baseline form. Production
    paths stream chunks from the ``CorpusStore`` instead (the engine via
    ``engine_chunks``, BOUND via ``_bound_stream``)."""
    if dtype is None:
        dtype = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32
    idx = b.index
    K = b.n_buckets
    S = idx.n_sources
    w = int(max(np.diff(b.starts))) if K else 1
    v = np.zeros((K, S, w), dtype=np.float32)
    for k in range(K):
        s0, s1 = int(b.starts[k]), int(b.starts[k + 1])
        v[k, :, : s1 - s0] = idx.store.slice_entries(s0, s1, dtype=np.float32)
    return PaddedBuckets(
        v_ksw=jnp.asarray(v, dtype=dtype),
        p_hat=jnp.asarray(b.p_hat, dtype=jnp.float32),
        m_suffix=jnp.asarray(b.m_suffix, dtype=jnp.float32),
        ebar_bucket=b.ebar_bucket,
        width=w,
    )


@partial(jax.jit, static_argnames=("s", "n", "ebar_bucket"))
def _bucketed_accumulate(v_ksw, p_hat, acc, s, n, ebar_bucket):
    """Scan over buckets: C_same→, shared counts n, counts outside Ē.

    C_same→[i,j] = Σ_k f→(A_i, A_j, p̂_k) · (V_k V_kᵀ)[i,j]
    """
    S = v_ksw.shape[1]
    f_a1 = acc[:, None]   # copier accuracy (rows)
    f_a2 = acc[None, :]   # source accuracy (cols)

    def body(carry, xs):
        c_same, n_cnt, n_out = carry
        v_k, p_k, k = xs
        count = jnp.dot(v_k, v_k.T, preferred_element_type=jnp.float32)
        f = score_same(p_k, f_a1, f_a2, s, n)
        c_same = c_same + f * count
        n_cnt = n_cnt + count
        n_out = n_out + jnp.where(k < ebar_bucket, count, 0.0)
        return (c_same, n_cnt, n_out), None

    init = (jnp.zeros((S, S), jnp.float32),) * 3
    ks = jnp.arange(v_ksw.shape[0])
    (c_same, n_cnt, n_out), _ = jax.lax.scan(body, init, (v_ksw, p_hat, ks))
    return c_same, n_cnt, n_out


def bucketed_index_detect(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    n_buckets: int = 64,
    rescore_margin: float = 1.0,
    index: InvertedIndex | None = None,
    tile: int = 256,
    devices: int | None = None,
) -> DetectionResult:
    """Production INDEX — routes through the pair-tiled DetectionEngine."""
    from repro.core.engine import DetectionEngine

    eng = DetectionEngine(cfg, mode="bucketed", n_buckets=n_buckets,
                          rescore_margin=rescore_margin, tile=tile,
                          devices=devices)
    return eng.detect(ds, p_claim, index=index)
