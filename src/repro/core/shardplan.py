"""Row-range-sharded corpus data plane (DESIGN.md §10).

Every replica so far held the ENTIRE corpus: the engine sharded pair tiles
over devices, but each host still materialized all S rows of every chunk.
This module is the storage half of the scale-out story:

  * ``ShardPlan`` — a row-range partition of the corpus: shard ``s`` owns
    the contiguous global rows ``[bounds[s], bounds[s+1])``. Plans are
    balanced on construction (``make_shard_plan``) and re-balanced after
    commit/retract growth skews them (``rebalance_plan`` /
    ``ShardedCorpusStore.rebalance``).
  * ``ShardedCorpusStore`` — a drop-in facade over per-shard row slices: it
    speaks the full ``CorpusStore`` consumer API (chunk views, slices,
    co-occurrence, gathers, row/entry mutation, snapshot/state_dict), but
    each shard holds ONLY its row slice of every chunk. Nothing below the
    facade ever allocates an (S, width) block — per-shard peak-resident
    bytes are tracked and asserted by ``BENCH_scaling``.
  * Cold-chunk **spill**: ``seal`` puts a shard's resident set under an LRU
    byte cap; evicted blocks land on disk in the WAL's checksummed-frame
    container (``wal.write_framed`` with ``SPILL_MAGIC``). A corrupt spill
    file (torn frame, CRC mismatch) is never trusted: the block is
    regathered from the committed source store when the facade was derived
    by ``gather_entries``, else a typed ``SpillCorruptionError`` surfaces.
  * **Bitpacking**: ``seal(pack=True)`` stores membership at 1 bit/entry
    (``store.pack_membership``), unpacked on gather — 8× on top of int8.
  * ``merge_shard_partials`` — the detection merge step: per-shard partial
    score/count grids cover disjoint pair tiles so they combine by sum,
    while the per-pair p̂-error bound merges by **elementwise max** — the
    exact-rescore trigger is therefore never weaker than single-host, which
    is what makes the merged decisions bit-equal to the unsharded engine
    (DetectionEngine's rescore argument, DESIGN.md §3.4/§10).

A shard failing mid-scan must never leak a partial decision matrix: the
engine wraps per-shard scans and raises one typed ``ShardScanError``.
"""
from __future__ import annotations

import os
import tempfile
from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

from repro.core import wal
from repro.core.store import (
    ChunkView,
    CorpusStore,
    PackedBlock,
    align_chunk,
    next_mseq,
    pack_membership,
    packed_count_matmul,
    unpack_membership,
)

#: Serialized-plan version (rides inside the store state dict).
SHARD_LAYOUT_VERSION = 1


class ShardScanError(RuntimeError):
    """One shard failed mid-scan; no partial decision matrix was produced.

    Raised by the engine's sharded tile scan: the merge step runs only
    after EVERY owning shard returned its partial grids, so a raising
    shard surfaces as this single typed error instead of a half-merged
    (and silently wrong) decision matrix.
    """

    def __init__(self, shard: int, message: str):
        super().__init__(f"shard {shard}: {message}")
        self.shard = int(shard)


class SpillCorruptionError(RuntimeError):
    """A spilled chunk failed frame validation and no source can regather it.

    When the facade was derived with ``gather_entries`` the corrupt block
    is silently regathered from the committed source store (and the spill
    file rewritten); only a facade with no regather source raises this.
    """


class SealedShardError(RuntimeError):
    """A mutating operation was attempted on a sealed (packed/spilled) store.

    Sealing freezes the block layout so spill files and packed blocks stay
    authoritative; call ``unseal()`` before committing/retracting rows.
    """


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardPlan:
    """A row-range partition: shard ``s`` owns rows [bounds[s], bounds[s+1]).

    ``bounds`` is a non-decreasing ``(n_shards + 1,)`` int64 array with
    ``bounds[0] == 0``; empty shards (equal consecutive bounds) are legal —
    a plan over fewer rows than shards simply leaves trailing shards empty.
    """

    bounds: np.ndarray

    def __post_init__(self):
        b = np.asarray(self.bounds, np.int64)
        if b.ndim != 1 or len(b) < 2 or b[0] != 0 or np.any(np.diff(b) < 0):
            raise ValueError(f"invalid shard bounds {b!r}")
        object.__setattr__(self, "bounds", b)

    @property
    def n_shards(self) -> int:
        """Number of shards in the plan."""
        return len(self.bounds) - 1

    @property
    def n_rows(self) -> int:
        """Total rows the plan covers (the last bound)."""
        return int(self.bounds[-1])

    def sizes(self) -> np.ndarray:
        """Rows per shard, ``(n_shards,)`` int64."""
        return np.diff(self.bounds)

    def range_of(self, s: int) -> tuple[int, int]:
        """Global row range ``[r0, r1)`` owned by shard ``s``."""
        return int(self.bounds[s]), int(self.bounds[s + 1])

    def owner_of_row(self, r: int) -> int:
        """Shard owning global row ``r`` (rows past the last bound → last)."""
        r = int(r)
        s = int(np.searchsorted(self.bounds, r, side="right")) - 1
        return min(max(s, 0), self.n_shards - 1)

    def imbalance(self) -> float:
        """max shard size / ideal size (1.0 = perfectly balanced)."""
        sizes = self.sizes()
        if self.n_rows == 0:
            return 1.0
        return float(sizes.max() * self.n_shards / self.n_rows)


def make_shard_plan(n_rows: int, n_shards: int) -> ShardPlan:
    """A balanced plan: shard sizes differ by at most one row."""
    n_rows, n_shards = int(n_rows), int(n_shards)
    if n_shards < 1:
        raise ValueError(f"need at least one shard, got {n_shards}")
    if n_rows < 0:
        raise ValueError(f"negative n_rows {n_rows}")
    bounds = (np.arange(n_shards + 1, dtype=np.int64) * n_rows) // n_shards
    return ShardPlan(bounds=bounds)


def rebalance_plan(plan: ShardPlan, n_rows: Optional[int] = None,
                   tolerance: float = 0.25) -> ShardPlan:
    """The plan to use after growth: re-split when skew exceeds tolerance.

    ``n_rows`` is the corpus's CURRENT row count (commits grow the last
    shard past ``plan.n_rows``; retractions shrink interior shards). The
    plan is extended to cover ``n_rows`` and re-balanced from scratch when
    its imbalance exceeds ``1 + tolerance``; otherwise the (extended)
    original plan is kept so shard-local state stays put.
    """
    rows = plan.n_rows if n_rows is None else int(n_rows)
    bounds = plan.bounds.copy()
    bounds[-1] = max(rows, int(bounds[-2]))
    grown = ShardPlan(bounds=bounds)
    if grown.imbalance() > 1.0 + float(tolerance):
        return make_shard_plan(rows, plan.n_shards)
    return grown


# ---------------------------------------------------------------------------
# Merge step (detection plane)
# ---------------------------------------------------------------------------

def merge_shard_partials(partials: list, shape: Optional[tuple] = None):
    """Combine per-shard partial pair grids into the single-host grids.

    Each element of ``partials`` is ``(c_same, count, count_outside, err)``
    full-size float32 grids with only that shard's owned tiles populated
    (everything else zero). Tile ownership partitions the pair space, so
    the three score/count channels combine by SUM (placement — on disjoint
    support, x + 0 is exact in any float order). The p̂-error bound channel
    combines by elementwise MAX: a bound must dominate EVERY shard's
    accumulated error for the pair, so max keeps the exact-rescore trigger
    at least as eager as single-host — the merged decision matrix is then
    bit-equal to the unsharded engine by the same rescore argument.
    Returns the four merged grids (zeros of ``shape`` when no partials).
    """
    if not partials:
        if shape is None:
            raise ValueError("merge_shard_partials: no partials and no shape")
        z = np.zeros(shape, np.float32)
        return z, z.copy(), z.copy(), z.copy()
    c_same, n_cnt, n_out, err = (p.copy() for p in partials[0])
    for cs, nc, no, er in partials[1:]:
        c_same += cs
        n_cnt += nc
        n_out += no
        np.maximum(err, er, out=err)
    return c_same, n_cnt, n_out, err


def scatter_tile_stacks(grids, coords, stacks, n_blocks: int,
                        tile: int) -> None:
    """Scatter both orientations of every unordered tile into full grids.

    The blocked transpose is a writable view, so fancy assignment on tile
    coordinates lands each (T, T) block in place. The (c, r) mirror of tile
    (r, c) is C_same←ᵀ for the score and the plain transpose for the
    symmetric-role channels; diagonal tiles write identical values twice.
    ``grids`` = [c_same, n_cnt, n_out, err]; ``stacks`` holds the five
    kernel channels (C→, C←, shared count, non-Ē count, error bound) as
    ``(≥ len(coords), T, T)`` arrays (device or host — mesh padding rows
    past ``len(coords)`` are ignored).
    """
    n = len(coords)
    rr, cc = coords[:, 0], coords[:, 1]
    cf_t, cb_t, n_t, o_t, e_t = (np.asarray(s, np.float32)[:n]
                                 for s in stacks)
    for grid, fwd, bwd in (
        (grids[0], cf_t, cb_t.transpose(0, 2, 1)),
        (grids[1], n_t, None),
        (grids[2], o_t, None),
        (grids[3], e_t, None),
    ):
        g4 = grid.reshape(n_blocks, tile, n_blocks, tile).transpose(0, 2, 1, 3)
        g4[rr, cc] = fwd
        g4[cc, rr] = fwd.transpose(0, 2, 1) if bwd is None else bwd


@dataclass
class OwnerPartial:
    """One shard-owner's share of a tiled detection pass (transport form).

    The shard-owner fan-out (DESIGN.md §12): each owner replica scans only
    the unordered pair tiles whose ROW block falls in its row range and
    ships the per-tile kernel outputs — not full ``(S_pad, S_pad)`` grids —
    back to the router. ``stacks`` holds the five kernel channels (C→, C←,
    shared count, non-Ē count, error bound) as ``(k, T, T)`` float32 host
    arrays aligned with ``coords``; ``to_grids`` scatters them into the
    full-size zero grids ``merge_shard_partials`` consumes. Tile ownership
    partitions the pair space, so scattering each owner's tiles and merging
    (sum / sum / sum / max) reproduces the single-host grids bit-exactly —
    the §3.4 rescore argument then carries decisions unchanged.
    """

    owner: int                 # shard-owner id under the placement plan
    n_blocks: int              # tile-grid edge (blocks per side)
    tile: int                  # tile edge T
    coords: np.ndarray         # (k, 2) int32 — this owner's surviving tiles
    stacks: Optional[list]     # 5 × (k, T, T) float32, or None (no work)
    chunk_tiles_run: int = 0   # chunk∘tile pairs this owner actually scanned

    @property
    def nbytes(self) -> int:
        """Transport payload size (what a real fan-out would ship)."""
        n = self.coords.nbytes
        if self.stacks is not None:
            n += sum(int(np.asarray(s).nbytes) for s in self.stacks)
        return n

    def to_grids(self) -> tuple:
        """This owner's partial grids, full-size with unowned tiles zero."""
        s_pad = self.n_blocks * self.tile
        grids = [np.zeros((s_pad, s_pad), np.float32) for _ in range(4)]
        if self.stacks is not None and len(self.coords):
            scatter_tile_stacks(grids, self.coords, self.stacks,
                                self.n_blocks, self.tile)
        return tuple(grids)


def merge_owner_partials(partials: list, n_blocks: int, tile: int):
    """Router-side merge of per-owner partials (DESIGN.md §12).

    Requires every owner exactly once — a missing or duplicate owner would
    silently drop or double its tiles' counts, so the merge refuses rather
    than produce a plausible-but-wrong decision grid (the fault-handling
    contract: no partial grids are ever merged after an owner failure).
    """
    owners = sorted(p.owner for p in partials)
    if owners != list(range(len(owners))):
        raise ValueError(
            f"owner partials must cover each owner exactly once, got "
            f"owners {owners}")
    return merge_shard_partials([p.to_grids() for p in partials],
                                shape=(n_blocks * tile, n_blocks * tile))


# ---------------------------------------------------------------------------
# Per-shard row slice
# ---------------------------------------------------------------------------

@dataclass
class _SpillRef:
    """Marker for a block whose bytes live on disk (spilled)."""

    path: str
    packed: bool               # was the resident form a PackedBlock?
    rows: int
    width: int


class _ShardSlice:
    """One shard's row slice of every chunk (dense | packed | spilled).

    ``blocks[c]`` holds this shard's rows of chunk ``c`` as a dense int8
    array (``(cap_rows, width)``), a ``PackedBlock`` (1 bit/entry), or a
    ``_SpillRef`` (bytes on disk). Residency is LRU-tracked; ``budget``
    caps resident bytes once sealed. ``peak_bytes`` records the high-water
    mark (packed blocks counted at their packed size — 1 bit/entry).
    """

    def __init__(self, shard_id: int, start: int, cap_rows: int):
        self.shard_id = int(shard_id)
        self.start = int(start)
        self.cap_rows = int(cap_rows)
        self.blocks: list = []
        self.sealed = False
        self.budget: Optional[int] = None
        self.spill_dir: Optional[str] = None
        self.peak_bytes = 0
        self._lru: OrderedDict = OrderedDict()   # chunk id → resident bytes
        self._owner = None                       # back-ref for regather

    # -- residency accounting ------------------------------------------------

    @staticmethod
    def _block_bytes(blk) -> int:
        if isinstance(blk, np.ndarray):
            return int(blk.nbytes)
        if isinstance(blk, PackedBlock):
            return blk.nbytes
        return 0

    @property
    def resident_bytes(self) -> int:
        """Bytes of incidence currently held in memory by this shard."""
        return sum(self._block_bytes(b) for b in self.blocks)

    def _note_peak(self) -> None:
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)

    def _touch(self, c: int) -> None:
        self._lru[c] = self._block_bytes(self.blocks[c])
        self._lru.move_to_end(c)

    # -- block access ---------------------------------------------------------

    def block_width(self, c: int) -> int:
        """Column count of chunk ``c``'s block."""
        blk = self.blocks[c]
        if isinstance(blk, np.ndarray):
            return blk.shape[1]
        return blk.width

    def get_block(self, c: int) -> np.ndarray:
        """Chunk ``c``'s rows as dense int8 ``(cap_rows, width)``.

        Packed blocks unpack transiently (the packed form stays resident);
        spilled blocks reload from disk — evicting colder blocks to stay
        under the budget — with corrupt frames regathered from the source
        store (see ``_reload``).
        """
        blk = self.blocks[c]
        if isinstance(blk, _SpillRef):
            blk = self._reload(c)
        self._touch(c)
        if isinstance(blk, PackedBlock):
            return unpack_membership(blk)
        return blk

    def packed_block(self, c: int) -> Optional[PackedBlock]:
        """Chunk ``c``'s resident ``PackedBlock``, or None when not packed."""
        blk = self.blocks[c]
        return blk if isinstance(blk, PackedBlock) else None

    # -- spill machinery --------------------------------------------------------

    def _spill_path(self, c: int) -> str:
        return os.path.join(self.spill_dir,
                            f"shard-{self.shard_id:03d}-chunk-{c:05d}.spill")

    def _write_spill(self, c: int) -> str:
        """Persist chunk ``c``'s resident block as a checksummed frame."""
        blk = self.blocks[c]
        if isinstance(blk, PackedBlock):
            arrays = {"bits": blk.bits,
                      "meta": np.array([1, blk.bits.shape[0], blk.width],
                                       np.int64)}
        else:
            arrays = {"bits": blk,
                      "meta": np.array([0, blk.shape[0], blk.shape[1]],
                                       np.int64)}
        return wal.write_framed(self._spill_path(c), arrays,
                                magic=wal.SPILL_MAGIC, fsync=False)

    def evict(self, c: int) -> None:
        """Spill chunk ``c`` to disk and drop its resident bytes (idempotent)."""
        blk = self.blocks[c]
        if isinstance(blk, _SpillRef):
            return
        if self.spill_dir is None:
            raise SealedShardError(
                f"shard {self.shard_id}: no spill_dir; seal(spill_dir=...) first")
        packed = isinstance(blk, PackedBlock)
        path = self._spill_path(c)
        if not os.path.exists(path):
            self._write_spill(c)
        rows = blk.bits.shape[0] if packed else blk.shape[0]
        width = blk.width if packed else blk.shape[1]
        self.blocks[c] = _SpillRef(path=path, packed=packed,
                                   rows=rows, width=width)
        self._lru.pop(c, None)

    def _reload(self, c: int):
        """Reinstate a spilled block, healing corrupt frames via regather."""
        ref = self.blocks[c]
        try:
            d = wal.load_framed(ref.path, magic=wal.SPILL_MAGIC)
            meta = np.asarray(d["meta"], np.int64)
            if int(meta[0]):
                blk = PackedBlock(bits=np.asarray(d["bits"], np.uint8),
                                  width=int(meta[2]))
            else:
                blk = np.asarray(d["bits"], np.int8)
        except wal.WalError as e:
            blk = self._regather_block(c, ref, cause=e)
        self.blocks[c] = blk
        self._enforce_budget(protect=c)
        self._note_peak()
        return blk

    def _regather_block(self, c: int, ref: _SpillRef, cause: Exception):
        """Rebuild a corrupt spilled block from the committed source store.

        The facade records ``(source, order)`` when it was derived by
        ``gather_entries``; the corrupt frame is rebuilt from those exact
        source columns (bit-equal by construction — the same gather that
        produced the block originally) and the spill file rewritten. A
        facade with no source cannot regather → ``SpillCorruptionError``.
        """
        owner = self._owner
        regather = getattr(owner, "_regather", None) if owner else None
        if regather is None:
            raise SpillCorruptionError(
                f"shard {self.shard_id} chunk {c}: corrupt spill frame "
                f"({cause}) and no source store to regather from") from cause
        source, order = regather
        w = owner.chunk_entries
        sel = order[c * w: c * w + ref.width]
        dense = _gather_rows_cols(source, sel, self.start,
                                  self.start + ref.rows)
        blk = pack_membership(dense) if ref.packed else dense
        self.blocks[c] = blk
        self._write_spill(c)      # heal the on-disk copy
        return blk

    def _enforce_budget(self, protect: Optional[int] = None) -> None:
        """Evict LRU blocks until resident bytes fit the budget."""
        if self.budget is None:
            return
        while self.resident_bytes > self.budget and self._lru:
            victim = next(iter(self._lru))
            if victim == protect:
                self._lru.move_to_end(victim)
                if len(self._lru) == 1:
                    break
                victim = next(iter(self._lru))
            self.evict(victim)


def _gather_rows_cols(src, order_slice: np.ndarray, r0: int,
                      r1: int) -> np.ndarray:
    """Dense ``(r1 − r0, len(order_slice))`` gather of global rows × columns.

    ``order_slice`` may contain ``-1`` padding markers (zero columns). Rows
    past the source's capacity read as zero (slack). Works for both a plain
    ``CorpusStore`` (direct chunk slicing) and a ``ShardedCorpusStore``
    (per-shard assembly) — the regather fallback and ``gather_entries``
    share it.
    """
    order_slice = np.asarray(order_slice, np.int64)
    out = np.zeros((r1 - r0, len(order_slice)), np.int8)
    live = order_slice >= 0
    if not live.any():
        return out
    cols = order_slice[live]
    dst = np.nonzero(live)[0]
    w = max(src.chunk_entries, 1)
    for cid in np.unique(cols // w):
        m = cols // w == cid
        if isinstance(src, ShardedCorpusStore):
            blk = src.assemble_rows(int(cid), r0, r1)
            out[:, dst[m]] = blk[:, cols[m] - cid * w]
        else:
            src_blk = src.chunks[int(cid)]
            hi = min(r1, src_blk.shape[0])
            if hi > r0:
                out[: hi - r0, dst[m]] = src_blk[r0:hi, cols[m] - cid * w]
    return out


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------

class ShardedCorpusStore:
    """Row-range-sharded ``CorpusStore`` facade (DESIGN.md §10).

    Speaks the full consumer API of ``CorpusStore`` — chunk views, column /
    slice / co-occurrence access, ``gather_entries``, the row/entry
    mutation protocol (append, truncate, retract, deactivate, delta
    chunks), snapshot/rollback, ``state_dict`` — but the incidence lives as
    per-shard row slices (``_ShardSlice``): shard ``s`` holds rows
    ``[starts[s], starts[s+1])`` of every chunk and nothing else. Entry
    metadata (item / value / p / score) is row-independent and stays
    global, sharing the copy-on-write discipline of ``CorpusStore``.

    Consumers that need a dense row range assemble it explicitly
    (``assemble_rows``); the per-shard resident set is what ``seal`` packs
    to 1 bit/entry and spills under an LRU byte cap.
    """

    def __init__(self, slices: list, starts: np.ndarray, widths: list,
                 entry_item, entry_value, entry_p, entry_score,
                 chunk_entries: int, n_rows: int, capacity: int,
                 delta_start: Optional[int], epoch: int):
        self._slices = list(slices)
        self._starts = np.asarray(starts, np.int64)
        self._widths = list(int(w) for w in widths)
        self.entry_item = entry_item
        self.entry_value = entry_value
        self.entry_p = entry_p
        self.entry_score = entry_score
        self.chunk_entries = int(chunk_entries)
        self.n_rows = int(n_rows)
        self.capacity = int(capacity)
        self.delta_start = delta_start
        self.epoch = int(epoch)
        # membership-state identity (block-OR cache validity); same
        # always-fresh discipline as CorpusStore.mseq
        self.mseq = next_mseq()
        self._regather = None            # (source store, gather order)
        for sl in self._slices:
            sl._owner = self

    # -- plan / geometry ------------------------------------------------------

    @property
    def n_shards(self) -> int:
        """Number of row-range shards."""
        return len(self._slices)

    @property
    def plan(self) -> ShardPlan:
        """The current row-range plan (last bound = live rows)."""
        return ShardPlan(bounds=np.append(self._starts,
                                          max(self.n_rows,
                                              int(self._starts[-1]))))

    def _coverage(self, s: int) -> tuple[int, int]:
        """Global row range shard ``s``'s blocks physically cover."""
        cov0 = int(self._starts[s])
        cov1 = (int(self._starts[s + 1]) if s + 1 < self.n_shards
                else self.capacity)
        return cov0, cov1

    @property
    def n_entries(self) -> int:
        """E — total entry columns across chunks (padding included)."""
        return len(self.entry_item)

    @property
    def n_chunks(self) -> int:
        """Number of entry chunks."""
        return len(self._widths)

    @property
    def max_chunk_nbytes(self) -> int:
        """Largest single resident incidence allocation across all shards."""
        return max((sl._block_bytes(b) for sl in self._slices
                    for b in sl.blocks), default=0)

    @property
    def n_live_entries(self) -> int:
        """Entries that are real (non-padding) columns."""
        return int(np.count_nonzero(self.entry_item >= 0))

    @property
    def n_delta_entries(self) -> int:
        """Live entries in the delta region (appended since the last base)."""
        if self.delta_start is None:
            return 0
        return int(np.count_nonzero(self.entry_item[self.delta_start:] >= 0))

    @property
    def n_delta_chunks(self) -> int:
        """Chunks that hold at least one delta entry."""
        if self.delta_start is None:
            return 0
        return self.n_chunks - self.delta_start // self.chunk_entries

    def chunk_start(self, c: int) -> int:
        """Global index of chunk ``c``'s first entry column."""
        return c * self.chunk_entries

    # -- sealing / residency ----------------------------------------------------

    @property
    def sealed(self) -> bool:
        """True once ``seal`` froze the block layout (read-only mode)."""
        return any(sl.sealed for sl in self._slices)

    def _require_mutable(self) -> None:
        if self.sealed:
            raise SealedShardError(
                "store is sealed (packed/spilled blocks); unseal() before "
                "mutating")

    def seal(self, pack: bool = False, spill_dir: Optional[str] = None,
             resident_bytes: Optional[int] = None) -> None:
        """Freeze the block layout; optionally bitpack and cap residency.

        ``pack=True`` converts every dense block to a ``PackedBlock``
        (1 bit/entry — 8× over int8; gathers unpack transiently).
        ``resident_bytes`` puts EACH shard's resident set under an LRU byte
        cap, spilling cold blocks to checksummed frames under
        ``spill_dir`` (a temp dir is created when a cap is given without
        one). Mutations raise ``SealedShardError`` until ``unseal``.
        """
        if resident_bytes is not None and spill_dir is None:
            spill_dir = tempfile.mkdtemp(prefix="cd-spill-")
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
        for sl in self._slices:
            sl.sealed = True
            sl.spill_dir = spill_dir
            sl.budget = (None if resident_bytes is None
                         else int(resident_bytes))
            if pack:
                sl.blocks = [pack_membership(b) if isinstance(b, np.ndarray)
                             else b for b in sl.blocks]
            sl._lru = OrderedDict(
                (c, sl._block_bytes(b)) for c, b in enumerate(sl.blocks)
                if not isinstance(b, _SpillRef))
            sl._note_peak()
            sl._enforce_budget()

    def unseal(self) -> None:
        """Reload/unpack every block to dense int8 and re-enable mutation."""
        for sl in self._slices:
            sl.budget = None
            for c in range(len(sl.blocks)):
                blk = sl.blocks[c]
                if isinstance(blk, _SpillRef):
                    blk = sl._reload(c)
                if isinstance(blk, PackedBlock):
                    sl.blocks[c] = unpack_membership(blk)
            sl.sealed = False
            sl._lru.clear()
            sl._note_peak()

    def evict_block(self, shard: int, c: int) -> None:
        """Spill one block of one shard (test/operator hook; needs a seal)."""
        self._slices[shard].evict(c)

    def shard_resident_bytes(self) -> list:
        """Per-shard resident incidence bytes (packed counted packed)."""
        return [sl.resident_bytes for sl in self._slices]

    def shard_peak_bytes(self) -> list:
        """Per-shard peak resident incidence bytes since construction."""
        return [max(sl.peak_bytes, sl.resident_bytes)
                for sl in self._slices]

    def reset_peak_bytes(self) -> None:
        """Restart the per-shard peak-resident high-water marks from now.

        Construction (``shard_store``) materializes each shard's row slice
        as dense int8 before ``seal`` packs/spills it; benchmarks call this
        after sealing so the reported peak reflects steady-state residency
        under the byte budget rather than the one-off build transient.
        """
        for sl in self._slices:
            sl.peak_bytes = sl.resident_bytes

    # -- assembly primitives ------------------------------------------------------

    def assemble_rows(self, c: int, r0: int, r1: int) -> np.ndarray:
        """Dense int8 ``(r1 − r0, width_c)`` slab of chunk ``c``'s rows.

        Rows beyond the live range read as zero (slack / tile padding), so
        the engine can request tile-aligned slabs straight off the facade.
        """
        out = np.zeros((r1 - r0, self._widths[c]), np.int8)
        for s, sl in enumerate(self._slices):
            cov0, cov1 = self._coverage(s)
            lo, hi = max(r0, cov0), min(r1, cov1)
            if lo < hi:
                blk = sl.get_block(c)
                out[lo - r0: hi - r0] = blk[lo - cov0: hi - cov0]
        return out

    def block_or(self, c: int, tile: int, n_blocks: int) -> np.ndarray:
        """Per-tile OR-reduction of chunk ``c`` — bool ``(n_blocks, width)``.

        The engine's tile∘chunk pruning input, computed shard by shard so
        no host ever assembles the full chunk for it.
        """
        out = np.zeros((n_blocks, self._widths[c]), bool)
        for s, sl in enumerate(self._slices):
            cov0, cov1 = self._coverage(s)
            hi = min(cov1, self.n_rows)
            if hi <= cov0:
                continue
            blk = sl.get_block(c)
            b0, b1 = cov0 // tile, (hi - 1) // tile
            for b in range(b0, min(b1, n_blocks - 1) + 1):
                lo = max(b * tile - cov0, 0)
                up = min((b + 1) * tile - cov0, hi - cov0)
                if up > lo:
                    out[b] |= blk[lo:up].any(axis=0)
        return out

    # -- CorpusStore consumer API ---------------------------------------------

    def chunk(self, c: int) -> ChunkView:
        """Chunk ``c`` as a handle (incidence assembled across shards).

        Unlike ``CorpusStore.chunk`` the incidence is a fresh assembly, not
        a memoized view — caching assembled chunks would silently grow a
        host's residency back to the full corpus.
        """
        s0 = self.chunk_start(c)
        s1 = s0 + self._widths[c]
        return ChunkView(
            start=s0,
            V=self.assemble_rows(c, 0, self.n_rows),
            item=self.entry_item[s0:s1],
            value=self.entry_value[s0:s1],
            p=self.entry_p[s0:s1],
            score=self.entry_score[s0:s1],
        )

    def iter_chunks(self) -> Iterator[ChunkView]:
        """Iterate chunk handles in entry order."""
        for c in range(self.n_chunks):
            yield self.chunk(c)

    def column(self, e: int) -> np.ndarray:
        """Incidence column of entry ``e`` over live rows (assembled)."""
        c, off = divmod(int(e), self.chunk_entries)
        out = np.zeros(self.n_rows, np.int8)
        for s, sl in enumerate(self._slices):
            cov0, cov1 = self._coverage(s)
            hi = min(cov1, self.n_rows)
            if hi > cov0:
                out[cov0:hi] = sl.get_block(c)[: hi - cov0, off]
        return out

    def providers(self, e: int) -> np.ndarray:
        """S̄(E) — indices of the sources providing entry ``e``'s value."""
        return np.nonzero(self.column(e))[0]

    def slice_entries(self, e0: int, e1: int,
                      dtype=np.int8, rows: Optional[int] = None) -> np.ndarray:
        """Dense ``(rows, e1 − e0)`` gather of an entry range across chunks.

        Bit-equal to ``CorpusStore.slice_entries`` over the same corpus —
        the shard assembly only changes WHERE the rows come from.
        """
        e0, e1 = int(e0), int(e1)
        n = self.n_rows if rows is None else int(rows)
        out = np.zeros((n, e1 - e0), dtype)
        w = self.chunk_entries
        nr = min(n, self.n_rows)
        for c in range(e0 // w if w else 0, self.n_chunks):
            s0 = self.chunk_start(c)
            if s0 >= e1:
                break
            s1 = s0 + self._widths[c]
            lo, hi = max(e0, s0), min(e1, s1)
            if lo < hi:
                for s, sl in enumerate(self._slices):
                    cov0, cov1 = self._coverage(s)
                    rhi = min(cov1, nr)
                    if rhi > cov0:
                        blk = sl.get_block(c)
                        out[cov0:rhi, lo - e0: hi - e0] = \
                            blk[: rhi - cov0, lo - s0: hi - s0]
        return out

    def to_dense(self) -> np.ndarray:
        """The full ``(n_rows, E)`` incidence — compat/debug accessor ONLY."""
        if self.n_chunks == 0:
            return np.zeros((self.n_rows, 0), np.int8)
        return np.concatenate(
            [self.assemble_rows(c, 0, self.n_rows)
             for c in range(self.n_chunks)], axis=1)

    def cooccurrence(self, stop: Optional[int] = None,
                     dtype=np.float32,
                     mask: Optional[np.ndarray] = None) -> np.ndarray:
        """Pair co-occurrence counts over selected entries (chunk-streamed).

        Same chunk order and float32 0/1-product arithmetic as
        ``CorpusStore.cooccurrence`` — exact small integers, hence
        bit-equal to the dense matmul for any sharding. Fully-selected
        chunks whose shards are all bitpacked accumulate through
        ``packed_count_matmul`` (byte-AND + popcount) without unpacking —
        also exact integers, so equality still holds bit-for-bit.
        """
        S = self.n_rows
        out = np.zeros((S, S), dtype)
        stop_eff = self.n_entries if stop is None else int(stop)
        for c in range(self.n_chunks):
            s0 = self.chunk_start(c)
            wc = self._widths[c]
            if mask is not None:
                m = mask[s0: s0 + wc]
                if not m.any():
                    continue
                whole = bool(m.all())
            else:
                if s0 >= stop_eff:
                    break
                whole = s0 + wc <= stop_eff
                m = None
            if whole and self._packed_coocc(c, out, dtype):
                continue
            v = self.assemble_rows(c, 0, S)
            if mask is not None and not whole:
                v = v[:, m]
            elif mask is None and not whole:
                v = v[:, : stop_eff - s0]
            v = v.astype(dtype)
            out += v @ v.T
        return out

    def _packed_coocc(self, c: int, out: np.ndarray, dtype) -> bool:
        """Accumulate chunk ``c``'s counts straight off packed bits.

        Returns False (caller falls back to assembly) unless EVERY shard
        holds the chunk as a resident ``PackedBlock``.
        """
        packs = []
        for s, sl in enumerate(self._slices):
            pb = sl.packed_block(c)
            if pb is None:
                return False
            cov0, cov1 = self._coverage(s)
            lv = max(min(cov1, self.n_rows) - cov0, 0)
            packs.append((cov0, lv,
                          PackedBlock(bits=pb.bits[:lv], width=pb.width)))
        for i, (ri, ni, pi) in enumerate(packs):
            if ni == 0:
                continue
            for rj, nj, pj in packs[i:]:
                if nj == 0:
                    continue
                blk = packed_count_matmul(pi, pj, dtype)
                out[ri: ri + ni, rj: rj + nj] += blk
                if rj != ri:
                    out[rj: rj + nj, ri: ri + ni] += blk.T
        return True

    # -- derived stores -----------------------------------------------------

    def gather_entries(self, order: np.ndarray,
                       chunk_entries: Optional[int] = None,
                       capacity: Optional[int] = None) -> "ShardedCorpusStore":
        """A sharded store whose column ``j`` is this store's ``order[j]``.

        Same plan, shard by shard: shard ``s`` of the result is gathered
        ONLY from shard ``s`` of the source — no host touches rows it does
        not own. The result remembers ``(source, order)`` so corrupt spill
        frames can be regathered (``_SpillRef`` fallback).
        """
        order = np.asarray(order, np.int64)
        E_out = len(order)
        w = (self.chunk_entries if chunk_entries is None
             else align_chunk(chunk_entries))
        cap = (self.capacity if capacity is None
               else max(int(capacity), self.n_rows))
        live = order >= 0
        safe = np.where(live, order, 0)

        item = np.full(E_out, -1, np.int32)
        value = np.full(E_out, -1, np.int32)
        p = np.zeros(E_out, np.float32)
        score = np.zeros(E_out, np.float32)
        item[live] = self.entry_item[safe[live]]
        value[live] = self.entry_value[safe[live]]
        p[live] = self.entry_p[safe[live]]
        score[live] = self.entry_score[safe[live]]

        starts = self._starts.copy()
        slices, widths = [], []
        for s in range(self.n_shards):
            cov0 = int(starts[s])
            cov1 = int(starts[s + 1]) if s + 1 < self.n_shards else cap
            slices.append(_ShardSlice(s, cov0, max(cov1 - cov0, 0)))
        for j0 in range(0, E_out, max(w, 1)):
            width = min(w, E_out - j0)
            widths.append(width)
            sel = order[j0: j0 + width]
            for s, sl in enumerate(slices):
                blk = _gather_rows_cols(self, sel, sl.start,
                                        sl.start + sl.cap_rows)
                sl.blocks.append(blk)
        out = ShardedCorpusStore(
            slices=slices, starts=starts, widths=widths,
            entry_item=item, entry_value=value, entry_p=p, entry_score=score,
            chunk_entries=w, n_rows=self.n_rows, capacity=cap,
            delta_start=None, epoch=0)
        out._regather = (self, order)
        for sl in out._slices:
            sl._note_peak()
        return out

    # -- row mutation ---------------------------------------------------------

    def append_rows(self, values_rows: np.ndarray,
                    collect_touched: bool = False):
        """Stage incidence rows for new sources (always in the LAST shard).

        Semantics identical to ``CorpusStore.append_rows`` — global row ids
        keep growing at the end, and the end of the row space belongs to
        the last shard until a ``rebalance`` re-splits.
        """
        self._require_mutable()
        values_rows = np.asarray(values_rows, np.int32)
        q = values_rows.shape[0]
        if self.n_rows + q > self.capacity:
            raise ValueError(
                f"append_rows: {q} rows exceed capacity "
                f"({self.n_rows}/{self.capacity} used)")
        last = self._slices[-1]
        loc = self.n_rows - last.start
        bits = 0
        touched = []
        for c in range(self.n_chunks):
            s0 = self.chunk_start(c)
            s1 = s0 + self._widths[c]
            it = self.entry_item[s0:s1]
            va = self.entry_value[s0:s1]
            ok = it >= 0
            hit = np.zeros((q, s1 - s0), np.int8)
            if ok.any() and q:
                hit[:, ok] = (
                    values_rows[:, it[ok]] == va[ok][None, :]
                ).astype(np.int8)
            last.blocks[c][loc: loc + q] = hit
            bits += int(hit.sum())
            if collect_touched:
                touched.append(s0 + np.nonzero(hit.any(axis=0))[0])
        self.n_rows += q
        self.mseq = next_mseq()
        if collect_touched:
            return bits, (np.concatenate(touched) if touched
                          else np.zeros(0, np.int64))
        return bits

    def truncate_rows(self, n_rows: int) -> None:
        """Drop appended rows back down to ``n_rows`` (zeroing their slack)."""
        self._require_mutable()
        n_rows = int(n_rows)
        if n_rows > self.n_rows:
            raise ValueError(
                f"truncate_rows({n_rows}) above n_rows={self.n_rows}")
        last = self._slices[-1]
        if n_rows < last.start:
            raise ValueError(
                f"truncate_rows({n_rows}) would cross the last shard "
                f"boundary ({last.start}); retract_rows handles committed rows")
        lo = n_rows - last.start
        hi = self.n_rows - last.start
        for blk in last.blocks:
            blk[lo:hi] = 0
        self.n_rows = n_rows
        self.mseq = next_mseq()

    def retract_rows(self, row_ids: np.ndarray) -> None:
        """Physically remove arbitrary live rows (source retraction).

        Each shard compacts its own surviving rows in place (fresh arrays —
        a pre-retraction snapshot's refs stay bit-exact for rollback); the
        shard starts shift down by the rows removed before them. Bumps
        ``epoch``. GC bookkeeping is the caller's job (``index.retract_rows``).
        """
        self._require_mutable()
        row_ids = np.unique(np.asarray(row_ids, np.int64))
        if len(row_ids) == 0:
            return
        if row_ids[0] < 0 or row_ids[-1] >= self.n_rows:
            raise ValueError(
                f"retract_rows: ids out of range [0, {self.n_rows})")
        keep = np.ones(self.n_rows, bool)
        keep[row_ids] = False
        new_starts = self._starts.copy()
        offset = 0
        for s, sl in enumerate(self._slices):
            cov0, cov1 = self._coverage(s)
            hi = min(cov1, self.n_rows)
            lv = max(hi - cov0, 0)
            k_local = keep[cov0:hi]
            n_keep = int(k_local.sum())
            new_starts[s] = offset
            for c in range(self.n_chunks):
                old = sl.blocks[c]
                blk = np.zeros((sl.cap_rows, old.shape[1]), np.int8)
                if n_keep:
                    blk[:n_keep] = old[:lv][k_local]
                sl.blocks[c] = blk
            offset += n_keep
        for s, sl in enumerate(self._slices):
            sl.start = int(new_starts[s])
        self._starts = new_starts
        self.capacity = int(new_starts[-1]) + self._slices[-1].cap_rows
        self.n_rows = offset
        self.epoch += 1
        self.mseq = next_mseq()

    def deactivate_entries(self, entry_ids: np.ndarray) -> None:
        """Turn entry columns into inert padding (retraction GC).

        Copy-on-write on the touched blocks of EVERY shard and on the
        global metadata arrays, mirroring ``CorpusStore.deactivate_entries``.
        Bumps ``epoch``.
        """
        self._require_mutable()
        entry_ids = np.asarray(entry_ids, np.int64)
        if len(entry_ids) == 0:
            return
        w = self.chunk_entries
        for cid in np.unique(entry_ids // w):
            cols = entry_ids[entry_ids // w == cid] - cid * w
            for sl in self._slices:
                blk = sl.blocks[int(cid)].copy()
                blk[:, cols] = 0
                sl.blocks[int(cid)] = blk
        item = self.entry_item.copy()
        value = self.entry_value.copy()
        p = self.entry_p.copy()
        score = self.entry_score.copy()
        item[entry_ids] = -1
        value[entry_ids] = -1
        p[entry_ids] = 0.0
        score[entry_ids] = 0.0
        self.entry_item, self.entry_value = item, value
        self.entry_p, self.entry_score = p, score
        self.epoch += 1
        self.mseq = next_mseq()

    # -- entry mutation ---------------------------------------------------------

    def _pad_last_chunk_full(self) -> None:
        """Pad the trailing chunk to uniform width with inert columns.

        Per-shard padded COPIES replace the old blocks (snapshot refs stay
        bit-exact), and the global metadata grows the same inert columns
        ``CorpusStore._pad_last_chunk_full`` would add.
        """
        if not self._widths:
            return
        w = self._widths[-1]
        if w == self.chunk_entries:
            return
        pad = self.chunk_entries - w
        for sl in self._slices:
            old = sl.blocks[-1]
            blk = np.zeros((sl.cap_rows, self.chunk_entries), np.int8)
            blk[:, :w] = old
            sl.blocks[-1] = blk
        self._widths[-1] = self.chunk_entries
        self.entry_item = np.concatenate(
            [self.entry_item, np.full(pad, -1, np.int32)])
        self.entry_value = np.concatenate(
            [self.entry_value, np.full(pad, -1, np.int32)])
        self.entry_p = np.concatenate(
            [self.entry_p, np.zeros(pad, np.float32)])
        self.entry_score = np.concatenate(
            [self.entry_score, np.zeros(pad, np.float32)])

    def append_entries(self, cols: np.ndarray, item, value, p, score) -> int:
        """Append new entry columns as delta chunks, split by shard rows.

        Mirrors ``CorpusStore.append_entries`` exactly in metadata and
        chunk addressing; the new columns' rows land on the shard that
        owns them. Bumps ``epoch``; returns delta chunks added.
        """
        self._require_mutable()
        cols = np.asarray(cols, np.int8)
        n_new = cols.shape[1]
        if n_new == 0:
            return 0
        if cols.shape[0] != self.n_rows:
            raise ValueError(
                f"append_entries: {cols.shape[0]} rows, store has "
                f"{self.n_rows}")
        self._pad_last_chunk_full()
        if self.delta_start is None:
            self.delta_start = self.n_entries
        w = self.chunk_entries
        added = 0
        for j0 in range(0, n_new, w):
            width = min(w, n_new - j0)
            for s, sl in enumerate(self._slices):
                cov0, cov1 = self._coverage(s)
                hi = min(cov1, self.n_rows)
                blk = np.zeros((sl.cap_rows, width), np.int8)
                if hi > cov0:
                    blk[: hi - cov0] = cols[cov0:hi, j0: j0 + width]
                sl.blocks.append(blk)
            self._widths.append(width)
            added += 1
        self.entry_item = np.concatenate(
            [self.entry_item, np.asarray(item, np.int32)])
        self.entry_value = np.concatenate(
            [self.entry_value, np.asarray(value, np.int32)])
        self.entry_p = np.concatenate(
            [self.entry_p, np.asarray(p, np.float32)])
        self.entry_score = np.concatenate(
            [self.entry_score, np.asarray(score, np.float32)])
        self.epoch += 1
        self.mseq = next_mseq()
        return added

    def ensure_row_capacity(self, n: int) -> None:
        """Grow row capacity (slack lives in the LAST shard; geometric)."""
        self._require_mutable()
        if n <= self.capacity:
            return
        new_cap = max(int(n), 2 * self.capacity)
        last = self._slices[-1]
        new_local = new_cap - last.start
        lv = max(self.n_rows - last.start, 0)
        for c in range(self.n_chunks):
            blk = np.zeros((new_local, last.blocks[c].shape[1]), np.int8)
            blk[:lv] = last.blocks[c][:lv]
            last.blocks[c] = blk
        last.cap_rows = new_local
        self.capacity = new_cap
        self.epoch += 1
        # no mseq bump — capacity growth is membership-preserving (see
        # CorpusStore.ensure_row_capacity)

    # -- rebalance ---------------------------------------------------------------

    def rebalance(self, tolerance: float = 0.25) -> bool:
        """Re-split rows evenly when commit/retract growth skewed the plan.

        Returns True when rows moved. Chunks are re-sliced one at a time
        (transiently assembling ONE chunk, never the incidence whole);
        see OPERATIONS.md for the operator runbook.
        """
        self._require_mutable()
        new_plan = rebalance_plan(self.plan, self.n_rows, tolerance)
        if np.array_equal(np.append(self._starts,
                                    max(self.n_rows, int(self._starts[-1]))),
                          new_plan.bounds):
            return False
        starts = new_plan.bounds[:-1].copy()
        slices = []
        for s in range(len(starts)):
            cov0 = int(starts[s])
            cov1 = (int(starts[s + 1]) if s + 1 < len(starts)
                    else self.capacity)
            slices.append(_ShardSlice(s, cov0, max(cov1 - cov0, 0)))
        for c in range(self.n_chunks):
            full = self.assemble_rows(c, 0, self.capacity)
            for sl in slices:
                sl.blocks.append(
                    np.ascontiguousarray(
                        full[sl.start: sl.start + sl.cap_rows]))
        for sl in slices:
            sl._owner = self
            sl._note_peak()
        self._slices = slices
        self._starts = starts
        self.epoch += 1
        self.mseq = next_mseq()
        return True

    # -- snapshot / serialization --------------------------------------------

    def snapshot(self) -> "ShardedStoreSnapshot":
        """Capture a rollback point (block refs, not copies — O(blocks))."""
        return ShardedStoreSnapshot(
            store=self,
            blocks=[list(sl.blocks) for sl in self._slices],
            cap_rows=[sl.cap_rows for sl in self._slices],
            starts=self._starts.copy(), widths=list(self._widths),
            entry_item=self.entry_item, entry_value=self.entry_value,
            entry_p=self.entry_p, entry_score=self.entry_score,
            n_rows=self.n_rows, capacity=self.capacity,
            delta_start=self.delta_start, epoch=self.epoch)

    def state_dict(self, prefix: str = "store/") -> dict:
        """Flat ``{key: ndarray}`` dict capturing this store bit-exactly.

        The chunk payload is identical to ``CorpusStore.state_dict`` over
        the same corpus (assembled, trimmed to live rows) — an unsharded
        loader reads it unchanged — plus a ``shard_starts`` key that
        shard-aware loaders (``from_state_dict``, the service restore
        path) use to re-establish the SAME row-range plan.
        """
        d = {
            prefix + "meta": np.array(
                [1, self.chunk_entries, self.n_rows,
                 -1 if self.delta_start is None else self.delta_start,
                 self.epoch, self.n_chunks], np.int64),
            prefix + "entry_item": self.entry_item,
            prefix + "entry_value": self.entry_value,
            prefix + "entry_p": self.entry_p,
            prefix + "entry_score": self.entry_score,
            prefix + "shard_starts": np.concatenate(
                [np.array([SHARD_LAYOUT_VERSION], np.int64), self._starts]),
        }
        for c in range(self.n_chunks):
            d[f"{prefix}chunk_{c:05d}"] = self.assemble_rows(
                c, 0, self.n_rows)
        return d

    @classmethod
    def from_state_dict(cls, d: dict, prefix: str = "store/",
                        capacity: Optional[int] = None) -> "ShardedCorpusStore":
        """Rebuild a sharded store (same plan) from ``state_dict`` output."""
        marker = np.asarray(d[prefix + "shard_starts"], np.int64)
        if int(marker[0]) > SHARD_LAYOUT_VERSION:
            raise ValueError(
                f"shard layout version {int(marker[0])} is newer than this "
                f"reader ({SHARD_LAYOUT_VERSION})")
        base = CorpusStore.from_state_dict(d, prefix=prefix,
                                           capacity=capacity)
        plan = ShardPlan(bounds=np.append(marker[1:], base.n_rows))
        return shard_store(base, plan)


@dataclass
class ShardedStoreSnapshot:
    """Rollback point for one ``ShardedCorpusStore`` (refs, not copies)."""

    store: "ShardedCorpusStore"
    blocks: list                 # per shard: list of block refs
    cap_rows: list
    starts: np.ndarray
    widths: list
    entry_item: np.ndarray
    entry_value: np.ndarray
    entry_p: np.ndarray
    entry_score: np.ndarray
    n_rows: int
    capacity: int
    delta_start: Optional[int]
    epoch: int

    def restore(self) -> None:
        """Put the captured store back to its snapshot state, bit-exact.

        Restores block refs, shard starts, and capacities, then zeroes the
        row slack of every dense block — staged rows were written in place
        (the same contract as ``StoreSnapshot.restore``).
        """
        st = self.store
        for s, sl in enumerate(st._slices):
            sl.blocks = list(self.blocks[s])
            sl.cap_rows = int(self.cap_rows[s])
            sl.start = int(self.starts[s])
            sl._lru.clear()
        st._starts = self.starts.copy()
        st._widths = list(self.widths)
        st.entry_item = self.entry_item
        st.entry_value = self.entry_value
        st.entry_p = self.entry_p
        st.entry_score = self.entry_score
        st.delta_start = self.delta_start
        st.epoch = self.epoch
        st.n_rows = self.n_rows
        st.capacity = self.capacity
        # fresh mseq on restore (never re-issue an observed membership key)
        st.mseq = next_mseq()
        for s, sl in enumerate(st._slices):
            cov0, cov1 = st._coverage(s)
            lv = max(min(cov1, st.n_rows) - cov0, 0)
            for blk in sl.blocks:
                if isinstance(blk, np.ndarray):
                    blk[lv:] = 0


def shard_store(store: CorpusStore, plan, *, pack: bool = False,
                spill_dir: Optional[str] = None,
                resident_bytes: Optional[int] = None,
                consume: bool = False) -> ShardedCorpusStore:
    """Slice a ``CorpusStore`` into a ``ShardedCorpusStore`` under ``plan``.

    ``plan`` is a ``ShardPlan`` or a shard count. Incidence rows are COPIED
    into per-shard blocks (the source store is not mutated unless
    ``consume``); entry metadata arrays are shared (both sides follow
    copy-on-write). Row slack beyond the committed rows lands in the last
    shard.

    ``pack`` / ``spill_dir`` / ``resident_bytes`` stream the SEAL through
    the build (DESIGN.md §12): each per-shard block is bitpacked as it is
    sliced and evicted under the LRU byte cap the moment the shard's
    resident set exceeds it — the returned store is already sealed, and no
    shard's peak-resident bytes ever exceed the cap DURING the build,
    where the old slice-everything-then-``seal()`` path transiently held
    every shard's full dense slice. ``consume=True`` additionally releases
    each source chunk once all shards sliced it
    (``CorpusStore.release_chunk``), bounding a from-scratch S=1M build to
    one source chunk plus the capped shard residents.
    """
    if isinstance(plan, int):
        plan = make_shard_plan(store.n_rows, plan)
    if plan.n_rows != store.n_rows:
        raise ValueError(
            f"plan covers {plan.n_rows} rows, store has {store.n_rows}")
    streaming = pack or spill_dir is not None or resident_bytes is not None
    if resident_bytes is not None and spill_dir is None:
        spill_dir = tempfile.mkdtemp(prefix="cd-spill-")
    if spill_dir is not None:
        os.makedirs(spill_dir, exist_ok=True)
    starts = plan.bounds[:-1].copy()
    n_shards = plan.n_shards
    slices = []
    widths = [blk.shape[1] for blk in store.chunks]
    for s in range(n_shards):
        cov0 = int(starts[s])
        cov1 = int(starts[s + 1]) if s + 1 < n_shards else store.capacity
        sl = _ShardSlice(s, cov0, max(cov1 - cov0, 0))
        if streaming:
            sl.sealed = True
            sl.spill_dir = spill_dir
            sl.budget = (None if resident_bytes is None
                         else int(resident_bytes))
        slices.append(sl)
    # chunk-major fill: every shard takes its rows of chunk c before chunk
    # c+1 is touched, so a streaming build can seal (pack + budget-evict)
    # each block immediately and release the source chunk behind it
    for c in range(store.n_chunks):
        src = store.chunks[c]
        for s, sl in enumerate(slices):
            cov1 = int(starts[s + 1]) if s + 1 < n_shards else store.capacity
            blk = np.zeros((sl.cap_rows, widths[c]), np.int8)
            lv = max(min(cov1, store.n_rows) - sl.start, 0)
            if lv:
                blk[:lv] = src[sl.start: sl.start + lv]
            if streaming and pack:
                blk = pack_membership(blk)
            sl.blocks.append(blk)
            if streaming:
                sl._touch(c)
                sl._note_peak()
                sl._enforce_budget()
        if consume:
            store.release_chunk(c)
    for sl in slices:
        sl._note_peak()
    return ShardedCorpusStore(
        slices=slices, starts=starts, widths=widths,
        entry_item=store.entry_item, entry_value=store.entry_value,
        entry_p=store.entry_p, entry_score=store.entry_score,
        chunk_entries=store.chunk_entries, n_rows=store.n_rows,
        capacity=store.capacity, delta_start=store.delta_start,
        epoch=store.epoch)


__all__ = [
    "OwnerPartial", "SHARD_LAYOUT_VERSION", "SealedShardError", "ShardPlan",
    "ShardScanError", "ShardedCorpusStore", "ShardedStoreSnapshot",
    "SpillCorruptionError", "make_shard_plan", "merge_owner_partials",
    "merge_shard_partials", "rebalance_plan", "scatter_tile_stacks",
    "shard_store",
]

