"""Data model for structured-source copy detection.

A *claims dataset* is the paper's (S, D) world: a set of sources each
providing at most one value per data item. Values are integer-coded per
item (two sources share a value on item d iff their codes are equal and
nonnegative). ``-1`` encodes a missing value.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class CopyConfig:
    """Model hyper-parameters of the Bayesian copy model (§II-A).

    alpha: a-priori probability of one source copying another (0 < α < .5).
    s:     copy selectivity — probability a copier copies a particular item.
    n:     number of uniformly-distributed false values per item.
    c:     discount applied to a copier's vote during truth finding.
    """

    alpha: float = 0.1
    s: float = 0.8
    n: float = 50.0
    c: float = 0.8

    @property
    def beta(self) -> float:
        """β = 1 − 2α: a-priori probability the pair is independent (§II-A)."""
        return 1.0 - 2.0 * self.alpha

    @property
    def theta_ind(self) -> float:
        """No-copying threshold θ_ind = ln(β/2α) (§IV-A)."""
        return float(np.log(self.beta / (2.0 * self.alpha)))

    @property
    def theta_cp(self) -> float:
        """Copying threshold θ_cp = ln(β/α) (§IV-A)."""
        return float(np.log(self.beta / self.alpha))

    @property
    def ln_1ms(self) -> float:
        """Different-value contribution ln(1−s) (Eq. 8)."""
        return float(np.log(1.0 - self.s))

    def replace(self, **kw) -> "CopyConfig":
        """A copy of this config with the given fields replaced."""
        return dataclasses.replace(self, **kw)


@dataclass
class ClaimsDataset:
    """values[s, d] = integer value id provided by source s on item d (−1 = missing)."""

    values: np.ndarray              # (S, D) int32
    accuracy: np.ndarray            # (S,)  float32 — current accuracy estimates A(S)
    item_names: Optional[Sequence[str]] = None
    source_names: Optional[Sequence[str]] = None
    value_names: Optional[dict] = None   # {(item, value_id): str}

    def __post_init__(self):
        self.values = np.asarray(self.values, dtype=np.int32)
        self.accuracy = np.asarray(self.accuracy, dtype=np.float32)
        assert self.values.ndim == 2
        assert self.accuracy.shape == (self.values.shape[0],)

    @property
    def n_sources(self) -> int:
        """|S| — number of sources (rows)."""
        return self.values.shape[0]

    @property
    def n_items(self) -> int:
        """|D| — number of data items (columns)."""
        return self.values.shape[1]

    @property
    def provided_mask(self) -> np.ndarray:
        """(S, D) bool — True where the source provides a value."""
        return self.values >= 0

    @property
    def items_per_source(self) -> np.ndarray:
        """|D̄(S)| per source."""
        return self.provided_mask.sum(axis=1).astype(np.int32)

    def claim_probability(self, value_probs: dict) -> np.ndarray:
        """Expand a {(d, v): P(D.v)} map to a (S, D) matrix of per-claim truth
        probabilities (probability the value *this source provided* is true)."""
        p = np.zeros(self.values.shape, dtype=np.float32)
        for s in range(self.n_sources):
            for d in range(self.n_items):
                v = self.values[s, d]
                if v >= 0:
                    p[s, d] = value_probs[(d, int(v))]
        return p

    def row_view(self, n_rows: int) -> "ClaimsDataset":
        """A ZERO-COPY view of the first ``n_rows`` sources.

        The returned dataset shares this dataset's buffers — the serving
        layer's resident corpus (``core/serving.ResidentCorpus``) uses this
        to expose corpus + staged query rows without concatenating a new
        dataset per batch (DESIGN.md §6). Mutating either aliases the other.
        """
        return ClaimsDataset(
            values=self.values[:n_rows],
            accuracy=self.accuracy[:n_rows],
            item_names=self.item_names,
        )

    def subset_items(self, item_idx: np.ndarray) -> "ClaimsDataset":
        """The dataset restricted to the given item columns (sources kept).

        This is the sampling projection of §VI: detection on the subset is
        the cheap candidate-discovery pass of ``sampled``/``sample_verify``
        (DESIGN.md §4)."""
        return ClaimsDataset(
            values=self.values[:, item_idx],
            accuracy=self.accuracy.copy(),
            item_names=[self.item_names[i] for i in item_idx] if self.item_names else None,
            source_names=self.source_names,
        )


@dataclass
class DetectionResult:
    """Output of a copy-detection algorithm for every ordered pair."""

    c_fwd: np.ndarray            # (S, S) C→ : [i, j] = evidence that i copies from j
    pr_independent: np.ndarray   # (S, S) Pr(Si ⊥ Sj | Φ), symmetric
    copying: np.ndarray          # (S, S) bool, symmetric: Pr⊥ ≤ .5
    counter: object = None       # ComputeCounter
    wall_time_s: float = 0.0

    @property
    def c_bwd(self) -> np.ndarray:
        """C← — evidence that j copies from i (the transpose, §II symmetry)."""
        return self.c_fwd.T

    def copying_pairs(self) -> set:
        """The detected unordered copying pairs as a set of (i, j), i < j."""
        s = set()
        idx = np.argwhere(self.copying)
        for i, j in idx:
            if i < j:
                s.add((int(i), int(j)))
        return s


#: Composite-key base for (item, value) claim keys: key = item·KEY_BASE + value.
#: One fixed base (rather than a per-dataset max) keeps keys comparable across
#: epochs — the result cache intersects key sets from different commits
#: (DESIGN.md §7), so the coding must not shift as new value ids appear.
CLAIM_KEY_BASE = np.int64(1) << 32


def claim_value_keys(values: np.ndarray) -> np.ndarray:
    """Composite int64 keys of the provided (item, value) claims in ``values``.

    ``values`` is any ``(…, D)`` integer value matrix in the corpus coding
    (−1 = missing). Returns the sorted unique keys ``d·CLAIM_KEY_BASE + v``
    of all provided claims — the currency of ``commit_rows``'s delta
    detection and of the serving cache's invalidation test: two sources can
    share a value iff their key sets intersect.
    """
    values = np.asarray(values)
    d = np.broadcast_to(
        np.arange(values.shape[-1], dtype=np.int64), values.shape)
    keys = d * CLAIM_KEY_BASE + values
    return np.unique(keys[values >= 0])


def pair_f_measure(pred: set, truth: set) -> tuple:
    """Precision/recall/F of detected copying pairs vs a reference set."""
    if not pred and not truth:
        return 1.0, 1.0, 1.0
    tp = len(pred & truth)
    prec = tp / len(pred) if pred else 0.0
    rec = tp / len(truth) if truth else 0.0
    f = 2 * prec * rec / (prec + rec) if prec + rec > 0 else 0.0
    return prec, rec, f
