"""Async double-buffered chunk staging for the tiled engine (DESIGN.md §11).

The tiled engine streams entry-chunk groups host→device: assemble a
``(S_pad, G, b)`` v-slab on the host, move it to device, run the tile
kernel. Done synchronously, the kernel idles for the full staging time of
every group. ``ChunkPrefetcher`` runs the staging on a producer thread a
configurable ``depth`` of groups ahead (modeled on
``repro.data.tokens.Prefetcher``), so group G+1's host copy and transfer
hide behind group G's compute.

Telemetry (all wall seconds, accumulated across the pass):

  * ``staging_s``   — time the producer spent assembling + transferring;
  * ``stage_wait_s``— time the CONSUMER blocked waiting for a staged group
    (pipeline stall: staging is the bottleneck);
  * ``compute_wait_s`` — time the PRODUCER blocked on a full queue
    (compute is the bottleneck — the healthy state).

``depth=0`` degrades to fully synchronous staging in the consumer's
thread; ``stage_wait_s`` then equals ``staging_s`` by construction, which
is what makes "prefetch hides staging" a measurable claim
(``stage_wait_s`` with prefetch < ``staging_s`` without).

A raising stage function surfaces as a typed ``PipelineStageError`` on the
consumer side (original exception chained); ``close`` always reaps the
thread and drains staged payloads so no device buffers are stranded.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable

#: Sentinel kinds flowing through the queue alongside staged payloads.
_ITEM, _DONE, _ERROR = "item", "done", "error"


class PipelineStageError(RuntimeError):
    """A prefetch stage thread failed; the original exception is chained."""


class ChunkPrefetcher:
    """Iterate staged payloads, staging up to ``depth`` groups ahead.

    ``stage_fn(descriptor)`` runs on the producer thread (``depth`` ≥ 1) or
    inline (``depth=0``) and returns the staged payload. The iterator
    yields payloads in descriptor order and raises ``PipelineStageError``
    if a stage failed. Always ``close()`` in a finally block.
    """

    def __init__(self, descriptors: Iterable, stage_fn: Callable,
                 depth: int = 2):
        """Start staging ``descriptors`` through ``stage_fn``."""
        self.stage_wait_s = 0.0
        self.compute_wait_s = 0.0
        self.staging_s = 0.0
        self._stage_fn = stage_fn
        self._depth = max(int(depth), 0)
        self._stop = False
        self.thread = None
        if self._depth == 0:
            self._it = iter(descriptors)
            return
        self._descs = list(descriptors)
        self.q: queue.Queue = queue.Queue(maxsize=self._depth)
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    # -- producer ------------------------------------------------------------

    def _put(self, payload) -> bool:
        """Queue-put that never blocks past a ``close()``; False = stopped."""
        while not self._stop:
            try:
                self.q.put(payload, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _work(self) -> None:
        try:
            for d in self._descs:
                if self._stop:
                    return
                t0 = time.perf_counter()
                staged = self._stage_fn(d)
                self.staging_s += time.perf_counter() - t0
                t1 = time.perf_counter()
                ok = self._put((_ITEM, staged))
                self.compute_wait_s += time.perf_counter() - t1
                if not ok:
                    return
            self._put((_DONE, None))
        except BaseException as exc:  # surfaced typed on the consumer side
            self._put((_ERROR, exc))

    # -- consumer ------------------------------------------------------------

    def __iter__(self):
        """Iterator protocol — the engine's group loop is a plain for."""
        return self

    def __next__(self):
        """Next staged payload; blocks until staged (timed as stall)."""
        if self._depth == 0:
            d = next(self._it)           # StopIteration ends the loop
            t0 = time.perf_counter()
            try:
                staged = self._stage_fn(d)
            except StopIteration:
                raise
            except BaseException as exc:
                raise PipelineStageError(
                    f"chunk staging failed: {exc!r}") from exc
            dt = time.perf_counter() - t0
            self.staging_s += dt
            self.stage_wait_s += dt      # consumer waited the full time
            return staged
        t0 = time.perf_counter()
        while True:
            try:
                kind, payload = self.q.get(timeout=0.5)
                break
            except queue.Empty:
                if not self.thread.is_alive():
                    raise PipelineStageError(
                        "prefetch stage thread died without a result")
        self.stage_wait_s += time.perf_counter() - t0
        if kind == _DONE:
            raise StopIteration
        if kind == _ERROR:
            raise PipelineStageError(
                f"chunk staging failed: {payload!r}") from payload
        return payload

    def close(self) -> None:
        """Stop the stage thread and drop staged payloads (device buffers).

        Idempotent; safe mid-iteration (the engine calls it in a finally on
        success AND failure paths). Draining the queue releases every
        already-staged device array so an aborted pass strands nothing.
        """
        self._stop = True
        if self.thread is None:
            return
        for _ in range(2):               # drain → join → drain again
            try:
                while True:
                    self.q.get_nowait()
            except queue.Empty:
                pass
            if self.thread.is_alive():
                self.thread.join(timeout=5.0)


__all__ = ["ChunkPrefetcher", "PipelineStageError"]
