"""BOUND / BOUND+ / HYBRID (§IV) — early-terminating detection.

TPU adaptation (DESIGN.md §2.2): the paper terminates per pair mid-scan; we
terminate at *bucket* granularity. After each score-ordered bucket we evaluate
the paper's bounds for all active pairs at once:

  C^min = C⁰ + (l − n₀)·ln(1−s)                                  (Eq. 9)
  C^max = C⁰ + (h − n₀)·ln(1−s) + (l − h)·M                      (Eq. 10)
    h = clip(max(n(S1)·l/|D̄(S1)|, n(S2)·l/|D̄(S2)|), n₀, l)
    M = exact max score of the unscanned suffix (m_suffix)

and freeze pairs that cross θ_cp = ln β/α (copying) or fall below
θ_ind = ln β/2α (no-copying). Frozen pairs stop accumulating C⁰/n₀ (their
values at the decision point are what INCREMENTAL's bookkeeping needs),
while the total shared-value count n keeps counting (the paper's |Ē⋈|).

BOUND+ re-check timers (§IV-B) are implemented faithfully per pair: after a
failed copying check, C^min is not re-evaluated until n₀ grew by
T^min = ⌈(θ_cp − max C^min)/(M − ln(1−s))⌉; after a failed no-copying check,
C^max is not re-evaluated until (h − n₀) grew by T₀^max.

HYBRID applies bounds only to pairs sharing more than ``l_threshold`` items
(default 16, the paper's empirical crossover).

The scan STREAMS buckets out of the chunked ``CorpusStore`` (DESIGN.md §6):
one jitted per-bucket step is driven from the host, each step gathering only
its bucket's entry columns — the ``(K, S, w)`` bucket tensor of the legacy
``pad_buckets`` path is never materialized.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import (
    BucketedIndex,
    InvertedIndex,
    bucketize,
    build_index,
    canonicalized,
)
from repro.core.scoring import (
    bucket_score_deltas,
    decide_copying,
    pair_scores_subset,
    posterior_independence,
    score_same,
)
from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult
from repro.utils.counters import ComputeCounter


@dataclass
class BoundState:
    """Post-scan per-pair state (all (S,S) numpy), consumed by INCREMENTAL."""

    c0: np.ndarray             # C⁰→ at decision point (== final for undecided)
    n0: np.ndarray             # shared values seen at decision point
    n_full: np.ndarray         # total shared values (all buckets)
    decided: np.ndarray        # int8: +1 copying, −1 no-copying, 0 till Step IV
    dec_bucket: np.ndarray     # bucket index of the decision (K if undecided)
    considered: np.ndarray     # co-occur outside Ē
    c_hat: np.ndarray          # Ĉ→ = C⁰_dec + (l − n)·ln(1−s)  (§V preparation)
    err: np.ndarray = None     # Σ δ_k·count accumulated p̂-error bound on C⁰→


@partial(jax.jit, static_argnames=("s", "n", "theta_cp", "theta_ind",
                                   "ln1ms", "use_timers", "K"))
def _bound_step(carry, v_k, p_k, m_next, delta_k, k, acc, l_counts, d_src,
                considered, boundable, s, n, theta_cp, theta_ind, ln1ms,
                use_timers, K):
    """One score-ordered bucket of the BOUND scan (Eqs. 9–10 + timers).

    ``v_k`` is the bucket's (S, w) incidence slice, zero-padded to the fixed
    maximum bucket width so every step reuses one compiled program. The
    carry threads the legacy 10-tuple plus the ``err`` accumulator:
    Σ δ_k·count bounds |C⁰ − C⁰_exact| (the p̂ approximation), and every
    freeze must now hold BEYOND the pair's accumulated error — which makes
    frozen decisions provably equal the exact INDEX for any bucketing,
    including a committed index's base+delta layout (DESIGN.md §7).
    """
    (c0, n0, n_full, nscan, decided, dec_bucket, min_due, max_due,
     err, ve, bc) = carry
    f_a1 = acc[:, None]
    f_a2 = acc[None, :]
    lf = l_counts.astype(jnp.float32)

    count = jnp.dot(v_k, v_k.T, preferred_element_type=jnp.float32)
    active = (decided == 0) & considered
    f = score_same(p_k, f_a1, f_a2, s, n)

    upd = active.astype(jnp.float32) * count
    c0 = c0 + f * upd
    n0 = n0 + upd
    err = err + delta_k * upd
    n_full = n_full + count * considered
    nscan = nscan + jnp.sum(v_k, axis=1)
    ve = ve + jnp.sum(jnp.triu(upd, 1))

    # ---- bounds (Eqs. 9–10), tightened by the accumulated p̂ error ----
    c_min_f = c0 - err + (lf - n0) * ln1ms
    c_min = jnp.maximum(c_min_f, c_min_f.T)
    h_raw = jnp.maximum(
        nscan[:, None] * lf / jnp.maximum(d_src[:, None], 1.0),
        nscan[None, :] * lf / jnp.maximum(d_src[None, :], 1.0),
    )
    h = jnp.clip(h_raw, n0, lf)
    c_max_f = c0 + err + (h - n0) * ln1ms + (lf - h) * m_next
    c_max = jnp.maximum(c_max_f, c_max_f.T)

    checkable = active & boundable
    if use_timers:
        check_min = checkable & (n0 >= min_due)
        check_max = checkable & ((h - n0) >= max_due)
    else:
        check_min = checkable
        check_max = checkable
    bc = bc + jnp.sum(jnp.triu(check_min, 1)) + jnp.sum(jnp.triu(check_max, 1))

    cp = check_min & (c_min >= theta_cp)
    ind = check_max & (c_max < theta_ind) & (c_max.T < theta_ind) & ~cp

    if use_timers:
        denom = jnp.maximum(m_next - ln1ms, 1e-6)
        t_min = jnp.ceil((theta_cp - c_min) / denom)
        min_due = jnp.where(check_min & ~cp, n0 + t_min, min_due)
        t0_max = jnp.ceil((c_max - theta_ind) / denom)
        max_due = jnp.where(check_max & ~ind, (h - n0) + t0_max, max_due)

    newly = jnp.where(cp, 1, jnp.where(ind, -1, 0)).astype(jnp.int8)
    decided = jnp.where((decided == 0) & (newly != 0), newly, decided)
    dec_bucket = jnp.where((dec_bucket == K) & (newly != 0), k, dec_bucket)

    return (c0, n0, n_full, nscan, decided, dec_bucket,
            min_due, max_due, err, ve, bc)


def _bound_stream(idx: InvertedIndex, b: BucketedIndex, acc, l_counts, d_src,
                  considered, boundable, cfg: CopyConfig, use_timers: bool):
    """Drive the per-bucket step over buckets streamed from the store.

    Gathers one bucket's columns at a time (``store.slice_entries``) —
    peak incidence residency is a single bucket slice, not (K, S, w).
    """
    S = idx.n_sources
    K = b.n_buckets
    starts = b.starts
    w = int(max(np.diff(starts))) if K else 1
    dt = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32

    # δ_k per bucket — bounds the p̂ approximation of every accumulated score
    # term (scoring.bucket_score_deltas; p extremes live-masked by bucketize)
    p_lo = b.p_lo if b.p_lo is not None else b.p_hat
    p_hi = b.p_hi if b.p_hi is not None else b.p_hat
    deltas = bucket_score_deltas(b.p_hat, p_lo, p_hi, acc, cfg) if K else \
        np.zeros(0, np.float32)

    zero = jnp.zeros((S, S), jnp.float32)
    carry = (zero, zero, zero, jnp.zeros((S,), jnp.float32),
             jnp.zeros((S, S), jnp.int8), jnp.full((S, S), K, jnp.int32),
             zero, zero, zero,
             jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    accj = jnp.asarray(acc, jnp.float32)
    lj = jnp.asarray(l_counts)
    dj = jnp.asarray(d_src, jnp.float32)
    cj = jnp.asarray(considered)
    bj = jnp.asarray(boundable)
    for k in range(K):
        s0, s1 = int(starts[k]), int(starts[k + 1])
        v_np = np.zeros((S, w), np.float32)
        v_np[:, : s1 - s0] = idx.store.slice_entries(s0, s1, dtype=np.float32)
        carry = _bound_step(
            carry, jnp.asarray(v_np, dt), jnp.float32(b.p_hat[k]),
            jnp.float32(b.m_suffix[k + 1]), jnp.float32(deltas[k]),
            jnp.int32(k), accj, lj, dj, cj, bj,
            s=cfg.s, n=cfg.n, theta_cp=cfg.theta_cp, theta_ind=cfg.theta_ind,
            ln1ms=cfg.ln_1ms, use_timers=use_timers, K=K)
    return carry


def bound_detect(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    n_buckets: int = 64,
    use_timers: bool = False,          # False = BOUND, True = BOUND+
    l_threshold: int = 0,              # >0 = HYBRID (INDEX for small-overlap pairs)
    rescore_margin: float = 1.0,
    index: InvertedIndex | None = None,
    bucketed: BucketedIndex | None = None,
    return_state: bool = False,
):
    """BOUND (§IV-A), BOUND+ (§IV-B, use_timers), HYBRID (l_threshold=16)."""
    t0 = time.perf_counter()
    idx = index if index is not None else build_index(ds, p_claim, cfg)
    if bucketed is None:
        # a committed index is re-gathered into score-sorted prefix-Ē form
        # first, so the bucket geometry (and Eq. 10's scan-order-dependent h
        # estimate) matches a from-scratch rebuild exactly (DESIGN.md §7);
        # callers that pass their own ``bucketed`` keep the physical order
        idx = canonicalized(idx, cfg)
        bucketed = bucketize(idx, n_buckets)
    S = ds.n_sources
    K = bucketed.n_buckets
    l_counts = idx.l_counts
    d_src = idx.items_per_source

    # considered = co-occurrence outside Ē, accumulated chunk by chunk
    # (0/1 products in f32 are exact integers, bit-equal to one dense
    # matmul); the mask form covers committed indexes, where Ē is no longer
    # a physical suffix (DESIGN.md §7)
    n_out = idx.store.cooccurrence(mask=idx.nonebar_mask)
    considered = n_out > 0.5
    np.fill_diagonal(considered, False)

    boundable = idx.l_counts > l_threshold
    np.fill_diagonal(boundable, False)

    (c0, n0, n_full, _nscan, decided, dec_bucket, _md, _xd, err, ve, bc) = \
        _bound_stream(idx, bucketed, ds.accuracy, l_counts, d_src,
                      considered, boundable, cfg, use_timers)
    c0, n0 = np.array(c0), np.array(n0)
    n_full = np.array(n_full)
    decided = np.array(decided)
    dec_bucket = np.array(dec_bucket)
    err = np.array(err)

    lf = idx.l_counts.astype(np.float32)
    # Step IV for still-active pairs (n0 == n_full there): C→ = C^min
    c_fwd = np.where(considered, c0 + (lf - n0) * cfg.ln_1ms, 0.0).astype(np.float32)
    np.fill_diagonal(c_fwd, 0.0)

    # Ĉ for incremental bookkeeping (§V preparation step)
    c_hat = np.where(considered, c0 + (lf - n_full) * cfg.ln_1ms, 0.0).astype(np.float32)

    active = (decided == 0) & considered
    z = np.log(cfg.alpha / cfg.beta) + np.logaddexp(c_fwd, c_fwd.T)
    # a still-active pair's decision can only differ from the exact INDEX if
    # the accumulated p̂ error reaches its decision margin — widen the band
    # by it, exactly as the engine's §3.4 rescore does
    near = (active
            & (np.abs(z) < rescore_margin + np.maximum(err, err.T))
            & np.triu(np.ones((S, S), bool), 1))
    pi, pj = np.nonzero(near)
    if len(pi):
        c_fwd[pi, pj] = pair_scores_subset(ds, p_claim, cfg, pi, pj)
        c_fwd[pj, pi] = pair_scores_subset(ds, p_claim, cfg, pj, pi)

    step4 = np.array(decide_copying(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    copying = np.where(decided != 0, decided > 0, step4) & considered
    pr_ind = np.array(posterior_independence(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    pr_ind = np.where(considered, pr_ind, 1.0)
    pr_ind = np.where(decided > 0, np.minimum(pr_ind, 0.5), pr_ind)
    pr_ind = np.where(decided < 0, np.maximum(pr_ind, 0.5), pr_ind)
    np.fill_diagonal(pr_ind, 1.0)
    np.fill_diagonal(copying, False)

    iu = np.triu_indices(S, 1)
    n_pairs = int(considered[iu].sum())
    counter = ComputeCounter(
        pairs_considered=n_pairs,
        shared_values_examined=int(ve),
        score_computations=2 * int(ve) + 2 * n_pairs + 2 * len(pi),
        bound_computations=2 * int(bc),
        index_entries=idx.n_entries,
    )
    result = DetectionResult(c_fwd=c_fwd, pr_independent=pr_ind, copying=copying,
                             counter=counter, wall_time_s=time.perf_counter() - t0)
    if return_state:
        state = BoundState(c0=c0, n0=n0, n_full=n_full, decided=decided,
                           dec_bucket=dec_bucket, considered=considered,
                           c_hat=c_hat, err=err)
        return result, state
    return result


def hybrid_detect(ds, p_claim, cfg, n_buckets: int = 64, **kw):
    """HYBRID: INDEX semantics for pairs sharing ≤16 items, BOUND+ beyond."""
    return bound_detect(ds, p_claim, cfg, n_buckets=n_buckets,
                        use_timers=True, l_threshold=16, **kw)
