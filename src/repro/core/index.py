"""The specialized inverted index (§III, Definition 3.2).

One entry per *shared* value D.v (≥ 2 providers), carrying

  * P(E)  — probability the value is true,
  * C(E)  — contribution score M̂(D.v), the maximum possible pair
            contribution, computable from only the extreme-accuracy
            providers (Proposition 3.1),
  * S̄(E) — the provider set, stored as a column of the source×entry
            incidence matrix V.

Entries are sorted in decreasing C(E) (the BYCONTRIBUTION order of §VI-C);
the low-score suffix Ē (Σ C(E) < ln β/2α) can never flip a pair to copying
on its own, so pairs that co-occur only inside Ē are skipped.

Index construction is host-side NumPy (the paper: "index building has a much
lower complexity, O(|S||D|)", and costs ~.9% of PAIRWISE); all detection
compute on top of it is JAX.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.scoring import score_same_np
from repro.core.types import ClaimsDataset, CopyConfig


@dataclass
class InvertedIndex:
    """Entries sorted by decreasing contribution score."""

    V: np.ndarray              # (S, E) uint8 incidence, columns in score order
    entry_item: np.ndarray     # (E,) int32 — D_E
    entry_value: np.ndarray    # (E,) int32 — v_E (per-item value id)
    entry_p: np.ndarray        # (E,) float32 — P(E)
    entry_score: np.ndarray    # (E,) float32 — C(E) = M̂(D_E.v_E), non-increasing
    ebar_start: int            # entries [ebar_start:] form Ē
    l_counts: np.ndarray       # (S, S) int32 — shared-item counts l(S1,S2)
    items_per_source: np.ndarray  # (S,) int32 — |D̄(S)|

    @property
    def n_entries(self) -> int:
        """|E| — number of shared-value entries (columns of V)."""
        return self.V.shape[1]

    @property
    def n_sources(self) -> int:
        """|S| — number of sources (rows of V)."""
        return self.V.shape[0]

    def providers(self, e: int) -> np.ndarray:
        """S̄(E) — indices of the sources providing the value of entry ``e``."""
        return np.nonzero(self.V[:, e])[0]


def entry_contribution_score(
    p: float, provider_accs: np.ndarray, cfg: CopyConfig
) -> float:
    """Proposition 3.1 — M̂(D.v) from the extreme-accuracy providers.

    Case 1 (A_min ≤ 1/(1 + nP/(1−P))):       S1 = max-acc,   S2 = min-acc
    Case 2 (else, P < .5):                    S1 = 2nd-min,   S2 = min-acc
    Case 3 (else):                            S1 = min-acc,   S2 = 2nd-min
    """
    accs = np.sort(np.asarray(provider_accs, dtype=np.float64))
    a_min, a_second, a_max = accs[0], accs[min(1, len(accs) - 1)], accs[-1]
    p = float(p)
    threshold = 1.0 / (1.0 + cfg.n * p / max(1.0 - p, 1e-12))
    if a_min <= threshold:
        a1, a2 = a_max, a_min
    elif p < 0.5:
        a1, a2 = a_second, a_min
    else:
        a1, a2 = a_min, a_second
    return float(score_same_np(p, a1, a2, cfg.s, cfg.n))


def prop31_reference_accs(
    p: np.ndarray, a_min: np.ndarray, a_second: np.ndarray, a_max: np.ndarray,
    cfg: CopyConfig,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized Prop-3.1 case split → the (A_1, A_2) pair per entry."""
    threshold = 1.0 / (1.0 + cfg.n * p / np.maximum(1.0 - p, 1e-12))
    case1 = a_min <= threshold
    case2 = (~case1) & (p < 0.5)
    a1 = np.where(case1, a_max, np.where(case2, a_second, a_min))
    a2 = np.where(case1, a_min, np.where(case2, a_min, a_second))
    return a1, a2


def entry_extreme_accuracies(
    V: np.ndarray, acc: np.ndarray, chunk: int = 4096
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-entry (min, second-min, max) provider accuracies from the
    incidence matrix, chunked over entries to bound peak memory."""
    E = V.shape[1]
    a_min = np.empty(E, np.float64)
    a_second = np.empty(E, np.float64)
    a_max = np.empty(E, np.float64)
    for s0 in range(0, E, chunk):
        blk = V[:, s0: s0 + chunk].astype(bool).T          # (e, S)
        a = np.where(blk, acc[None, :], np.inf)
        m = a.min(axis=1)
        a[np.arange(len(a)), np.argmin(a, axis=1)] = np.inf
        a_min[s0: s0 + chunk] = m
        a_second[s0: s0 + chunk] = a.min(axis=1)
        a_max[s0: s0 + chunk] = np.where(blk, acc[None, :], -np.inf).max(axis=1)
    # single-provider entries (not produced by build_index) degrade gracefully
    a_second = np.where(np.isfinite(a_second), a_second, a_min)
    return a_min, a_second, a_max


def _entry_scores_vectorized(
    p: np.ndarray, a_min: np.ndarray, a_second: np.ndarray, a_max: np.ndarray,
    cfg: CopyConfig,
) -> np.ndarray:
    """Vectorized Prop 3.1 over all entries."""
    a1, a2 = prop31_reference_accs(p, a_min, a_second, a_max, cfg)
    return score_same_np(p.astype(np.float64), a1, a2, cfg.s, cfg.n).astype(np.float32)


def build_index(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    max_entries: Optional[int] = None,
) -> InvertedIndex:
    """Build the inverted index for a claims dataset.

    p_claim[s, d] is the truth probability of the value s provides on d
    (identical across providers of the same value).
    """
    values = ds.values
    S, D = values.shape
    prov = values >= 0

    # --- group claims by (item, value): vectorized via a composite key -----
    max_v = int(values.max()) + 1 if values.size and values.max() >= 0 else 1
    key = np.where(prov, np.arange(D, dtype=np.int64)[None, :] * max_v + values, -1)
    flat_key = key.ravel()
    claim_src = np.repeat(np.arange(S, dtype=np.int32), D)
    valid = flat_key >= 0
    flat_key, claim_src = flat_key[valid], claim_src[valid]
    flat_p = p_claim.ravel()[valid].astype(np.float32)

    order = np.argsort(flat_key, kind="stable")
    flat_key, claim_src, flat_p = flat_key[order], claim_src[order], flat_p[order]
    uniq_key, starts, counts = np.unique(flat_key, return_index=True, return_counts=True)

    shared = counts >= 2                       # Def. 3.2: ≥ 2 providers
    e_keys = uniq_key[shared]
    e_starts = starts[shared]
    e_counts = counts[shared]
    E = len(e_keys)

    entry_item = (e_keys // max_v).astype(np.int32)
    entry_value = (e_keys % max_v).astype(np.int32)
    entry_p = flat_p[e_starts]

    # incidence matrix: scatter every claim of a shared group into its entry
    # column (flat arrays are key-sorted, so groups are contiguous)
    group_id = np.repeat(np.arange(len(uniq_key)), counts)
    entry_of_group = np.cumsum(shared) - 1
    in_shared = shared[group_id]
    V = np.zeros((S, E), dtype=np.uint8)
    V[claim_src[in_shared], entry_of_group[group_id[in_shared]]] = 1

    # extreme provider accuracies per entry: sort claims by (key, accuracy)
    # once, then the group's first / second / last positions are the extremes
    acc = ds.accuracy.astype(np.float64)
    acc_claims = acc[claim_src]
    by_acc = np.lexsort((acc_claims, flat_key))
    acc_sorted = acc_claims[by_acc]
    a_min = acc_sorted[e_starts]
    a_second = acc_sorted[e_starts + 1]                  # counts ≥ 2 (Def 3.2)
    a_max = acc_sorted[e_starts + e_counts - 1]

    entry_score = _entry_scores_vectorized(entry_p, a_min, a_second, a_max, cfg)

    # sort entries by decreasing contribution score
    order = np.argsort(-entry_score, kind="stable")
    V = np.ascontiguousarray(V[:, order])
    entry_item = entry_item[order]
    entry_value = entry_value[order]
    entry_p = entry_p[order]
    entry_score = entry_score[order]

    # Ē — maximal low-score suffix with Σ C(E) < ln(β/2α)
    pos_scores = np.maximum(entry_score, 0.0)
    suffix_sum = np.cumsum(pos_scores[::-1])[::-1]
    below = suffix_sum < cfg.theta_ind
    ebar_start = int(np.argmax(below)) if below.any() else E

    prov64 = prov.astype(np.int64)
    l_counts = (prov64 @ prov64.T).astype(np.int32)

    return InvertedIndex(
        V=V,
        entry_item=entry_item,
        entry_value=entry_value,
        entry_p=entry_p,
        entry_score=entry_score,
        ebar_start=ebar_start,
        l_counts=l_counts,
        items_per_source=prov.sum(axis=1).astype(np.int32),
    )


@dataclass
class BucketedIndex:
    """Score-ordered index partitioned into K contiguous buckets.

    Bucket k covers entry columns [starts[k], starts[k+1]), all approximated
    with a single representative truth probability p̂_k (geometric mean).
    M̂_suffix[k] = max entry score at or after bucket k (the "next unscanned
    entry" bound M of Eq. 10, exact because entries are score-sorted).
    """

    index: InvertedIndex
    starts: np.ndarray        # (K+1,) int32
    p_hat: np.ndarray         # (K,) float32
    m_suffix: np.ndarray      # (K+1,) float32; m_suffix[K] = 0
    ebar_bucket: int          # first bucket that lies fully inside Ē

    @property
    def n_buckets(self) -> int:
        """K — number of contiguous entry buckets."""
        return len(self.p_hat)


def bucketize(index: InvertedIndex, n_buckets: int = 64) -> BucketedIndex:
    """Partition score-sorted entries into ~equal buckets on p-coherence.

    Buckets are contiguous in score order, so processing buckets in order is
    the paper's BYCONTRIBUTION scan at coarser granularity. Bucket boundaries
    are chosen on quantiles of ln p so that within-bucket p spread is small.
    """
    E = index.n_entries
    if E == 0:
        return BucketedIndex(index, np.zeros(1, np.int32), np.zeros(0, np.float32),
                             np.zeros(1, np.float32), 0)
    K = min(n_buckets, E)
    # contiguous equal-count split in score order
    bounds = np.linspace(0, E, K + 1).round().astype(np.int32)
    bounds = np.unique(bounds)
    K = len(bounds) - 1
    p_hat = np.empty(K, dtype=np.float32)
    logp = np.log(np.clip(index.entry_p, 1e-9, 1.0))
    for k in range(K):
        p_hat[k] = float(np.exp(logp[bounds[k]: bounds[k + 1]].mean()))
    # ensure Ē boundary is also a bucket boundary so the Ē-skip rule is exact
    if 0 < index.ebar_start < E and index.ebar_start not in bounds:
        bounds = np.sort(np.unique(np.append(bounds, index.ebar_start)))
        K = len(bounds) - 1
        p_hat = np.empty(K, dtype=np.float32)
        for k in range(K):
            p_hat[k] = float(np.exp(logp[bounds[k]: bounds[k + 1]].mean()))
    m_suffix = np.zeros(K + 1, dtype=np.float32)
    # true suffix max (exact for any entry ordering, incl. the RANDOM /
    # BYPROVIDER ablations of §VI-C)
    for k in range(K - 1, -1, -1):
        blk_max = float(index.entry_score[bounds[k]: bounds[k + 1]].max())
        m_suffix[k] = max(blk_max, m_suffix[k + 1])
    ebar_bucket = int(np.searchsorted(bounds, index.ebar_start))
    return BucketedIndex(index=index, starts=bounds, p_hat=p_hat,
                         m_suffix=m_suffix, ebar_bucket=ebar_bucket)


def bucketize_engine(
    index: InvertedIndex, n_buckets: int = 64
) -> tuple[BucketedIndex, np.ndarray, np.ndarray]:
    """p-homogeneous bucketization for the order-insensitive tiled INDEX.

    The engine's accumulation Σ_e f(A_i, A_j, p_e)·(V Vᵀ) does not depend on
    entry order — only the Ē boundary must stay exact (it defines the
    considered mask). So entries are re-sorted by truth probability within
    the non-Ē prefix and within Ē, and buckets become p-quantiles of each
    region: the within-bucket p spread — and with it the representative-p̂
    error the engine must cover with exact rescoring — collapses compared to
    the score-contiguous buckets BOUND needs.

    Returns (bucketed, p_lo, p_hi): a BucketedIndex over a reordered copy of
    the index plus per-bucket p extremes for the engine's rescore bound.
    """
    E = index.n_entries
    e0 = index.ebar_start
    if E == 0:
        b = bucketize(index, n_buckets)
        return b, np.zeros(0, np.float32), np.zeros(0, np.float32)

    order = np.concatenate([
        np.argsort(index.entry_p[:e0], kind="stable"),
        e0 + np.argsort(index.entry_p[e0:], kind="stable"),
    ])
    idx2 = InvertedIndex(
        V=np.ascontiguousarray(index.V[:, order]),
        entry_item=index.entry_item[order],
        entry_value=index.entry_value[order],
        entry_p=index.entry_p[order],
        entry_score=index.entry_score[order],
        ebar_start=e0,
        l_counts=index.l_counts,
        items_per_source=index.items_per_source,
    )
    # buckets proportional to region sizes, ≥1 per non-empty region, with a
    # boundary pinned at e0 so the Ē-skip rule stays exact
    k_out = min(max(int(round(n_buckets * e0 / E)), 1), e0) if e0 else 0
    k_in = min(max(n_buckets - k_out, 1), E - e0) if E > e0 else 0
    bounds = np.unique(np.concatenate([
        np.linspace(0, e0, k_out + 1).round(),
        np.linspace(e0, E, k_in + 1).round(),
    ])).astype(np.int32)
    K = len(bounds) - 1

    logp = np.log(np.clip(idx2.entry_p, 1e-9, 1.0))
    p_hat = np.empty(K, np.float32)
    p_lo = np.empty(K, np.float32)
    p_hi = np.empty(K, np.float32)
    for k in range(K):
        seg = slice(bounds[k], bounds[k + 1])
        p_hat[k] = float(np.exp(logp[seg].mean()))
        p_lo[k] = float(idx2.entry_p[seg].min())
        p_hi[k] = float(idx2.entry_p[seg].max())
    m_suffix = np.zeros(K + 1, np.float32)
    for k in range(K - 1, -1, -1):
        blk_max = float(idx2.entry_score[bounds[k]: bounds[k + 1]].max())
        m_suffix[k] = max(blk_max, m_suffix[k + 1])
    ebar_bucket = int(np.searchsorted(bounds, e0))
    return (BucketedIndex(index=idx2, starts=bounds, p_hat=p_hat,
                          m_suffix=m_suffix, ebar_bucket=ebar_bucket),
            p_lo, p_hi)
