"""The specialized inverted index (§III, Definition 3.2).

One entry per *shared* value D.v (≥ 2 providers), carrying

  * P(E)  — probability the value is true,
  * C(E)  — contribution score M̂(D.v), the maximum possible pair
            contribution, computable from only the extreme-accuracy
            providers (Proposition 3.1),
  * S̄(E) — the provider set, stored as a column of the source×entry
            incidence matrix V.

Entries are sorted in decreasing C(E) (the BYCONTRIBUTION order of §VI-C);
the low-score suffix Ē (Σ C(E) < ln β/2α) can never flip a pair to copying
on its own, so pairs that co-occur only inside Ē are skipped.

Index construction is host-side NumPy (the paper: "index building has a much
lower complexity, O(|S||D|)", and costs ~.9% of PAIRWISE); all detection
compute on top of it is JAX. The incidence never exists as one ``(S, E)``
array: ``build_index`` streams claims into a chunked ``CorpusStore``
(DESIGN.md §6), and every consumer iterates chunks.

Live mutation (DESIGN.md §7): ``commit_rows`` folds accepted query rows into
an existing index without rebuilding — membership bits for existing entries,
**delta chunks** for newly-shared values (score-ordered within the delta),
refreshed contribution scores for entries whose provider set grew, block
updates of ``l_counts``, and an Ē **mask** re-derived from the merged score
metadata without re-sorting the resident incidence. ``rollback_commit``
restores the pre-commit state bit-exact; ``compact_index`` folds deltas back
into one score-sorted base once they exceed a corpus fraction.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.scoring import score_same_np
from repro.core.store import (
    DEFAULT_CHUNK_ENTRIES,
    CorpusStore,
    StoreSnapshot,
    align_chunk,
)
from repro.core.types import CLAIM_KEY_BASE, ClaimsDataset, CopyConfig, claim_value_keys


@dataclass
class InvertedIndex:
    """Entries sorted by decreasing contribution score, backed by a
    chunked ``CorpusStore`` (the single source of corpus truth).

    After ``commit_rows`` the physical order is base entries followed by
    delta chunks — no longer globally score-sorted — and Ē becomes the
    explicit ``ebar_mask`` (``nonebar_mask`` is the consumer-facing API;
    when the mask is ``None`` it reduces to the classic prefix split at
    ``ebar_start``)."""

    store: CorpusStore         # entry-chunked incidence + entry metadata
    ebar_start: int            # entries [ebar_start:] form Ē (prefix form)
    l_counts: np.ndarray       # (S, S) int32 — shared-item counts l(S1,S2)
    items_per_source: np.ndarray  # (S,) int32 — |D̄(S)|
    ebar_mask: Optional[np.ndarray] = None  # (E,) bool Ē membership; set by
                                            # commit_rows (wins over ebar_start)

    @property
    def n_entries(self) -> int:
        """|E| — number of shared-value entries (columns of V)."""
        return self.store.n_entries

    @property
    def n_sources(self) -> int:
        """|S| — number of live sources (rows of V)."""
        return self.store.n_rows

    @property
    def entry_item(self) -> np.ndarray:
        """(E,) int32 — D_E per entry (view into the store)."""
        return self.store.entry_item

    @property
    def entry_value(self) -> np.ndarray:
        """(E,) int32 — v_E per entry (view into the store)."""
        return self.store.entry_value

    @property
    def entry_p(self) -> np.ndarray:
        """(E,) float32 — P(E) per entry (view into the store)."""
        return self.store.entry_p

    @property
    def entry_score(self) -> np.ndarray:
        """(E,) float32 — C(E) per entry, non-increasing (view)."""
        return self.store.entry_score

    @property
    def live_mask(self) -> np.ndarray:
        """(E,) bool — True for real entry columns (False for inert padding)."""
        return self.store.entry_item >= 0

    @property
    def nonebar_mask(self) -> np.ndarray:
        """(E,) bool — live entries OUTSIDE Ē (the consumer-facing Ē API).

        Every consumer of the Ē boundary (engine chunking, BOUND's
        considered test, the exact INDEX scan) goes through this mask, so
        the prefix form (fresh builds) and the mask form (after
        ``commit_rows``) are interchangeable.
        """
        live = self.live_mask
        if self.ebar_mask is not None:
            return live & ~self.ebar_mask
        pre = np.arange(self.store.n_entries) < self.ebar_start
        return live & pre

    @property
    def V(self) -> np.ndarray:
        """Dense (S, E) incidence — compat/debug accessor ONLY.

        Zero-copy for a single-chunk store; materializes otherwise.
        Production paths must stream ``store`` chunks instead.
        """
        return self.store.to_dense()

    def providers(self, e: int) -> np.ndarray:
        """S̄(E) — indices of the sources providing the value of entry ``e``."""
        return self.store.providers(e)

    @classmethod
    def from_dense(cls, V: np.ndarray, entry_item, entry_value, entry_p,
                   entry_score, ebar_start: int, l_counts, items_per_source,
                   chunk_entries: Optional[int] = None) -> "InvertedIndex":
        """Wrap a dense incidence (compat path for reorders/ablations)."""
        return cls(
            store=CorpusStore.from_dense(V, entry_item, entry_value, entry_p,
                                         entry_score,
                                         chunk_entries=chunk_entries),
            ebar_start=ebar_start, l_counts=l_counts,
            items_per_source=items_per_source)

    # -- (de)serialization (durability layer, DESIGN.md §8) ------------------

    def state_dict(self) -> dict:
        """Flat ``{key: ndarray}`` dict capturing this index bit-exactly.

        Wraps ``CorpusStore.state_dict`` (which carries the chunk-layout
        version) and adds the index-level derived state — Ē boundary/mask,
        pair counts, per-source item counts — so a restore needs no
        recomputation and reproduces the exact base+delta layout a sequence
        of ``commit_rows`` calls left behind (the replay-determinism
        precondition, DESIGN.md §8).
        """
        d = self.store.state_dict()
        d["index/meta"] = np.array(
            [self.ebar_start, 0 if self.ebar_mask is None else 1], np.int64)
        if self.ebar_mask is not None:
            d["index/ebar_mask"] = self.ebar_mask.astype(np.uint8)
        d["index/l_counts"] = self.l_counts
        d["index/items_per_source"] = self.items_per_source
        return d

    @classmethod
    def from_state_dict(cls, d: dict,
                        row_capacity: Optional[int] = None) -> "InvertedIndex":
        """Rebuild an index from ``state_dict`` output, bit-exact.

        ``row_capacity`` re-establishes the store's row slack (serving needs
        slack ≥ its pending-row budget to stage batches in place).
        """
        meta = np.asarray(d["index/meta"], np.int64)
        ebar_mask = None
        if int(meta[1]):
            ebar_mask = np.asarray(d["index/ebar_mask"], np.uint8).astype(bool)
        if "store/shard_starts" in d:
            # the corpus was row-range sharded when captured — re-establish
            # the same plan (shardplan.py; imported here to avoid a cycle)
            from repro.core.shardplan import ShardedCorpusStore
            store = ShardedCorpusStore.from_state_dict(
                d, capacity=row_capacity)
        else:
            store = CorpusStore.from_state_dict(d, capacity=row_capacity)
        return cls(
            store=store,
            ebar_start=int(meta[0]),
            l_counts=np.asarray(d["index/l_counts"], np.int32),
            items_per_source=np.asarray(d["index/items_per_source"], np.int32),
            ebar_mask=ebar_mask)


def entry_contribution_score(
    p: float, provider_accs: np.ndarray, cfg: CopyConfig
) -> float:
    """Proposition 3.1 — M̂(D.v) from the extreme-accuracy providers.

    Case 1 (A_min ≤ 1/(1 + nP/(1−P))):       S1 = max-acc,   S2 = min-acc
    Case 2 (else, P < .5):                    S1 = 2nd-min,   S2 = min-acc
    Case 3 (else):                            S1 = min-acc,   S2 = 2nd-min
    """
    accs = np.sort(np.asarray(provider_accs, dtype=np.float64))
    a_min, a_second, a_max = accs[0], accs[min(1, len(accs) - 1)], accs[-1]
    p = float(p)
    threshold = 1.0 / (1.0 + cfg.n * p / max(1.0 - p, 1e-12))
    if a_min <= threshold:
        a1, a2 = a_max, a_min
    elif p < 0.5:
        a1, a2 = a_second, a_min
    else:
        a1, a2 = a_min, a_second
    return float(score_same_np(p, a1, a2, cfg.s, cfg.n))


def prop31_reference_accs(
    p: np.ndarray, a_min: np.ndarray, a_second: np.ndarray, a_max: np.ndarray,
    cfg: CopyConfig,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized Prop-3.1 case split → the (A_1, A_2) pair per entry."""
    threshold = 1.0 / (1.0 + cfg.n * p / np.maximum(1.0 - p, 1e-12))
    case1 = a_min <= threshold
    case2 = (~case1) & (p < 0.5)
    a1 = np.where(case1, a_max, np.where(case2, a_second, a_min))
    a2 = np.where(case1, a_min, np.where(case2, a_min, a_second))
    return a1, a2


def entry_extreme_accuracies(
    V, acc: np.ndarray, chunk: int = 4096
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-entry (min, second-min, max) provider accuracies from the
    incidence, chunked over entries to bound peak memory. ``V`` may be a
    ``CorpusStore`` (iterated chunk by chunk) or a dense array."""
    if isinstance(V, CorpusStore) or hasattr(V, "iter_chunks"):
        # CorpusStore or the row-sharded facade (shardplan.py) — both
        # stream chunk handles; the dense branch below is arrays only
        E = V.n_entries
        a_min = np.empty(E, np.float64)
        a_second = np.empty(E, np.float64)
        a_max = np.empty(E, np.float64)
        for ch in V.iter_chunks():
            blk = ch.V.astype(bool).T                      # (w, S)
            a = np.where(blk, acc[None, :], np.inf)
            m = a.min(axis=1)
            a[np.arange(len(a)), np.argmin(a, axis=1)] = np.inf
            sl = slice(ch.start, ch.start + ch.width)
            a_min[sl] = m
            a_second[sl] = a.min(axis=1)
            a_max[sl] = np.where(blk, acc[None, :], -np.inf).max(axis=1)
        a_second = np.where(np.isfinite(a_second), a_second, a_min)
        return a_min, a_second, a_max
    E = V.shape[1]
    a_min = np.empty(E, np.float64)
    a_second = np.empty(E, np.float64)
    a_max = np.empty(E, np.float64)
    for s0 in range(0, E, chunk):
        blk = V[:, s0: s0 + chunk].astype(bool).T          # (e, S)
        a = np.where(blk, acc[None, :], np.inf)
        m = a.min(axis=1)
        a[np.arange(len(a)), np.argmin(a, axis=1)] = np.inf
        a_min[s0: s0 + chunk] = m
        a_second[s0: s0 + chunk] = a.min(axis=1)
        a_max[s0: s0 + chunk] = np.where(blk, acc[None, :], -np.inf).max(axis=1)
    # single-provider entries (not produced by build_index) degrade gracefully
    a_second = np.where(np.isfinite(a_second), a_second, a_min)
    return a_min, a_second, a_max


def _entry_scores_vectorized(
    p: np.ndarray, a_min: np.ndarray, a_second: np.ndarray, a_max: np.ndarray,
    cfg: CopyConfig,
) -> np.ndarray:
    """Vectorized Prop 3.1 over all entries."""
    a1, a2 = prop31_reference_accs(p, a_min, a_second, a_max, cfg)
    return score_same_np(p.astype(np.float64), a1, a2, cfg.s, cfg.n).astype(np.float32)


def build_index(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    max_entries: Optional[int] = None,
    chunk_entries: Optional[int] = None,
    chunk_bytes: Optional[int] = None,
    row_capacity: Optional[int] = None,
) -> InvertedIndex:
    """Build the inverted index for a claims dataset, streaming into chunks.

    p_claim[s, d] is the truth probability of the value s provides on d
    (identical across providers of the same value).

    The incidence is written one ``(S, chunk_entries)`` chunk at a time —
    the peak single incidence allocation is one chunk, never ``(S, E)``.
    ``chunk_bytes`` derives the chunk width from a byte budget for that
    peak allocation (it wins over ``chunk_entries``); ``row_capacity``
    preallocates slack rows for ``store.append_rows``.
    """
    values = ds.values
    S, D = values.shape
    prov = values >= 0

    cap = S if row_capacity is None else max(int(row_capacity), S)
    if chunk_bytes is not None:
        # the byte budget is a CEILING on one chunk allocation — round the
        # derived width DOWN to the 8-entry alignment (floored at 8: below
        # 8·rows bytes the budget is unsatisfiable and 8 is the minimum)
        chunk_entries = max(((chunk_bytes // max(cap, 1)) // 8) * 8, 8)
    if chunk_entries is None:
        chunk_entries = DEFAULT_CHUNK_ENTRIES
    chunk_entries = align_chunk(chunk_entries)

    # --- group claims by (item, value): vectorized via a composite key -----
    max_v = int(values.max()) + 1 if values.size and values.max() >= 0 else 1
    key = np.where(prov, np.arange(D, dtype=np.int64)[None, :] * max_v + values, -1)
    flat_key = key.ravel()
    claim_src = np.repeat(np.arange(S, dtype=np.int32), D)
    valid = flat_key >= 0
    flat_key, claim_src = flat_key[valid], claim_src[valid]
    flat_p = p_claim.ravel()[valid].astype(np.float32)

    order = np.argsort(flat_key, kind="stable")
    flat_key, claim_src, flat_p = flat_key[order], claim_src[order], flat_p[order]
    uniq_key, starts, counts = np.unique(flat_key, return_index=True, return_counts=True)

    shared = counts >= 2                       # Def. 3.2: ≥ 2 providers
    e_keys = uniq_key[shared]
    e_starts = starts[shared]
    e_counts = counts[shared]
    E = len(e_keys)

    entry_item = (e_keys // max_v).astype(np.int32)
    entry_value = (e_keys % max_v).astype(np.int32)
    entry_p = flat_p[e_starts]

    # extreme provider accuracies per entry: sort claims by (key, accuracy)
    # once, then the group's first / second / last positions are the extremes
    acc = ds.accuracy.astype(np.float64)
    acc_claims = acc[claim_src]
    by_acc = np.lexsort((acc_claims, flat_key))
    acc_sorted = acc_claims[by_acc]
    a_min = acc_sorted[e_starts]
    a_second = acc_sorted[e_starts + 1]                  # counts ≥ 2 (Def 3.2)
    a_max = acc_sorted[e_starts + e_counts - 1]

    entry_score = _entry_scores_vectorized(entry_p, a_min, a_second, a_max, cfg)

    # sort entries by decreasing contribution score (metadata only — the
    # incidence is scattered straight into its final, sorted column below)
    order = np.argsort(-entry_score, kind="stable")
    rank = np.empty(E, np.int64)
    rank[order] = np.arange(E)
    entry_item = entry_item[order]
    entry_value = entry_value[order]
    entry_p = entry_p[order]
    entry_score = entry_score[order]

    # stream the incidence into chunks: each claim of a shared group lands at
    # (source, rank-of-its-entry); groups are contiguous in the key-sorted
    # flat arrays, so the per-claim column is one gather
    group_id = np.repeat(np.arange(len(uniq_key)), counts)
    entry_of_group = np.cumsum(shared) - 1
    in_shared = shared[group_id]
    claim_col = rank[entry_of_group[group_id[in_shared]]]
    store = CorpusStore.from_claim_coords(
        claim_src[in_shared], claim_col, S, entry_item, entry_value,
        entry_p, entry_score, chunk_entries=chunk_entries, capacity=cap)

    # Ē — maximal low-score suffix with Σ C(E) < ln(β/2α)
    ebar_start = _ebar_boundary(entry_score, cfg.theta_ind)

    prov64 = prov.astype(np.int64)
    l_counts = (prov64 @ prov64.T).astype(np.int32)

    return InvertedIndex(
        store=store,
        ebar_start=ebar_start,
        l_counts=l_counts,
        items_per_source=prov.sum(axis=1).astype(np.int32),
    )


# ---------------------------------------------------------------------------
# Live corpus mutation: commit / rollback / compact (DESIGN.md §7)
# ---------------------------------------------------------------------------

@dataclass
class MutationDelta:
    """The (chunk, row-block) change set of one commit/retraction (§11).

    Attached to ``CommitInfo``/``RetractInfo`` so the engine's incremental
    block-OR cache (``core.tilecache.BlockOrCache``) can update exactly the
    cells whose membership changed instead of regathering every chunk:

      * a COMMIT appends rows ``[from_rows, to_rows)`` and sets bits only
        in those rows of ``touched`` existing entries (monotone — no bit is
        ever cleared), plus brand-new entry columns from
        ``new_entry_start`` on (those carry bits on OLD rows too — provider
        sets span the whole corpus);
      * a RETRACTION compacts rows ≥ ``row_start`` upward and zeroes the
        ``gc_entries`` columns, so only row-blocks ≥ ``row_start // tile``
        can change.

    ``from_mseq``/``to_mseq`` are the store's membership-state identities
    before/after (``store.mseq``); a cache applies the delta only when its
    own mseq equals ``from_mseq``. ``full=True`` (compaction ran) means the
    delta cannot describe the change — the cache must rebuild.
    """

    kind: str                      # "commit" | "retract"
    from_mseq: int                 # store.mseq before the mutation
    to_mseq: int                   # store.mseq after the mutation
    from_rows: int                 # live rows before
    to_rows: int                   # live rows after
    row_start: int                 # first row whose blocks can change
    touched: np.ndarray            # existing entry ids whose bits changed
    new_entry_start: int = -1      # first appended column (commit; -1 none)
    gc_entries: np.ndarray = None  # deactivated entry ids (retract)
    full: bool = False             # compaction ran — delta insufficient


@dataclass
class CommitInfo:
    """Receipt of one ``commit_rows`` call (stats + the rollback snapshot).

    ``touched_keys`` is the commit's invalidation currency: the sorted
    composite (item, value) keys of EVERY claim the committed rows carry.
    A pair of sources can only share an entry this commit touched if one of
    them claims a key in this set — the serving cache's exactness argument
    (DESIGN.md §7) rests on that superset property.
    """

    rows: int                      # query rows folded into the corpus
    bits_set: int                  # membership bits set on existing entries
    new_entries: int               # newly-shared values appended as deltas
    touched_entries: int           # existing entries whose providers grew
    delta_chunks_added: int        # chunks appended this commit
    compacted: bool                # deltas folded back into the base?
    epoch: int                     # store epoch after the commit
    touched_keys: np.ndarray       # sorted int64 claim keys of the new rows
    wall_s: float                  # host time spent committing
    delta: Optional[MutationDelta] = None   # changed-cell set (§11)
    _snap: StoreSnapshot = field(repr=False, default=None)
    _ebar_start: int = field(repr=False, default=0)
    _ebar_mask: Optional[np.ndarray] = field(repr=False, default=None)
    _l_counts: np.ndarray = field(repr=False, default=None)
    _items_per_source: np.ndarray = field(repr=False, default=None)


def _ebar_boundary(scores_desc: np.ndarray, theta_ind: float) -> int:
    """First index of the maximal low-score suffix with Σ max(C, 0) < θ_ind.

    ``scores_desc`` is a decreasing-score sequence; the ONE implementation
    of the Ē rule shared by ``build_index`` (fresh prefix), ``commit_rows``
    (mask over the merged order), and ``compact_index`` (restored prefix).
    """
    pos = np.maximum(np.asarray(scores_desc, np.float64), 0.0)
    if not len(pos):
        return 0
    suffix = np.cumsum(pos[::-1])[::-1]
    below = suffix < theta_ind
    return int(np.argmax(below)) if below.any() else len(pos)


def _derive_ebar_mask(store: CorpusStore, theta_ind: float) -> np.ndarray:
    """Ē membership over the MERGED score metadata, without moving incidence.

    Virtually sorts the live entries by decreasing contribution score
    (metadata argsort only — base and delta columns stay where they are) and
    marks the maximal low-score suffix with Σ max(C, 0) < θ_ind. Restricted
    to any score-sorted subsequence (the base region, each commit's delta)
    the marked set is still a suffix, which is the layout invariant
    DESIGN.md §7 argues the Ē-skip rule from. Padding columns are marked
    in-Ē (they carry no incidence, so no consumer ever counts them).
    """
    live = store.entry_item >= 0
    ids = np.nonzero(live)[0]
    scores = store.entry_score[ids].astype(np.float64)
    order = np.argsort(-scores, kind="stable")
    start = _ebar_boundary(scores[order], theta_ind)
    mask = np.ones(store.n_entries, bool)
    mask[ids[order[:start]]] = False
    return mask


def _extremes_of(acc: np.ndarray, provider_lists: list) -> tuple:
    """(min, second-min, max) provider accuracy per provider list."""
    n = len(provider_lists)
    a_min = np.empty(n, np.float64)
    a_second = np.empty(n, np.float64)
    a_max = np.empty(n, np.float64)
    for i, provs in enumerate(provider_lists):
        a = np.sort(acc[provs])
        a_min[i] = a[0]
        a_second[i] = a[min(1, len(a) - 1)]
        a_max[i] = a[-1]
    return a_min, a_second, a_max


def commit_rows(
    index: InvertedIndex,
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    n_new: int,
    *,
    compact: bool = True,
    compact_threshold: float = 0.25,
) -> CommitInfo:
    """Fold the last ``n_new`` rows of ``ds`` into the index, incrementally.

    ``ds``/``p_claim`` are the UNION claims (corpus rows first, the accepted
    query rows last); the index currently covers the first
    ``ds.n_sources − n_new`` rows. The commit:

      1. stages the rows' membership bits for every existing entry
         (``store.append_rows`` — O(q·E));
      2. detects the (item, value) groups the new rows turn into *shared*
         values (union provider count ≥ 2, not yet indexed) and appends them
         as **delta chunks**, score-ordered within the delta and chunk-
         aligned exactly like a fresh build;
      3. refreshes C(E) of existing entries whose provider set grew (M̂ is a
         max over provider pairs, so stale scores would under-bound BOUND's
         m_suffix);
      4. extends ``l_counts``/``items_per_source`` by block updates
         (O(S·q·D), never the O(S²·D) rebuild matmul);
      5. re-derives the Ē boundary from the merged score metadata as
         ``ebar_mask`` — the resident incidence is never re-sorted;
      6. optionally compacts: once live delta entries exceed
         ``compact_threshold`` of all live entries, deltas fold back into
         one score-sorted base (``compact_index``).

    Returns a ``CommitInfo`` receipt; ``rollback_commit(index, info)``
    restores the pre-commit state bit-exact (mid-batch failure recovery and
    the serving layer's per-batch transient unions both rely on it).
    """
    t0 = time.perf_counter()
    store = index.store
    S = ds.n_sources
    q = int(n_new)
    S0 = S - q
    if store.n_rows != S0:
        raise ValueError(
            f"commit_rows: index covers {store.n_rows} rows, union has "
            f"{S} with {q} new — expected {S0}")
    snap = store.snapshot()
    from_mseq = store.mseq
    info = CommitInfo(
        rows=q, bits_set=0, new_entries=0, touched_entries=0,
        delta_chunks_added=0, compacted=False, epoch=store.epoch,
        touched_keys=np.zeros(0, np.int64), wall_s=0.0,
        _snap=snap, _ebar_start=index.ebar_start, _ebar_mask=index.ebar_mask,
        _l_counts=index.l_counts, _items_per_source=index.items_per_source)

    new_vals = ds.values[S0:S]
    bits, touched = store.append_rows(new_vals, collect_touched=True)

    # -- 2. newly-shared (item, value) groups → delta entries ---------------
    live = store.entry_item >= 0
    existing = np.unique(
        store.entry_item[live].astype(np.int64) * CLAIM_KEY_BASE
        + store.entry_value[live])
    new_keys = claim_value_keys(new_vals)
    cand = new_keys[~np.isin(new_keys, existing)]
    # provider discovery is inherently one union-column scan per NOVEL key
    # (O(|cand|·S)); the serving path keeps |cand| at O(q · claims/row),
    # far under the O(S·D log) a rebuild pays — a commit whose rows are
    # mostly novel claims on a huge corpus should just rebuild instead
    e_item, e_value, e_p, e_provs = [], [], [], []
    for key in cand:
        d = int(key // CLAIM_KEY_BASE)
        v = int(key % CLAIM_KEY_BASE)
        provs = np.nonzero(ds.values[:, d] == v)[0]
        if len(provs) < 2:
            continue                      # still a singleton in the union
        e_item.append(d)
        e_value.append(v)
        e_p.append(float(p_claim[provs[0], d]))
        e_provs.append(provs)
    n_newe = len(e_item)
    # first appended column id: captured BEFORE append_entries so the pad
    # columns _pad_last_chunk_full adds count as "new" (zero incidence —
    # the cache assigns them all-zero block masks, which is exact)
    new_entry_start = store.n_entries if n_newe else -1
    if n_newe:
        acc = ds.accuracy.astype(np.float64)
        a_min, a_second, a_max = _extremes_of(acc, e_provs)
        p_arr = np.asarray(e_p, np.float64)
        scores = _entry_scores_vectorized(p_arr.astype(np.float32),
                                          a_min, a_second, a_max, cfg)
        order = np.argsort(-scores, kind="stable")
        cols = np.zeros((S, n_newe), np.int8)
        for j, src in enumerate(order):
            cols[e_provs[src], j] = 1
        info.delta_chunks_added = store.append_entries(
            cols,
            np.asarray(e_item, np.int32)[order],
            np.asarray(e_value, np.int32)[order],
            p_arr.astype(np.float32)[order],
            scores[order])
        info.new_entries = n_newe

    # -- 3. refresh scores of entries whose provider set grew ---------------
    if len(touched):
        if store.entry_score is snap.entry_score:
            # no deltas were appended, so the metadata array is still the
            # snapshot's — copy-on-write keeps the rollback point bit-exact
            store.entry_score = store.entry_score.copy()
            store.epoch += 1
        acc = ds.accuracy.astype(np.float64)
        provider_lists = [store.providers(e) for e in touched]
        a_min, a_second, a_max = _extremes_of(acc, provider_lists)
        store.entry_score[touched] = _entry_scores_vectorized(
            store.entry_p[touched], a_min, a_second, a_max, cfg)
        info.touched_entries = len(touched)

    # -- 4. block updates of the pair/source aggregates ---------------------
    if q:
        prov = ds.provided_mask
        prov_old = prov[:S0].astype(np.int64)
        prov_new = prov[S0:].astype(np.int64)
        l_new = np.zeros((S, S), np.int32)
        l_new[:S0, :S0] = index.l_counts
        cross = (prov_old @ prov_new.T).astype(np.int32)
        l_new[:S0, S0:] = cross
        l_new[S0:, :S0] = cross.T
        l_new[S0:, S0:] = (prov_new @ prov_new.T).astype(np.int32)
        index.l_counts = l_new
        index.items_per_source = np.concatenate(
            [index.items_per_source,
             prov[S0:].sum(axis=1).astype(np.int32)])

    # -- 5. Ē from merged score metadata ------------------------------------
    index.ebar_mask = _derive_ebar_mask(store, cfg.theta_ind)

    # -- 6. compaction ------------------------------------------------------
    if compact and store.delta_start is not None:
        n_live = store.n_live_entries
        if n_live and store.n_delta_entries > compact_threshold * n_live:
            compact_index(index, cfg)
            info.compacted = True

    info.bits_set = bits
    info.epoch = index.store.epoch
    info.touched_keys = new_keys
    info.delta = MutationDelta(
        kind="commit", from_mseq=from_mseq, to_mseq=index.store.mseq,
        from_rows=S0, to_rows=index.store.n_rows, row_start=S0,
        touched=touched, new_entry_start=new_entry_start,
        gc_entries=np.zeros(0, np.int64), full=info.compacted)
    info.wall_s = time.perf_counter() - t0
    return info


@dataclass
class RetractInfo:
    """Receipt of one ``retract_rows`` call (stats + the rollback snapshot).

    Shares the private rollback fields with ``CommitInfo`` so
    ``rollback_commit`` unwinds either receipt — every mutation path copies
    instead of writing captured arrays in place, which is what makes the
    ref-restoring snapshot valid for retraction too.
    """

    rows: int                      # sources removed from the corpus
    touched_entries: int           # entries the retracted rows provided
    gc_entries: int                # entries GC'd (fell below 2 providers)
    rescored_entries: int          # surviving touched entries re-scored
    epoch: int                     # store epoch after the retraction
    wall_s: float                  # host time spent retracting
    delta: Optional[MutationDelta] = None   # changed-cell set (§11)
    _snap: StoreSnapshot = field(repr=False, default=None)
    _ebar_start: int = field(repr=False, default=0)
    _ebar_mask: Optional[np.ndarray] = field(repr=False, default=None)
    _l_counts: np.ndarray = field(repr=False, default=None)
    _items_per_source: np.ndarray = field(repr=False, default=None)


def retract_rows(
    index: InvertedIndex,
    ds_after: ClaimsDataset,
    cfg: CopyConfig,
    row_ids: np.ndarray,
) -> RetractInfo:
    """Drop committed sources from the index — the inverse half of
    ``commit_rows`` (DESIGN.md §9).

    ``ds_after`` is the POST-retraction claims dataset (the surviving rows,
    compacted — ``ResidentCorpus.retract_rows`` produces it); ``row_ids``
    are the retracted rows' indices in the PRE-retraction corpus. The
    retraction:

      1. finds the entries the retracted rows were members of (their
         membership bits, one any-reduction per chunk);
      2. removes the rows from the incidence (``store.retract_rows`` —
         surviving rows compact upward, chunk arrays are replaced so the
         pre-retraction snapshot stays rollback-valid);
      3. GCs touched entries whose surviving provider count drops below 2 —
         no longer *shared* values (Def. 3.2) — into inert padding columns
         (``store.deactivate_entries``), exactly the set a rebuild over
         ``ds_after`` would not index;
      4. re-scores the surviving touched entries from the remaining
         providers' extreme accuracies (M̂ is a provider-pair max — a
         retracted extreme provider changes it);
      5. shrinks ``l_counts``/``items_per_source`` along the removed rows;
      6. re-derives the Ē boundary as ``ebar_mask`` over the surviving
         score metadata.

    Returns a ``RetractInfo``; ``rollback_commit(index, info)`` restores
    the pre-retraction state bit-exact (LIFO, router broadcast recovery).
    """
    t0 = time.perf_counter()
    store = index.store
    row_ids = np.unique(np.asarray(row_ids, np.int64))
    k = len(row_ids)
    S0 = store.n_rows
    if ds_after.n_sources != S0 - k:
        raise ValueError(
            f"retract_rows: index covers {S0} rows, {k} retracted — "
            f"ds_after must have {S0 - k} rows, got {ds_after.n_sources}")
    snap = store.snapshot()
    from_mseq = store.mseq
    info = RetractInfo(
        rows=k, touched_entries=0, gc_entries=0, rescored_entries=0,
        epoch=store.epoch, wall_s=0.0,
        _snap=snap, _ebar_start=index.ebar_start, _ebar_mask=index.ebar_mask,
        _l_counts=index.l_counts, _items_per_source=index.items_per_source)
    if k == 0:
        info.wall_s = time.perf_counter() - t0
        return info

    # -- 1. entries the retracted rows provided -----------------------------
    touched = []
    for ch in store.iter_chunks():
        hit = ch.V[row_ids].any(axis=0)
        if hit.any():
            touched.append(ch.start + np.nonzero(hit)[0])
    touched = (np.concatenate(touched) if touched
               else np.zeros(0, np.int64))
    info.touched_entries = len(touched)

    # -- 2. remove the rows -------------------------------------------------
    store.retract_rows(row_ids)

    # -- 3. GC entries that stopped being shared ----------------------------
    gc_ids = np.zeros(0, np.int64)
    if len(touched):
        counts = np.array([int(store.column(e).sum()) for e in touched])
        gc_ids = touched[counts < 2]
        survivors = touched[counts >= 2]
        store.deactivate_entries(gc_ids)
        info.gc_entries = len(gc_ids)

        # -- 4. re-score survivors from the remaining providers -------------
        if len(survivors):
            if store.entry_score is snap.entry_score:
                # copy-on-write keeps the rollback point bit-exact
                store.entry_score = store.entry_score.copy()
                store.epoch += 1
            acc = ds_after.accuracy.astype(np.float64)
            provider_lists = [store.providers(e) for e in survivors]
            a_min, a_second, a_max = _extremes_of(acc, provider_lists)
            store.entry_score[survivors] = _entry_scores_vectorized(
                store.entry_p[survivors], a_min, a_second, a_max, cfg)
            info.rescored_entries = len(survivors)

    # -- 5. shrink the pair/source aggregates -------------------------------
    index.l_counts = np.delete(
        np.delete(index.l_counts, row_ids, axis=0), row_ids, axis=1)
    index.items_per_source = np.delete(index.items_per_source, row_ids)

    # -- 6. Ē from the surviving score metadata -----------------------------
    index.ebar_mask = _derive_ebar_mask(store, cfg.theta_ind)

    info.epoch = store.epoch
    info.delta = MutationDelta(
        kind="retract", from_mseq=from_mseq, to_mseq=store.mseq,
        from_rows=S0, to_rows=store.n_rows, row_start=int(row_ids[0]),
        touched=touched, new_entry_start=-1, gc_entries=gc_ids, full=False)
    info.wall_s = time.perf_counter() - t0
    return info


def rollback_commit(index: InvertedIndex, info) -> None:
    """Restore the index to its pre-commit state, bit-exact.

    Valid for the LAST mutation applied (commits/retractions must unwind
    LIFO); accepts a ``CommitInfo`` or a ``RetractInfo`` — both capture the
    same rollback fields. Works across compaction too: the snapshot holds
    the pre-mutation store object, which the mutation path never writes in
    place (appended rows are zeroed back, replaced arrays are restored by
    reference).
    """
    info._snap.restore()
    index.store = info._snap.store
    index.ebar_start = info._ebar_start
    index.ebar_mask = info._ebar_mask
    index.l_counts = info._l_counts
    index.items_per_source = info._items_per_source


def compact_index(index: InvertedIndex, cfg: CopyConfig) -> None:
    """Fold delta chunks back into one score-sorted base (DESIGN.md §7).

    Gathers the live entries in decreasing-score order into a fresh
    uniform-chunk store (one chunk resident at a time), drops the inert
    padding columns, and restores the classic prefix Ē (``ebar_mask`` back
    to ``None``). O(S·E) copy — amortized by the ``compact_threshold``
    fraction in ``commit_rows``.
    """
    store = index.store
    live_ids = np.nonzero(store.entry_item >= 0)[0]
    order = live_ids[np.argsort(-store.entry_score[live_ids], kind="stable")]
    new_store = store.gather_entries(order, chunk_entries=store.chunk_entries,
                                     capacity=store.capacity)
    new_store.epoch = store.epoch + 1
    index.ebar_start = _ebar_boundary(new_store.entry_score, cfg.theta_ind)
    index.ebar_mask = None
    index.store = new_store


def _segment_p_stats(entry_p: np.ndarray, live: np.ndarray,
                     bounds: np.ndarray) -> tuple:
    """Per-segment (p̂, p_lo, p_hi) over the LIVE columns of each
    ``[bounds[k], bounds[k+1])`` range — geometric-mean representative and
    true extremes, 0.5 fallbacks for all-padding segments. The one
    implementation behind both ``bucketize`` and ``engine_chunks``, so the
    p̂ feeding BOUND's and the engine's shared δ error channel can never
    drift apart.
    """
    logp = np.log(np.clip(entry_p, 1e-9, 1.0))
    K = len(bounds) - 1
    p_hat = np.empty(K, np.float32)
    p_lo = np.empty(K, np.float32)
    p_hi = np.empty(K, np.float32)
    for k in range(K):
        seg = slice(int(bounds[k]), int(bounds[k + 1]))
        m = live[seg]
        lp = logp[seg] if m.all() else logp[seg][m]
        ps = entry_p[seg] if m.all() else entry_p[seg][m]
        p_hat[k] = float(np.exp(lp.mean())) if len(lp) else 0.5
        p_lo[k] = float(ps.min()) if len(ps) else 0.5
        p_hi[k] = float(ps.max()) if len(ps) else 0.5
    return p_hat, p_lo, p_hi


def canonicalized(index: InvertedIndex, cfg: CopyConfig) -> InvertedIndex:
    """A score-sorted, prefix-Ē VIEW of a committed index (gathered copy).

    Returns ``index`` unchanged when it is already canonical. Otherwise
    gathers the live entries in decreasing-score order into a fresh store —
    a detection-time copy exactly like ``engine_chunks``' per-call gather,
    NOT a mutation of the committed index. BOUND's scan uses this so its
    bucket geometry (and with it the Eq. 10 ``h`` overlap estimate, which is
    scan-order-dependent by design) is identical whether the index was
    committed into or rebuilt from scratch (DESIGN.md §7).
    """
    if index.ebar_mask is None:
        return index
    view = InvertedIndex(store=index.store, ebar_start=index.ebar_start,
                         l_counts=index.l_counts,
                         items_per_source=index.items_per_source,
                         ebar_mask=index.ebar_mask)
    compact_index(view, cfg)          # mutates only the shallow view
    return view


@dataclass
class BucketedIndex:
    """Score-ordered index partitioned into K contiguous buckets.

    Bucket k covers entry columns [starts[k], starts[k+1]), all approximated
    with a single representative truth probability p̂_k (geometric mean).
    M̂_suffix[k] = max entry score at or after bucket k (the "next unscanned
    entry" bound M of Eq. 10, exact because entries are score-sorted).
    """

    index: InvertedIndex
    starts: np.ndarray        # (K+1,) int32
    p_hat: np.ndarray         # (K,) float32
    m_suffix: np.ndarray      # (K+1,) float32; m_suffix[K] = 0
    ebar_bucket: int          # first bucket that lies fully inside Ē
    p_lo: Optional[np.ndarray] = None  # (K,) min live p per bucket (for the
    p_hi: Optional[np.ndarray] = None  # (K,) max — δ_k error bound, §2.2)

    @property
    def n_buckets(self) -> int:
        """K — number of contiguous entry buckets."""
        return len(self.p_hat)


def bucketize(index: InvertedIndex, n_buckets: int = 64) -> BucketedIndex:
    """Partition score-sorted entries into ~equal buckets on p-coherence.

    Buckets are contiguous in score order, so processing buckets in order is
    the paper's BYCONTRIBUTION scan at coarser granularity. Bucket boundaries
    are chosen on quantiles of ln p so that within-bucket p spread is small.

    A committed index (delta chunks, ``ebar_mask``) buckets the PHYSICAL
    order instead: ``m_suffix`` is the true suffix max (exact for any
    ordering), p̂ averages only live columns, and the Ē-boundary pin is
    skipped — Ē-dependent consumers read ``index.nonebar_mask`` directly.
    """
    E = index.n_entries
    if E == 0:
        return BucketedIndex(index, np.zeros(1, np.int32), np.zeros(0, np.float32),
                             np.zeros(1, np.float32), 0)
    K = min(n_buckets, E)
    live = index.live_mask

    # contiguous equal-count split in score order
    bounds = np.linspace(0, E, K + 1).round().astype(np.int32)
    bounds = np.unique(bounds)
    p_hat, p_lo, p_hi = _segment_p_stats(index.entry_p, live, bounds)
    # ensure Ē boundary is also a bucket boundary so the Ē-skip rule is exact
    # (prefix-Ē indexes only; committed indexes carry the mask instead)
    if (index.ebar_mask is None and 0 < index.ebar_start < E
            and index.ebar_start not in bounds):
        bounds = np.sort(np.unique(np.append(bounds, index.ebar_start)))
        p_hat, p_lo, p_hi = _segment_p_stats(index.entry_p, live, bounds)
    K = len(bounds) - 1
    m_suffix = np.zeros(K + 1, dtype=np.float32)
    # true suffix max (exact for any entry ordering, incl. the RANDOM /
    # BYPROVIDER ablations of §VI-C and the post-commit base+delta layout)
    for k in range(K - 1, -1, -1):
        blk_max = float(index.entry_score[bounds[k]: bounds[k + 1]].max())
        m_suffix[k] = max(blk_max, m_suffix[k + 1])
    if index.ebar_mask is None:
        ebar_bucket = int(np.searchsorted(bounds, index.ebar_start))
    else:
        # first bucket from which EVERY later bucket is fully inside Ē
        nonebar = index.nonebar_mask
        full = [not nonebar[bounds[k]: bounds[k + 1]].any() for k in range(K)]
        ebar_bucket = K
        for k in range(K - 1, -1, -1):
            if not full[k]:
                break
            ebar_bucket = k
    return BucketedIndex(index=index, starts=bounds, p_hat=p_hat,
                         m_suffix=m_suffix, ebar_bucket=ebar_bucket,
                         p_lo=p_lo, p_hi=p_hi)


def bucketize_engine(
    index: InvertedIndex, n_buckets: int = 64
) -> tuple[BucketedIndex, np.ndarray, np.ndarray]:
    """p-homogeneous bucketization (legacy full-reorder form).

    Kept for the kernel microbenchmark's legacy baseline; the production
    engine uses ``engine_chunks`` (below), which produces the same p-sorted
    regions as a uniform-width chunk store without variable-width buckets.

    Returns (bucketed, p_lo, p_hi): a BucketedIndex over a reordered copy of
    the index plus per-bucket p extremes for the engine's rescore bound.
    """
    E = index.n_entries
    e0 = index.ebar_start
    if E == 0:
        b = bucketize(index, n_buckets)
        return b, np.zeros(0, np.float32), np.zeros(0, np.float32)

    order = np.concatenate([
        np.argsort(index.entry_p[:e0], kind="stable"),
        e0 + np.argsort(index.entry_p[e0:], kind="stable"),
    ])
    idx2 = InvertedIndex(
        store=index.store.gather_entries(order),
        ebar_start=e0,
        l_counts=index.l_counts,
        items_per_source=index.items_per_source,
    )
    # buckets proportional to region sizes, ≥1 per non-empty region, with a
    # boundary pinned at e0 so the Ē-skip rule stays exact
    k_out = min(max(int(round(n_buckets * e0 / E)), 1), e0) if e0 else 0
    k_in = min(max(n_buckets - k_out, 1), E - e0) if E > e0 else 0
    bounds = np.unique(np.concatenate([
        np.linspace(0, e0, k_out + 1).round(),
        np.linspace(e0, E, k_in + 1).round(),
    ])).astype(np.int32)
    K = len(bounds) - 1

    logp = np.log(np.clip(idx2.entry_p, 1e-9, 1.0))
    p_hat = np.empty(K, np.float32)
    p_lo = np.empty(K, np.float32)
    p_hi = np.empty(K, np.float32)
    for k in range(K):
        seg = slice(bounds[k], bounds[k + 1])
        p_hat[k] = float(np.exp(logp[seg].mean()))
        p_lo[k] = float(idx2.entry_p[seg].min())
        p_hi[k] = float(idx2.entry_p[seg].max())
    m_suffix = np.zeros(K + 1, np.float32)
    for k in range(K - 1, -1, -1):
        blk_max = float(idx2.entry_score[bounds[k]: bounds[k + 1]].max())
        m_suffix[k] = max(blk_max, m_suffix[k + 1])
    ebar_bucket = int(np.searchsorted(bounds, e0))
    return (BucketedIndex(index=idx2, starts=bounds, p_hat=p_hat,
                          m_suffix=m_suffix, ebar_bucket=ebar_bucket),
            p_lo, p_hi)


@dataclass
class EngineChunks:
    """The engine's chunk-handle view of an index (DESIGN.md §6).

    Entries are re-sorted by truth probability within the non-Ē prefix and
    within Ē (the tiled accumulation is order-insensitive; only the Ē
    boundary must stay exact), each region is zero-padded to a chunk
    multiple, and the result is a uniform-width ``CorpusStore`` whose chunks
    double as the kernel's entry blocks: each chunk k carries one
    representative p̂_k, its true p extremes (for the rescore bound δ_k),
    and a non-Ē flag. Row capacity is padded to the engine's tile grid so
    chunk arrays slice straight into pair tiles.
    """

    store: CorpusStore        # p-ordered regions, uniform chunk width
    p_hat: np.ndarray         # (K,) float32 — representative p̂ per chunk
    p_lo: np.ndarray          # (K,) float32 — min live p per chunk
    p_hi: np.ndarray          # (K,) float32 — max live p per chunk
    nout: np.ndarray          # (K,) float32 — 1.0 ⇔ chunk before Ē boundary
    ebar_chunk: int           # chunks [ebar_chunk:] lie fully inside Ē
    n_live: int               # E — real (non-padding) entries
    order: np.ndarray = None  # gathered column j = base column order[j] (−1 pad)

    @property
    def n_chunks(self) -> int:
        """K — number of uniform-width entry chunks."""
        return self.store.n_chunks

    @property
    def width(self) -> int:
        """Chunk width (= the kernel entry-block size block_e)."""
        return self.store.chunk_entries


def engine_chunks(
    index: InvertedIndex,
    n_buckets: int = 64,
    row_capacity: Optional[int] = None,
    max_width: Optional[int] = None,
) -> EngineChunks:
    """Build the engine's uniform-width chunk store from an index.

    The chunk width is ``ceil(E / n_buckets)`` aligned up to the kernel tile
    edge (8), so ``n_buckets`` keeps its meaning as the p̂ granularity; the
    Ē boundary is chunk-aligned by construction (each region is padded with
    inert zero columns), which keeps the fused kernel's per-chunk non-Ē
    mask channel exact. ``max_width`` caps the chunk width from above (the
    engine derives it from its per-pass byte budget) — narrower chunks just
    mean more of them, with one p̂ each, so the cap never costs accuracy.

    The regions come from ``index.nonebar_mask``, so a committed index
    (base + delta chunks, Ē as a mask — DESIGN.md §7) chunks exactly like a
    fresh one: the gather pulls each region's live columns wherever they
    physically sit, and the delta layout dissolves into the p-sorted order.
    """
    nonebar = index.nonebar_mask
    live = index.live_mask
    non = np.nonzero(nonebar)[0]
    ebar = np.nonzero(live & ~nonebar)[0]
    n_live = len(non) + len(ebar)
    cap = index.n_sources if row_capacity is None else int(row_capacity)
    if n_live == 0:
        empty = index.store.gather_entries(np.zeros(0, np.int64), capacity=cap)
        z = np.zeros(0, np.float32)
        return EngineChunks(store=empty, p_hat=z, p_lo=z, p_hi=z, nout=z,
                            ebar_chunk=0, n_live=0,
                            order=np.zeros(0, np.int64))

    b = align_chunk(-(-n_live // max(int(n_buckets), 1)))
    if max_width is not None:
        b = min(b, max(8, (int(max_width) // 8) * 8))
    order_pre = non[np.argsort(index.entry_p[non], kind="stable")]
    order_suf = ebar[np.argsort(index.entry_p[ebar], kind="stable")]
    pad0 = (-len(non)) % b
    pad1 = (-len(ebar)) % b
    order = np.concatenate([
        order_pre, np.full(pad0, -1, np.int64),
        order_suf, np.full(pad1, -1, np.int64),
    ])
    store = index.store.gather_entries(order, chunk_entries=b,
                                       capacity=cap)
    K = store.n_chunks
    ebar_chunk = (len(non) + pad0) // b

    p_hat, p_lo, p_hi = _segment_p_stats(
        store.entry_p, store.entry_item >= 0, np.arange(K + 1) * b)
    nout = (np.arange(K) < ebar_chunk).astype(np.float32)
    return EngineChunks(store=store, p_hat=p_hat, p_lo=p_lo, p_hi=p_hi,
                        nout=nout, ebar_chunk=ebar_chunk, n_live=n_live,
                        order=order)
