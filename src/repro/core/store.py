"""CorpusStore — the entry-chunked incidence store (DESIGN.md §6).

The inverted index's source×entry incidence matrix V is the one object that
grows as S·E; at the ROADMAP's million-source target a dense ``(S, E)``
array is a hard wall long before detection compute is. ``CorpusStore``
replaces it as the single source of corpus truth across every layer:

  * the incidence lives as **entry-chunked blocks** — dense int8 arrays of
    ``(capacity, chunk_entries)``, the chunk width a multiple of the kernel
    tile edge (8, the f32 sublane) so chunks feed the Pallas copyscore
    kernels without relayout;
  * per-chunk **entry metadata** (item, value id, truth probability,
    contribution score) rides along as zero-copy views of the store's
    entry arrays;
  * rows are allocated with **slack capacity** so a serving layer can write
    query rows in place (``append_rows`` / ``truncate_rows``) instead of
    concatenating a new corpus per batch.

``build_index`` streams claims into chunks without ever allocating the
``(S, E)`` incidence whole; the engine gathers one chunk (group) at a time;
``bound``/``incremental`` iterate chunks. The only dense materialization
left is the explicit ``to_dense()`` compat accessor (tests, tiny data).

No chunk is ever wider than ``chunk_entries`` columns, so the largest
single incidence allocation anywhere in the pipeline is bounded by
``capacity · chunk_entries`` bytes — ``build_index(chunk_bytes=...)``
derives the width from that budget (the CI memory smoke asserts it).

Mutation (DESIGN.md §7): the store is append-commit-compact. ``append_rows``
/ ``truncate_rows`` stage query rows in the slack; ``append_entries`` grows
the entry axis with **delta chunks** (the last resident chunk is padded to
full width with inert columns first, so the uniform ``chunk_start``
addressing survives); ``index.commit_rows`` orchestrates both plus the
metadata/Ē updates, and folds deltas back into a score-sorted base via
compaction. ``epoch`` counts structural mutations; per-chunk metadata views
are memoized per ``(epoch, n_rows)`` so hot loops stop rebuilding them.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np

#: Default entry-chunk width (columns). A multiple of the kernel entry block
#: (512 = default block_e) and of the pair-tile edge alignment (8).
DEFAULT_CHUNK_ENTRIES = 512

#: Chunk-layout version for serialized stores (``state_dict``). Bump when
#: the on-disk key set or the chunk addressing scheme changes; loaders
#: reject state dicts from a newer version (DESIGN.md §8, OPERATIONS.md).
STORE_LAYOUT_VERSION = 1


def align_chunk(width: int) -> int:
    """Round a requested chunk width up to the kernel tile-edge multiple (8)."""
    return max(8, -(-int(width) // 8) * 8)


#: Global monotonic mutation-sequence source. Every store mutation — and,
#: crucially, every snapshot RESTORE — draws a fresh value, so
#: ``(store identity, mseq)`` names one membership state forever: no
#: rollback can ever reproduce a previously seen mseq with different bits.
#: (A per-store counter could: restore would rewind it, and two different
#: transient commit→rollback unions would collide on the same key.)
_MSEQ = itertools.count(1)


def next_mseq() -> int:
    """Draw the next globally unique mutation-sequence number."""
    return next(_MSEQ)


@dataclass
class ChunkView:
    """One chunk handle: live incidence rows + its entry-metadata views."""

    start: int                 # global index of this chunk's first entry
    V: np.ndarray              # (n_rows, width) int8 incidence (a view)
    item: np.ndarray           # (width,) int32 — D_E (−1 for padding columns)
    value: np.ndarray          # (width,) int32 — v_E (−1 for padding columns)
    p: np.ndarray              # (width,) float32 — P(E)
    score: np.ndarray          # (width,) float32 — C(E)

    @property
    def width(self) -> int:
        """Number of entry columns in this chunk."""
        return self.V.shape[1]


@dataclass
class CorpusStore:
    """Entry-chunked incidence + metadata; rows have slack capacity.

    Invariants: every chunk except the last is exactly ``chunk_entries``
    wide (a multiple of 8); chunk row dimension is ``capacity`` with rows
    ``[n_rows:]`` zero (slack for ``append_rows``). Columns may be inert
    padding (``entry_item == -1``, all-zero incidence) — they contribute
    nothing to any co-occurrence count, so every consumer can ignore them.
    """

    chunks: list = field(default_factory=list)   # list[np.ndarray] (capacity, w)
    entry_item: np.ndarray = None                # (E,) int32
    entry_value: np.ndarray = None               # (E,) int32
    entry_p: np.ndarray = None                   # (E,) float32
    entry_score: np.ndarray = None               # (E,) float32
    chunk_entries: int = DEFAULT_CHUNK_ENTRIES
    n_rows: int = 0
    capacity: int = 0
    delta_start: Optional[int] = None            # first delta entry; None = no deltas
    epoch: int = 0                               # bumped on structural mutation

    def __post_init__(self):
        if self.entry_item is None:
            self.entry_item = np.zeros(0, np.int32)
        if self.entry_value is None:
            self.entry_value = np.zeros(0, np.int32)
        if self.entry_p is None:
            self.entry_p = np.zeros(0, np.float32)
        if self.entry_score is None:
            self.entry_score = np.zeros(0, np.float32)
        if self.capacity < self.n_rows:
            self.capacity = self.n_rows
        # per-(epoch, n_rows) memo of ChunkView handles (satellite: the
        # engine's per-group hot loop must not rebuild metadata views)
        self._views: dict = {}
        self._views_key = None
        # membership-state identity for the engine's block-OR cache; NOT a
        # dataclass field and NOT serialized — identity is per process
        self.mseq = next_mseq()

    # -- geometry -----------------------------------------------------------

    @property
    def n_entries(self) -> int:
        """E — total entry columns across chunks (padding included)."""
        return len(self.entry_item)

    @property
    def n_chunks(self) -> int:
        """Number of entry chunks."""
        return len(self.chunks)

    @property
    def max_chunk_nbytes(self) -> int:
        """Largest single incidence allocation held by this store."""
        return max((c.nbytes for c in self.chunks if c is not None),
                   default=0)

    def release_chunk(self, c: int) -> None:
        """Free chunk ``c``'s incidence block, irreversibly.

        The streaming shard build (``shardplan.shard_store(consume=True)``)
        calls this after all shards sliced their rows of chunk ``c``, so a
        from-scratch sharded build never holds more than one source chunk
        alongside the capped shard residents. The store is consumed: any
        later read of a released chunk fails loud instead of returning
        stale or zero incidence.
        """
        self.chunks[int(c)] = None
        self._views = {}
        self._views_key = None

    @property
    def n_live_entries(self) -> int:
        """Entries that are real (non-padding) columns."""
        return int(np.count_nonzero(self.entry_item >= 0))

    @property
    def n_delta_entries(self) -> int:
        """Live entries in the delta region (appended since the last base)."""
        if self.delta_start is None:
            return 0
        return int(np.count_nonzero(self.entry_item[self.delta_start:] >= 0))

    @property
    def n_delta_chunks(self) -> int:
        """Chunks that hold at least one delta entry."""
        if self.delta_start is None:
            return 0
        return self.n_chunks - self.delta_start // self.chunk_entries

    def chunk_start(self, c: int) -> int:
        """Global index of chunk ``c``'s first entry column."""
        return c * self.chunk_entries

    def chunk(self, c: int) -> ChunkView:
        """Chunk ``c`` as a handle: live rows + metadata views (zero copy).

        Handles are memoized per ``(epoch, n_rows)`` — within one epoch the
        same ``ChunkView`` object is returned on every access, so per-group
        hot loops (engine streaming, INCREMENTAL's masked counts) never
        rebuild the metadata slices. Structural mutations (``append_entries``,
        ``ensure_row_capacity``, compaction) bump ``epoch``; row staging
        changes ``n_rows`` — either invalidates the memo.
        """
        key = (self.epoch, self.n_rows)
        if self._views_key != key:
            self._views = {}
            self._views_key = key
        view = self._views.get(c)
        if view is None:
            if self.chunks[c] is None:
                raise RuntimeError(
                    f"chunk {c} was released (release_chunk) — this store "
                    f"was consumed by a streaming shard build")
            s0 = self.chunk_start(c)
            s1 = s0 + self.chunks[c].shape[1]
            view = ChunkView(
                start=s0,
                V=self.chunks[c][: self.n_rows],
                item=self.entry_item[s0:s1],
                value=self.entry_value[s0:s1],
                p=self.entry_p[s0:s1],
                score=self.entry_score[s0:s1],
            )
            self._views[c] = view
        return view

    def iter_chunks(self) -> Iterator[ChunkView]:
        """Iterate chunk handles in entry order."""
        for c in range(self.n_chunks):
            yield self.chunk(c)

    # -- column access ------------------------------------------------------

    def column(self, e: int) -> np.ndarray:
        """Incidence column of entry ``e`` over live rows (a view)."""
        c, off = divmod(int(e), self.chunk_entries)
        return self.chunks[c][: self.n_rows, off]

    def providers(self, e: int) -> np.ndarray:
        """S̄(E) — indices of the sources providing entry ``e``'s value."""
        return np.nonzero(self.column(e))[0]

    def slice_entries(self, e0: int, e1: int,
                      dtype=np.int8, rows: Optional[int] = None) -> np.ndarray:
        """Dense ``(rows, e1 − e0)`` gather of an entry range across chunks.

        Intended for *narrow* ranges (one bucket / one kernel block) — the
        result is a fresh allocation of exactly the requested width, so the
        caller controls peak memory. ``rows`` defaults to the live rows.
        """
        e0, e1 = int(e0), int(e1)
        n = self.n_rows if rows is None else int(rows)
        out = np.zeros((n, e1 - e0), dtype=dtype)
        w = self.chunk_entries
        c0 = e0 // w if w else 0
        for c in range(c0, self.n_chunks):
            s0 = self.chunk_start(c)
            if s0 >= e1:
                break
            s1 = s0 + self.chunks[c].shape[1]
            lo, hi = max(e0, s0), min(e1, s1)
            if lo < hi:
                out[: min(n, self.n_rows), lo - e0: hi - e0] = \
                    self.chunks[c][: min(n, self.n_rows), lo - s0: hi - s0]
        return out

    def to_dense(self) -> np.ndarray:
        """The full ``(n_rows, E)`` incidence — compat/debug accessor ONLY.

        This is the one densifying path; production code must stream chunks
        instead (the engine, bound, and incremental all do). With a single
        chunk this is a zero-copy view.
        """
        if self.n_chunks == 1:
            return self.chunks[0][: self.n_rows]
        if self.n_chunks == 0:
            return np.zeros((self.n_rows, 0), np.int8)
        return np.concatenate(
            [c[: self.n_rows] for c in self.chunks], axis=1)

    def cooccurrence(self, stop: Optional[int] = None,
                     dtype=np.float32,
                     mask: Optional[np.ndarray] = None) -> np.ndarray:
        """Pair co-occurrence counts Σ_e V[i,e]·V[j,e] over selected entries.

        ``stop`` keeps the prefix ``[:stop]``; ``mask`` (an (E,) bool array)
        keeps an arbitrary entry subset instead — the form the Ē test needs
        once delta chunks make Ē a mask rather than a suffix (DESIGN.md §7).
        Accumulated chunk by chunk — peak incidence residency is one chunk.
        0/1 products in float32 are exact integers (< 2²⁴), so the result is
        bit-equal to the dense matmul for any chunking.
        """
        S = self.n_rows
        out = np.zeros((S, S), dtype)
        if mask is not None:
            for ch in self.iter_chunks():
                m = mask[ch.start: ch.start + ch.width]
                if m.all():
                    v = ch.V.astype(dtype)
                elif m.any():
                    v = ch.V[:, m].astype(dtype)
                else:
                    continue
                out += v @ v.T
            return out
        stop = self.n_entries if stop is None else int(stop)
        for ch in self.iter_chunks():
            if ch.start >= stop:
                break
            w = min(ch.width, stop - ch.start)
            v = ch.V[:, :w].astype(dtype)
            out += v @ v.T
        return out

    # -- derived stores -----------------------------------------------------

    def gather_entries(self, order: np.ndarray,
                       chunk_entries: Optional[int] = None,
                       capacity: Optional[int] = None) -> "CorpusStore":
        """A new store whose column ``j`` is this store's column ``order[j]``.

        ``order`` may contain ``-1`` markers for inert zero-padding columns
        (the engine uses them to align region boundaries to chunk edges).
        Built chunk by chunk — never materializes either incidence whole.
        """
        order = np.asarray(order, np.int64)
        E_out = len(order)
        w = self.chunk_entries if chunk_entries is None else align_chunk(chunk_entries)
        cap = self.capacity if capacity is None else max(int(capacity), self.n_rows)
        live = order >= 0
        safe = np.where(live, order, 0)

        item = np.full(E_out, -1, np.int32)
        value = np.full(E_out, -1, np.int32)
        p = np.zeros(E_out, np.float32)
        score = np.zeros(E_out, np.float32)
        item[live] = self.entry_item[safe[live]]
        value[live] = self.entry_value[safe[live]]
        p[live] = self.entry_p[safe[live]]
        score[live] = self.entry_score[safe[live]]

        chunks = []
        src_w = max(self.chunk_entries, 1)
        for j0 in range(0, E_out, max(w, 1)):
            width = min(w, E_out - j0)
            blk = np.zeros((cap, width), np.int8)
            sel = order[j0: j0 + width]
            lv = sel >= 0
            if lv.any():
                src_cols = sel[lv]
                dst_cols = np.nonzero(lv)[0]
                # group source columns by their chunk to keep slicing local
                cids = src_cols // src_w
                for cid in np.unique(cids):
                    m = cids == cid
                    blk[: self.n_rows, dst_cols[m]] = \
                        self.chunks[cid][: self.n_rows, src_cols[m] - cid * src_w]
            chunks.append(blk)
        return CorpusStore(chunks=chunks, entry_item=item, entry_value=value,
                           entry_p=p, entry_score=score, chunk_entries=w,
                           n_rows=self.n_rows, capacity=cap)

    # -- row mutation (serving / corpus-mutation follow-on) ------------------

    def append_rows(self, values_rows: np.ndarray,
                    collect_touched: bool = False):
        """Write incidence rows for new sources into the slack capacity.

        ``values_rows`` is ``(q, D)`` int32 in the corpus's value coding. For
        every *existing* entry (D_E, v_E) the new rows' membership bit is set
        where their claim matches — one vectorized ``(q, width)`` comparison
        per chunk, so the cost is O(q·E), independent of the corpus rows.
        Values the new rows share only with each other (or that turn a
        singleton into a shared value) are NOT in the entry set — they get
        their entry columns from ``index.commit_rows``'s delta re-index
        (DESIGN.md §7), which also needs the set of entries whose provider
        set grew: pass ``collect_touched=True`` to get
        ``(bits, touched_entry_ids)`` instead of the bare bit count.
        """
        values_rows = np.asarray(values_rows, np.int32)
        q = values_rows.shape[0]
        if self.n_rows + q > self.capacity:
            raise ValueError(
                f"append_rows: {q} rows exceed capacity "
                f"({self.n_rows}/{self.capacity} used)")
        bits = 0
        touched = []
        for c in range(self.n_chunks):
            s0 = self.chunk_start(c)
            s1 = s0 + self.chunks[c].shape[1]
            it = self.entry_item[s0:s1]
            va = self.entry_value[s0:s1]
            ok = it >= 0
            hit = np.zeros((q, s1 - s0), np.int8)
            if ok.any() and q:
                hit[:, ok] = (
                    values_rows[:, it[ok]] == va[ok][None, :]
                ).astype(np.int8)
            self.chunks[c][self.n_rows: self.n_rows + q] = hit
            bits += int(hit.sum())
            if collect_touched:
                touched.append(s0 + np.nonzero(hit.any(axis=0))[0])
        self.n_rows += q
        self.mseq = next_mseq()
        if collect_touched:
            return bits, (np.concatenate(touched) if touched
                          else np.zeros(0, np.int64))
        return bits

    def truncate_rows(self, n_rows: int) -> None:
        """Drop appended rows back down to ``n_rows`` (zeroing their slack)."""
        n_rows = int(n_rows)
        if n_rows > self.n_rows:
            raise ValueError(f"truncate_rows({n_rows}) above n_rows={self.n_rows}")
        for c in self.chunks:
            c[n_rows: self.n_rows] = 0
        self.n_rows = n_rows
        self.mseq = next_mseq()

    def retract_rows(self, row_ids: np.ndarray) -> None:
        """Physically remove ARBITRARY live rows (source retraction, §7).

        Unlike ``truncate_rows`` (trailing slack only), this deletes
        committed rows anywhere in the live range: every chunk is replaced
        by a fresh array holding the surviving rows compacted upward, so the
        row axis stays dense and ``n_rows`` drops by ``len(row_ids)``.
        Capacity is preserved. The OLD chunk arrays are never written — a
        pre-retraction ``snapshot()``'s refs stay bit-exact for rollback.
        Bumps ``epoch``. Membership/GC bookkeeping (entries that drop below
        two providers) is the caller's job (``index.retract_rows``).
        """
        row_ids = np.unique(np.asarray(row_ids, np.int64))
        if len(row_ids) == 0:
            return
        if row_ids[0] < 0 or row_ids[-1] >= self.n_rows:
            raise ValueError(
                f"retract_rows: ids out of range [0, {self.n_rows})")
        keep = np.ones(self.n_rows, bool)
        keep[row_ids] = False
        n_keep = int(keep.sum())
        for c in range(self.n_chunks):
            blk = np.zeros((self.capacity, self.chunks[c].shape[1]), np.int8)
            blk[:n_keep] = self.chunks[c][: self.n_rows][keep]
            self.chunks[c] = blk
        self.n_rows = n_keep
        self.epoch += 1
        self.mseq = next_mseq()

    def deactivate_entries(self, entry_ids: np.ndarray) -> None:
        """Turn entry columns into inert padding (retraction GC, §7).

        A retracted source can leave an entry with < 2 providers — no longer
        a *shared* value (Def. 3.2), so it must leave the index exactly as a
        rebuild would drop it. The column's incidence is zeroed and its
        metadata set to the padding convention (item/value −1, p/score 0);
        every consumer already skips padding columns. Copy-on-write on both
        the affected chunks and the metadata arrays, so a snapshot taken
        before stays valid. Bumps ``epoch``.
        """
        entry_ids = np.asarray(entry_ids, np.int64)
        if len(entry_ids) == 0:
            return
        w = self.chunk_entries
        for cid in np.unique(entry_ids // w):
            cols = entry_ids[entry_ids // w == cid] - cid * w
            blk = self.chunks[cid].copy()
            blk[:, cols] = 0
            self.chunks[int(cid)] = blk
        item = self.entry_item.copy()
        value = self.entry_value.copy()
        p = self.entry_p.copy()
        score = self.entry_score.copy()
        item[entry_ids] = -1
        value[entry_ids] = -1
        p[entry_ids] = 0.0
        score[entry_ids] = 0.0
        self.entry_item, self.entry_value = item, value
        self.entry_p, self.entry_score = p, score
        self.epoch += 1
        self.mseq = next_mseq()

    # -- entry mutation (delta chunks, DESIGN.md §7) -------------------------

    def _pad_last_chunk_full(self) -> None:
        """Pad the trailing chunk to the uniform width with inert columns.

        Keeps the ``chunk_start(c) = c·chunk_entries`` addressing valid when
        delta chunks are appended after a partial base chunk. The replaced
        chunk array is NOT mutated (a padded copy takes its place), so a
        pre-commit snapshot's chunk refs stay bit-exact for rollback.
        """
        if not self.chunks:
            return
        last = self.chunks[-1]
        w = last.shape[1]
        if w == self.chunk_entries:
            return
        pad = self.chunk_entries - w
        blk = np.zeros((last.shape[0], self.chunk_entries), np.int8)
        blk[:, :w] = last
        self.chunks[-1] = blk
        self.entry_item = np.concatenate(
            [self.entry_item, np.full(pad, -1, np.int32)])
        self.entry_value = np.concatenate(
            [self.entry_value, np.full(pad, -1, np.int32)])
        self.entry_p = np.concatenate(
            [self.entry_p, np.zeros(pad, np.float32)])
        self.entry_score = np.concatenate(
            [self.entry_score, np.zeros(pad, np.float32)])

    def append_entries(self, cols: np.ndarray, item, value, p, score) -> int:
        """Append new entry columns as delta chunks (DESIGN.md §7).

        ``cols`` is ``(n_rows, n_new)`` int8 incidence over the live rows;
        the caller orders columns by decreasing contribution score (the
        within-delta BYCONTRIBUTION order). The last resident chunk is first
        padded to the uniform width with inert columns, then the new columns
        land in fresh ``(capacity, chunk_entries)`` blocks — the resident
        incidence is never re-sorted or re-copied. Returns the number of
        delta chunks added. Bumps ``epoch``.
        """
        cols = np.asarray(cols, np.int8)
        n_new = cols.shape[1]
        if n_new == 0:
            return 0
        if cols.shape[0] != self.n_rows:
            raise ValueError(
                f"append_entries: {cols.shape[0]} rows, store has {self.n_rows}")
        self._pad_last_chunk_full()
        if self.delta_start is None:
            self.delta_start = self.n_entries
        w = self.chunk_entries
        added = 0
        for j0 in range(0, n_new, w):
            width = min(w, n_new - j0)
            blk = np.zeros((self.capacity, width), np.int8)
            blk[: self.n_rows] = cols[:, j0: j0 + width]
            self.chunks.append(blk)
            added += 1
        self.entry_item = np.concatenate(
            [self.entry_item, np.asarray(item, np.int32)])
        self.entry_value = np.concatenate(
            [self.entry_value, np.asarray(value, np.int32)])
        self.entry_p = np.concatenate(
            [self.entry_p, np.asarray(p, np.float32)])
        self.entry_score = np.concatenate(
            [self.entry_score, np.asarray(score, np.float32)])
        self.epoch += 1
        self.mseq = next_mseq()
        return added

    def ensure_row_capacity(self, n: int) -> None:
        """Grow every chunk's row capacity to at least ``n`` (geometric).

        Reallocates each chunk once (copying only the live rows); a no-op
        when the capacity already suffices. Bumps ``epoch`` (views alias the
        old arrays).
        """
        if n <= self.capacity:
            return
        new_cap = max(int(n), 2 * self.capacity)
        for c in range(self.n_chunks):
            blk = np.zeros((new_cap, self.chunks[c].shape[1]), np.int8)
            blk[: self.n_rows] = self.chunks[c][: self.n_rows]
            self.chunks[c] = blk
        self.capacity = new_cap
        self.epoch += 1
        # deliberately NOT an mseq bump: capacity growth is membership-
        # preserving (rows ≥ n_rows read zero before and after), and the
        # serving layer grows capacity between a detect and its commit —
        # bumping here would break every commit's delta chain

    def snapshot(self) -> "StoreSnapshot":
        """Capture a rollback point (array REFS, not copies — O(chunks)).

        Valid because mutations never write existing entry columns in place:
        ``append_entries`` replaces the padded chunk and the metadata arrays
        with extended copies, and row staging only writes rows ≥ ``n_rows``
        (which ``StoreSnapshot.restore`` zeroes back).
        """
        return StoreSnapshot(
            store=self, chunks=list(self.chunks), entry_item=self.entry_item,
            entry_value=self.entry_value, entry_p=self.entry_p,
            entry_score=self.entry_score, n_rows=self.n_rows,
            capacity=self.capacity, delta_start=self.delta_start,
            epoch=self.epoch)

    # -- (de)serialization (durability layer, DESIGN.md §8) ------------------

    def state_dict(self, prefix: str = "store/") -> dict:
        """Flat ``{key: ndarray}`` dict capturing this store bit-exactly.

        Keys are ``prefix``-namespaced so the dict can nest inside a larger
        snapshot payload (``InvertedIndex.state_dict`` does). Chunks are
        stored trimmed to the live rows — slack capacity is a runtime
        concern the loader re-chooses — and the layout version rides along
        so future chunk-scheme changes stay detectable. Row-slack state
        (staged-but-uncommitted rows) is deliberately NOT captured: the
        durability contract persists committed state only.
        """
        d = {
            prefix + "meta": np.array(
                [STORE_LAYOUT_VERSION, self.chunk_entries, self.n_rows,
                 -1 if self.delta_start is None else self.delta_start,
                 self.epoch, self.n_chunks], np.int64),
            prefix + "entry_item": self.entry_item,
            prefix + "entry_value": self.entry_value,
            prefix + "entry_p": self.entry_p,
            prefix + "entry_score": self.entry_score,
        }
        for c, blk in enumerate(self.chunks):
            d[f"{prefix}chunk_{c:05d}"] = blk[: self.n_rows]
        return d

    @classmethod
    def from_state_dict(cls, d: dict, prefix: str = "store/",
                        capacity: Optional[int] = None) -> "CorpusStore":
        """Rebuild a store from ``state_dict`` output, bit-exact.

        ``capacity`` re-establishes row slack (≥ the stored ``n_rows``;
        defaults to no slack). Raises ``ValueError`` on a layout version
        newer than this reader.
        """
        meta = np.asarray(d[prefix + "meta"], np.int64)
        version, chunk_entries, n_rows, delta_start, epoch, n_chunks = (
            int(x) for x in meta[:6])
        if version > STORE_LAYOUT_VERSION:
            raise ValueError(
                f"store layout version {version} is newer than this reader "
                f"({STORE_LAYOUT_VERSION})")
        cap = n_rows if capacity is None else max(int(capacity), n_rows)
        chunks = []
        for c in range(n_chunks):
            src = np.asarray(d[f"{prefix}chunk_{c:05d}"], np.int8)
            blk = np.zeros((cap, src.shape[1]), np.int8)
            blk[:n_rows] = src
            chunks.append(blk)
        return cls(
            chunks=chunks,
            entry_item=np.asarray(d[prefix + "entry_item"], np.int32),
            entry_value=np.asarray(d[prefix + "entry_value"], np.int32),
            entry_p=np.asarray(d[prefix + "entry_p"], np.float32),
            entry_score=np.asarray(d[prefix + "entry_score"], np.float32),
            chunk_entries=chunk_entries, n_rows=n_rows, capacity=cap,
            delta_start=None if delta_start < 0 else delta_start,
            epoch=epoch)

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_dense(cls, V: np.ndarray, entry_item, entry_value, entry_p,
                   entry_score, chunk_entries: Optional[int] = None,
                   capacity: Optional[int] = None) -> "CorpusStore":
        """Wrap a dense ``(S, E)`` incidence (compat path; tests, reorders).

        Default keeps one chunk spanning all entries, so no re-chunking copy
        happens and ``to_dense()`` stays a view.
        """
        S, E = V.shape
        cap = S if capacity is None else int(capacity)
        w = max(E, 1) if chunk_entries is None else align_chunk(chunk_entries)
        chunks = []
        for j0 in range(0, E, w):
            blk = np.zeros((cap, min(w, E - j0)), np.int8)
            blk[:S] = V[:, j0: j0 + blk.shape[1]]
            chunks.append(blk)
        return cls(chunks=chunks,
                   entry_item=np.asarray(entry_item, np.int32),
                   entry_value=np.asarray(entry_value, np.int32),
                   entry_p=np.asarray(entry_p, np.float32),
                   entry_score=np.asarray(entry_score, np.float32),
                   chunk_entries=w, n_rows=S, capacity=cap)

    @classmethod
    def from_claim_coords(cls, src: np.ndarray, col: np.ndarray,
                          n_rows: int, entry_item, entry_value, entry_p,
                          entry_score, chunk_entries: int,
                          capacity: Optional[int] = None) -> "CorpusStore":
        """Stream claim coordinates into chunks (the ``build_index`` path).

        ``src[k]`` / ``col[k]`` place claim k at incidence position
        (source, entry column). Claims are bucketed by chunk with one sort,
        then each chunk is allocated and scattered independently — the peak
        incidence allocation is ONE chunk (``capacity · chunk_entries``
        int8 bytes), never the ``(S, E)`` whole.
        """
        w = align_chunk(chunk_entries)
        E = len(entry_item)
        cap = n_rows if capacity is None else int(capacity)
        order = np.argsort(col, kind="stable")
        src, col = src[order], col[order]
        n_chunks = -(-E // w) if E else 0
        bounds = np.searchsorted(col, np.arange(0, n_chunks + 1) * w)
        chunks = []
        for c in range(n_chunks):
            width = min(w, E - c * w)
            blk = np.zeros((cap, width), np.int8)
            lo, hi = bounds[c], bounds[c + 1]
            blk[src[lo:hi], col[lo:hi] - c * w] = 1
            chunks.append(blk)
        return cls(chunks=chunks,
                   entry_item=np.asarray(entry_item, np.int32),
                   entry_value=np.asarray(entry_value, np.int32),
                   entry_p=np.asarray(entry_p, np.float32),
                   entry_score=np.asarray(entry_score, np.float32),
                   chunk_entries=w, n_rows=n_rows, capacity=cap)


# ---------------------------------------------------------------------------
# Bitpacked membership (sharded data plane, DESIGN.md §10)
# ---------------------------------------------------------------------------

#: Byte → set-bit-count lookup table for ``packed_count_matmul``.
_POPCOUNT = np.array([bin(i).count("1") for i in range(256)], np.int64)


@dataclass(frozen=True)
class PackedBlock:
    """One bitpacked incidence block: int8 membership at 1 bit per entry.

    ``bits[r, :]`` is row ``r``'s membership packed MSB-first along the
    column axis (``np.packbits`` layout); ``width`` records the original
    column count because the packed byte axis rounds up to a multiple of 8
    — trailing pad bits are always zero, so AND/popcount arithmetic over
    whole bytes never sees phantom members. Packed blocks are immutable
    (frozen): mutation paths unpack, edit, repack.
    """

    bits: np.ndarray           # (rows, ceil(width/8)) uint8
    width: int                 # original (unpacked) column count

    @property
    def nbytes(self) -> int:
        """Resident bytes — the packed payload (1 bit per entry)."""
        return int(self.bits.nbytes)

    @property
    def shape(self) -> tuple:
        """Logical (rows, width) of the unpacked block."""
        return (int(self.bits.shape[0]), int(self.width))


def pack_membership(block: np.ndarray) -> PackedBlock:
    """Pack a 0/1 int8 membership block to 1 bit per entry (8× vs int8).

    Any width is accepted — widths that are not a multiple of 8 pad the
    final byte with zero bits (``unpack_membership`` trims them back via
    ``count=width``), so the ``align_chunk`` 8-column invariant is a kernel
    concern, not a packing requirement.
    """
    block = np.ascontiguousarray(block)
    if block.ndim != 2:
        raise ValueError(f"pack_membership: need a 2-D block, got {block.shape}")
    return PackedBlock(bits=np.packbits(block != 0, axis=1),
                       width=int(block.shape[1]))


def unpack_membership(packed: PackedBlock, dtype=np.int8) -> np.ndarray:
    """Inverse of ``pack_membership`` — bit-exact for 0/1 input blocks."""
    return np.unpackbits(packed.bits, axis=1,
                         count=packed.width).astype(dtype)


def packed_count_matmul(a: PackedBlock, b: Optional[PackedBlock] = None,
                        dtype=np.float32, row_block: int = 256) -> np.ndarray:
    """``counts[i, j] = Σ_e a[i, e] · b[j, e]`` straight off the packed bits.

    Byte-wise AND + popcount — every partial sum is an exact small integer,
    so the result is bit-equal to the int8 matmul in ``dtype`` (float32
    holds integers < 2²⁴ exactly, same argument as ``cooccurrence``).
    ``b=None`` means ``a @ a.T``. ``row_block`` bounds the (rows_a ·
    rows_b · bytes) AND temporary.
    """
    other = a if b is None else b
    if b is not None and a.width != b.width:
        raise ValueError(
            f"packed_count_matmul: width mismatch {a.width} vs {b.width}")
    n, m = a.bits.shape[0], other.bits.shape[0]
    out = np.zeros((n, m), dtype)
    for i0 in range(0, n, max(int(row_block), 1)):
        blk = a.bits[i0: i0 + row_block]
        anded = blk[:, None, :] & other.bits[None, :, :]
        out[i0: i0 + row_block] = _POPCOUNT[anded].sum(axis=2).astype(dtype)
    return out


@dataclass
class StoreSnapshot:
    """Rollback point for one ``CorpusStore`` (refs captured by ``snapshot``)."""

    store: "CorpusStore"
    chunks: list
    entry_item: np.ndarray
    entry_value: np.ndarray
    entry_p: np.ndarray
    entry_score: np.ndarray
    n_rows: int
    capacity: int
    delta_start: Optional[int]
    epoch: int

    def restore(self) -> None:
        """Put the captured store back to its snapshot state, bit-exact.

        Restores the array refs — including ``capacity``, which must track
        the restored chunk arrays: an ``ensure_row_capacity`` between
        snapshot and restore swapped in larger chunks, so keeping the grown
        capacity against the restored (smaller) arrays would let a later
        ``append_rows`` pass the capacity check and write out of bounds —
        then zeroes the row slack of every chunk (staged rows were written
        in place).
        """
        st = self.store
        st.chunks = list(self.chunks)
        st.capacity = self.capacity
        st.entry_item = self.entry_item
        st.entry_value = self.entry_value
        st.entry_p = self.entry_p
        st.entry_score = self.entry_score
        st.delta_start = self.delta_start
        st.epoch = self.epoch
        st.n_rows = self.n_rows
        # FRESH mseq, deliberately not the captured one: a restored state
        # must never alias a previously observed (store, mseq) pair, or a
        # stale block-OR cache could validate against different bits
        st.mseq = next_mseq()
        st._views = {}
        st._views_key = None
        for c in st.chunks:
            c[self.n_rows:] = 0


__all__ = ["CorpusStore", "ChunkView", "PackedBlock", "StoreSnapshot",
           "DEFAULT_CHUNK_ENTRIES", "STORE_LAYOUT_VERSION", "align_chunk",
           "next_mseq", "pack_membership", "packed_count_matmul",
           "unpack_membership"]
