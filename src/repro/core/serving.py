"""Batched copy-detection serving (DESIGN.md §5).

A detection service answers *queries against a shared corpus*: each request
carries a handful of query sources — dataset deltas (new or re-crawled
sources) or per-item queries (sparse rows claiming only the items the caller
cares about) — and asks which corpus sources they copy from. Running the
`DetectionEngine` once per request wastes the engine's fixed costs (index
build, bucketize, tile pruning, kernel dispatch) on a tile grid that is
~identical across requests.

``serve_batch`` instead stacks every pending request's rows under the corpus
and runs ONE tiled engine pass over the union, then scatters each request's
row-slice of the decision matrix back into its own response. This is sound
because a pair's exact-INDEX decision is intrinsic to the two sources'
claims (DESIGN.md §5): co-batched strangers can create new index entries,
but those entries only ever contribute to pairs that actually share the
value, so batched decisions equal the per-request ones — asserted by
tests/test_serving.py and re-checked by the `serve` benchmark in CI.
Cross-request pairs are computed (they ride along in the same tiles for
free) but never reported: each response sees only its rows vs the corpus
plus its own intra-request block.

The invariant is about *decisions*: ``copying``/``intra_copying`` are
batch-independent. The continuous fields (``c_fwd``, ``pr_independent``)
are the engine's bucketed approximation, and the bucket p̂-quantiles shift
with the union index — away from the decision boundary (where the engine
never exact-rescores) they can differ between batch compositions. Treat
them as decision-grade diagnostics, not calibrated evidence.

``DetectionService`` is the async layer on top: a worker thread drains a
bounded queue into ``serve_batch`` calls, ``submit`` hands back a
``concurrent.futures.Future`` and *blocks* once ``max_pending_rows`` query
rows are queued (backpressure — the caller slows down instead of the queue
growing without bound). ``launch/serve.py --task detect`` is the CLI on top
of this module.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import DetectionEngine
from repro.core.types import ClaimsDataset, CopyConfig


class ServiceOverloaded(TimeoutError):
    """Raised by ``DetectionService.submit`` when backpressure wins: the
    pending-row budget stayed full for the whole submit timeout."""


@dataclass
class DetectRequest:
    """One detection query: ``values.shape[0]`` query sources vs the corpus.

    Query rows must use the corpus's value coding — ``values[r, d]`` equal to
    a corpus source's code on item d means "the same value" (−1 = item not
    claimed; a per-item query is simply a row that claims few items).
    """

    rid: int                      # caller-chosen id, echoed on the response
    values: np.ndarray            # (q, D) int32 — same item axis as the corpus
    accuracy: np.ndarray          # (q,) float32 — accuracy estimate per row
    p_claim: np.ndarray           # (q, D) float32 — truth prob of each claim

    def __post_init__(self):
        self.values = np.asarray(self.values, dtype=np.int32)
        self.accuracy = np.asarray(self.accuracy, dtype=np.float32)
        self.p_claim = np.asarray(self.p_claim, dtype=np.float32)
        if self.values.ndim != 2 or self.p_claim.shape != self.values.shape:
            raise ValueError("values/p_claim must both be (q, D)")
        if self.accuracy.shape != (self.values.shape[0],):
            raise ValueError("accuracy must be (q,)")

    @property
    def n_rows(self) -> int:
        """Number of query sources in this request."""
        return self.values.shape[0]


@dataclass
class DetectResponse:
    """Per-request slice of one batched engine pass.

    Row r of every matrix is the request's r-th query source; columns of the
    ``*_vs_corpus`` fields are corpus sources. Pairs with other requests in
    the same batch are never included. ``copying``/``intra_copying`` are
    batch-independent (equal to a solo engine pass); ``c_fwd`` and
    ``pr_independent`` carry the bucketed approximation away from the
    decision boundary and can vary with batch composition (module docstring).
    """

    rid: int
    copying: np.ndarray           # (q, S_corpus) bool — query copies corpus?
    pr_independent: np.ndarray    # (q, S_corpus) Pr(⊥ | Φ), approximate
    c_fwd: np.ndarray             # (q, S_corpus) C→ (bucketed approximation)
    intra_copying: np.ndarray     # (q, q) bool — within-request pairs
    batch_requests: int = 1       # how many requests shared the engine pass
    batch_rows: int = 0           # total query rows in that pass
    engine_wall_s: float = 0.0    # wall time of the shared pass
    latency_s: float = 0.0        # submit → result (filled by the service)

    def copying_sources(self, row: int = 0) -> np.ndarray:
        """Corpus source indices the given query row is detected to copy."""
        return np.nonzero(self.copying[row])[0]


def serve_batch(
    base: ClaimsDataset,
    base_p: np.ndarray,
    engine: DetectionEngine,
    requests: Sequence[DetectRequest],
) -> list[DetectResponse]:
    """Answer a batch of requests with ONE tiled engine pass (DESIGN.md §5).

    Args:
      base: the shared corpus (S, D).
      base_p: (S, D) per-claim truth probabilities of the corpus.
      engine: any stateless-mode DetectionEngine (``bucketed`` for exact
        serving, ``sample_verify`` for sampled serving at scale);
        ``incremental`` is rejected — its bookkeeping assumes a fixed source
        axis, which batching changes every call.
      requests: the pending requests; their rows are stacked under the
        corpus rows in order.

    Returns one ``DetectResponse`` per request, in request order.
    """
    if engine.mode == "incremental":
        raise ValueError("serve_batch requires a stateless engine mode")
    if not requests:
        return []
    D = base.n_items
    for r in requests:
        if r.values.shape[1] != D:
            raise ValueError(
                f"request {r.rid}: {r.values.shape[1]} items, corpus has {D}")
    S0 = base.n_sources
    values = np.concatenate([base.values] + [r.values for r in requests])
    acc = np.concatenate([base.accuracy] + [r.accuracy for r in requests])
    p = np.concatenate([base_p] + [r.p_claim for r in requests])
    union = ClaimsDataset(values=values, accuracy=acc)

    res = engine.detect(union, p)

    out = []
    off = S0
    n_rows = sum(r.n_rows for r in requests)
    for r in requests:
        rows = slice(off, off + r.n_rows)
        out.append(DetectResponse(
            rid=r.rid,
            copying=res.copying[rows, :S0].copy(),
            pr_independent=res.pr_independent[rows, :S0].copy(),
            c_fwd=res.c_fwd[rows, :S0].copy(),
            intra_copying=res.copying[rows, rows].copy(),
            batch_requests=len(requests),
            batch_rows=n_rows,
            engine_wall_s=res.wall_time_s,
        ))
        off += r.n_rows
    return out


@dataclass
class ServiceStats:
    """Counters the service accumulates across batches (read via .stats)."""

    requests: int = 0
    batches: int = 0
    rows: int = 0
    rejected: int = 0             # submits that timed out on backpressure

    @property
    def mean_batch(self) -> float:
        """Mean requests per engine pass (1.0 ⇒ batching never kicked in)."""
        return self.requests / self.batches if self.batches else 0.0


class DetectionService:
    """Queue + worker thread that batches requests through one engine.

    Lifecycle::

        svc = DetectionService(corpus, p, cfg, max_batch_requests=8)
        with svc:                       # starts the worker thread
            futs = [svc.submit(r) for r in reqs]   # blocks when queue full
            results = [f.result() for f in futs]

    ``submit`` applies backpressure: once ``max_pending_rows`` query rows are
    waiting, it blocks (up to ``timeout``) until the worker drains the queue,
    then raises ``ServiceOverloaded`` — load sheds at the edge instead of
    accumulating unbounded memory. Without the context manager (or
    ``start()``), ``flush()`` drains the queue synchronously in the caller's
    thread — the deterministic path tests and benchmarks use.
    """

    def __init__(
        self,
        base: ClaimsDataset,
        base_p: np.ndarray,
        cfg: CopyConfig,
        *,
        mode: str = "bucketed",
        max_batch_requests: int = 8,
        max_pending_rows: int = 256,
        **engine_options,
    ):
        """Build the service around a fresh engine.

        max_batch_requests: requests folded into one engine pass (the bench
          sweeps this; ≥ 3× throughput at 8 on the serve benchmark).
        max_pending_rows: backpressure bound on queued query rows.
        engine_options: forwarded to ``EngineOptions`` (tile, devices, ...).
        """
        if mode == "incremental":
            raise ValueError(
                "DetectionService requires a stateless engine mode "
                "(incremental bookkeeping assumes a fixed source axis)")
        self.base = base
        self.base_p = np.asarray(base_p, dtype=np.float32)
        self.engine = DetectionEngine(cfg, mode=mode, **engine_options)
        self.max_batch_requests = int(max_batch_requests)
        self.max_pending_rows = int(max_pending_rows)
        self.stats = ServiceStats()
        self._pending: deque = deque()   # (request, future, t_submit)
        self._pending_rows = 0
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._stopping = False

    # -- submission ---------------------------------------------------------

    def submit(self, request: DetectRequest,
               timeout: Optional[float] = 30.0) -> Future:
        """Enqueue a request; returns a Future resolving to DetectResponse.

        Blocks while the pending-row budget is full (backpressure); raises
        ``ServiceOverloaded`` if it stays full past ``timeout`` seconds, and
        ``ValueError`` for a request that could never fit the budget.
        """
        if request.n_rows > self.max_pending_rows:
            raise ValueError(
                f"request {request.rid}: {request.n_rows} rows exceeds "
                f"max_pending_rows={self.max_pending_rows}")
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._stopping:
                # after the worker's final drain a queued entry would never
                # resolve — refuse instead of stranding the future
                raise RuntimeError("service is stopping; submit rejected")
            while self._pending_rows + request.n_rows > self.max_pending_rows:
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    self.stats.rejected += 1
                    raise ServiceOverloaded(
                        f"queue full ({self._pending_rows} rows pending)")
                self._cv.wait(wait)
                if self._stopping:
                    # stop() drained the queue while we waited — enqueueing
                    # now would strand the future past the worker's exit
                    raise RuntimeError("service is stopping; submit rejected")
            fut: Future = Future()
            self._pending.append((request, fut, time.monotonic()))
            self._pending_rows += request.n_rows
            self._cv.notify_all()
        return fut

    # -- draining -----------------------------------------------------------

    def _take_batch(self) -> list:
        """Pop up to max_batch_requests pending entries (caller holds _cv)."""
        batch = []
        while self._pending and len(batch) < self.max_batch_requests:
            entry = self._pending.popleft()
            self._pending_rows -= entry[0].n_rows
            batch.append(entry)
        if batch:
            self._cv.notify_all()        # wake blocked submitters
        return batch

    @staticmethod
    def _resolve(fut: Future, *, result=None, exc=None) -> None:
        """Resolve a future, tolerating client-side cancellation — a
        cancelled future must never take down the worker thread."""
        if not fut.set_running_or_notify_cancel():
            return                                   # client cancelled it
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)

    def _run_batch(self, batch: list) -> None:
        """One serve_batch call; resolve (or fail) every future in it."""
        reqs = [entry[0] for entry in batch]
        try:
            responses = serve_batch(self.base, self.base_p, self.engine, reqs)
        except Exception as exc:                      # noqa: BLE001
            for _, fut, _ in batch:
                self._resolve(fut, exc=exc)
            return
        done = time.monotonic()
        for (_, fut, t_sub), resp in zip(batch, responses):
            resp.latency_s = done - t_sub
            self._resolve(fut, result=resp)
        self.stats.requests += len(batch)
        self.stats.batches += 1
        self.stats.rows += sum(r.n_rows for r in reqs)

    def flush(self) -> int:
        """Synchronously drain the queue in the caller's thread.

        Returns the number of requests served. Only valid when no worker
        thread is running (deterministic tests / benchmarks) — the engine is
        stateful per pass, so two threads must never drive it concurrently."""
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError(
                "flush() while the worker thread is running would drive the "
                "engine from two threads; use the futures instead")
        served = 0
        while True:
            with self._cv:
                batch = self._take_batch()
            if not batch:
                return served
            self._run_batch(batch)
            served += len(batch)

    # -- worker lifecycle ---------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stopping:
                    self._cv.wait()
                if self._stopping and not self._pending:
                    return
                batch = self._take_batch()
            if batch:
                self._run_batch(batch)

    def start(self) -> "DetectionService":
        """Start the background worker (idempotent)."""
        if self._worker is None or not self._worker.is_alive():
            self._stopping = False
            self._worker = threading.Thread(
                target=self._worker_loop, name="detection-service", daemon=True)
            self._worker.start()
        return self

    def stop(self) -> None:
        """Drain remaining requests, then join the worker."""
        if self._worker is None:
            self.flush()
            return
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._worker.join()
        self._worker = None
        with self._cv:
            # back to idle under the lock, so a submitter that raced the
            # shutdown either saw _stopping (and raised) or lands in the
            # defined idle state: enqueued for a later flush()/start()
            self._stopping = False

    def __enter__(self) -> "DetectionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


__all__ = ["DetectRequest", "DetectResponse", "DetectionService",
           "ServiceOverloaded", "ServiceStats", "serve_batch"]
