"""Batched copy-detection serving (DESIGN.md §5).

A detection service answers *queries against a shared corpus*: each request
carries a handful of query sources — dataset deltas (new or re-crawled
sources) or per-item queries (sparse rows claiming only the items the caller
cares about) — and asks which corpus sources they copy from. Running the
`DetectionEngine` once per request wastes the engine's fixed costs (index
build, bucketize, tile pruning, kernel dispatch) on a tile grid that is
~identical across requests.

``serve_batch`` instead answers the batch with ONE tiled engine pass over
the union of corpus and query rows. The union is never concatenated: a
``ResidentCorpus`` preallocates the claims buffers once with ``S_max`` slack
rows (DESIGN.md §6), each batch writes only its query rows into the slack
(O(q·D), not O(S·D)), and the engine sees a zero-copy row view. Each
request's row-slice of the decision matrix is then scattered back into its
own response. This is sound
because a pair's exact-INDEX decision is intrinsic to the two sources'
claims (DESIGN.md §5): co-batched strangers can create new index entries,
but those entries only ever contribute to pairs that actually share the
value, so batched decisions equal the per-request ones — asserted by
tests/test_serving.py and re-checked by the `serve` benchmark in CI.
Cross-request pairs are computed (they ride along in the same tiles for
free) but never reported: each response sees only its rows vs the corpus
plus its own intra-request block.

The invariant is about *decisions*: ``copying``/``intra_copying`` are
batch-independent. The continuous fields (``c_fwd``, ``pr_independent``)
are the engine's bucketed approximation, and the bucket p̂-quantiles shift
with the union index — away from the decision boundary (where the engine
never exact-rescores) they can differ between batch compositions. Treat
them as decision-grade diagnostics, not calibrated evidence.

``DetectionService`` is the async layer on top: a worker thread drains a
bounded queue into ``serve_batch`` calls, ``submit`` hands back a
``concurrent.futures.Future`` and *blocks* once ``max_pending_rows`` query
rows are queued (backpressure — the caller slows down instead of the queue
growing without bound). ``launch/serve.py --task detect`` is the CLI on top
of this module.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import DetectionEngine
from repro.core.types import ClaimsDataset, CopyConfig


class ServiceOverloaded(TimeoutError):
    """Raised by ``DetectionService.submit`` when backpressure wins: the
    pending-row budget stayed full for the whole submit timeout."""


@dataclass
class DetectRequest:
    """One detection query: ``values.shape[0]`` query sources vs the corpus.

    Query rows must use the corpus's value coding — ``values[r, d]`` equal to
    a corpus source's code on item d means "the same value" (−1 = item not
    claimed; a per-item query is simply a row that claims few items).
    """

    rid: int                      # caller-chosen id, echoed on the response
    values: np.ndarray            # (q, D) int32 — same item axis as the corpus
    accuracy: np.ndarray          # (q,) float32 — accuracy estimate per row
    p_claim: np.ndarray           # (q, D) float32 — truth prob of each claim

    def __post_init__(self):
        self.values = np.asarray(self.values, dtype=np.int32)
        self.accuracy = np.asarray(self.accuracy, dtype=np.float32)
        self.p_claim = np.asarray(self.p_claim, dtype=np.float32)
        if self.values.ndim != 2 or self.p_claim.shape != self.values.shape:
            raise ValueError("values/p_claim must both be (q, D)")
        if self.accuracy.shape != (self.values.shape[0],):
            raise ValueError("accuracy must be (q,)")

    @property
    def n_rows(self) -> int:
        """Number of query sources in this request."""
        return self.values.shape[0]


@dataclass
class DetectResponse:
    """Per-request slice of one batched engine pass.

    Row r of every matrix is the request's r-th query source; columns of the
    ``*_vs_corpus`` fields are corpus sources. Pairs with other requests in
    the same batch are never included. ``copying``/``intra_copying`` are
    batch-independent (equal to a solo engine pass); ``c_fwd`` and
    ``pr_independent`` carry the bucketed approximation away from the
    decision boundary and can vary with batch composition (module docstring).
    """

    rid: int
    copying: np.ndarray           # (q, S_corpus) bool — query copies corpus?
    pr_independent: np.ndarray    # (q, S_corpus) Pr(⊥ | Φ), approximate
    c_fwd: np.ndarray             # (q, S_corpus) C→ (bucketed approximation)
    intra_copying: np.ndarray     # (q, q) bool — within-request pairs
    batch_requests: int = 1       # how many requests shared the engine pass
    batch_rows: int = 0           # total query rows in that pass
    engine_wall_s: float = 0.0    # wall time of the shared pass
    latency_s: float = 0.0        # submit → result (filled by the service)
    host_copy_bytes: int = 0      # bytes staged into the resident buffers
                                  # for this batch (query rows only)

    def copying_sources(self, row: int = 0) -> np.ndarray:
        """Corpus source indices the given query row is detected to copy."""
        return np.nonzero(self.copying[row])[0]


class ResidentCorpus:
    """Preallocated corpus + query-slack claims buffers (DESIGN.md §6).

    The corpus rows are written ONCE at construction; every batch after that
    writes only its query rows into the ``max_query_rows`` slack and hands
    the engine a zero-copy row view — the O(S·D) per-batch union
    concatenation the legacy ``serve_batch`` did is gone. The buffers mirror
    the ``CorpusStore`` row-slack protocol (``store.append_rows``) one level
    up, at the claims layer the per-batch index build streams from.
    """

    def __init__(self, base: ClaimsDataset, base_p: np.ndarray,
                 max_query_rows: int):
        S0, D = base.values.shape
        self.n_corpus = S0
        self.capacity = S0 + int(max_query_rows)
        self.values = np.full((self.capacity, D), -1, np.int32)
        self.accuracy = np.full(self.capacity, 0.5, np.float32)
        self.p_claim = np.zeros((self.capacity, D), np.float32)
        self.values[:S0] = base.values
        self.accuracy[:S0] = base.accuracy
        self.p_claim[:S0] = base_p
        self._full = ClaimsDataset(values=self.values, accuracy=self.accuracy,
                                   item_names=base.item_names)

    @property
    def n_items(self) -> int:
        """D — item columns of the resident buffers."""
        return self.values.shape[1]

    def corpus_view(self) -> ClaimsDataset:
        """Zero-copy dataset over the corpus rows only (no query slack).

        Long-lived owners (``DetectionService``) rebind their corpus
        reference to this view so the resident buffers are the SINGLE copy
        of the corpus in memory — not a second one next to the caller's."""
        return self._full.row_view(self.n_corpus)

    def stage(self, requests: Sequence[DetectRequest]
              ) -> tuple[ClaimsDataset, np.ndarray, int]:
        """Write the batch's query rows into the slack; return the union view.

        Returns ``(union_dataset, union_p, bytes_written)`` where both union
        arrays are zero-copy views of the resident buffers covering the
        corpus plus the staged rows, and ``bytes_written`` counts only the
        query-row bytes (the measurable win over the legacy concat).
        """
        off = self.n_corpus
        written = 0
        for r in requests:
            if off + r.n_rows > self.capacity:
                raise ValueError(
                    f"batch of {sum(q.n_rows for q in requests)} query rows "
                    f"exceeds resident slack "
                    f"({self.capacity - self.n_corpus} rows)")
            rows = slice(off, off + r.n_rows)
            self.values[rows] = r.values
            self.accuracy[rows] = r.accuracy
            self.p_claim[rows] = r.p_claim
            written += r.values.nbytes + r.accuracy.nbytes + r.p_claim.nbytes
            off += r.n_rows
        return self._full.row_view(off), self.p_claim[:off], written


def serve_batch(
    base: ClaimsDataset,
    base_p: np.ndarray,
    engine: DetectionEngine,
    requests: Sequence[DetectRequest],
    resident: Optional[ResidentCorpus] = None,
) -> list[DetectResponse]:
    """Answer a batch of requests with ONE tiled engine pass (DESIGN.md §5).

    Args:
      base: the shared corpus (S, D).
      base_p: (S, D) per-claim truth probabilities of the corpus.
      engine: any stateless-mode DetectionEngine (``bucketed`` for exact
        serving, ``sample_verify`` for sampled serving at scale);
        ``incremental`` is rejected — its bookkeeping assumes a fixed source
        axis, which batching changes every call.
      requests: the pending requests; their rows are staged into the
        resident slack under the corpus rows, in order.
      resident: the preallocated buffers to stage into. ``DetectionService``
        passes its own (built once); a standalone call builds a transient
        one sized for this batch — the corpus copy then happens once here
        rather than once per batch.

    Returns one ``DetectResponse`` per request, in request order.
    """
    if engine.mode == "incremental":
        raise ValueError("serve_batch requires a stateless engine mode")
    if not requests:
        return []
    D = base.n_items
    for r in requests:
        if r.values.shape[1] != D:
            raise ValueError(
                f"request {r.rid}: {r.values.shape[1]} items, corpus has {D}")
    S0 = base.n_sources
    n_rows = sum(r.n_rows for r in requests)
    if resident is None:
        resident = ResidentCorpus(base, base_p, max_query_rows=n_rows)
    elif resident.n_corpus != S0 or resident.n_items != D:
        # detection would silently run against the resident's corpus, not
        # ``base``, and the response slices would misalign — fail fast
        raise ValueError(
            f"resident corpus is {resident.n_corpus}×{resident.n_items}, "
            f"base is {S0}×{D}; serve_batch requires the resident to be "
            f"built over the same corpus")
    union, p, copied = resident.stage(requests)

    res = engine.detect(union, p)

    out = []
    off = S0
    for r in requests:
        rows = slice(off, off + r.n_rows)
        out.append(DetectResponse(
            rid=r.rid,
            copying=res.copying[rows, :S0].copy(),
            pr_independent=res.pr_independent[rows, :S0].copy(),
            c_fwd=res.c_fwd[rows, :S0].copy(),
            intra_copying=res.copying[rows, rows].copy(),
            batch_requests=len(requests),
            batch_rows=n_rows,
            engine_wall_s=res.wall_time_s,
            host_copy_bytes=copied,
        ))
        off += r.n_rows
    return out


@dataclass
class ServiceStats:
    """Counters the service accumulates across batches (read via .stats)."""

    requests: int = 0
    batches: int = 0
    rows: int = 0
    rejected: int = 0             # submits that timed out on backpressure
    host_copy_bytes: int = 0      # total bytes staged into the resident
                                  # buffers (query rows only — the corpus is
                                  # written once, at service construction)

    @property
    def mean_batch(self) -> float:
        """Mean requests per engine pass (1.0 ⇒ batching never kicked in)."""
        return self.requests / self.batches if self.batches else 0.0


class DetectionService:
    """Queue + worker thread that batches requests through one engine.

    Lifecycle::

        svc = DetectionService(corpus, p, cfg, max_batch_requests=8)
        with svc:                       # starts the worker thread
            futs = [svc.submit(r) for r in reqs]   # blocks when queue full
            results = [f.result() for f in futs]

    ``submit`` applies backpressure: once ``max_pending_rows`` query rows are
    waiting, it blocks (up to ``timeout``) until the worker drains the queue,
    then raises ``ServiceOverloaded`` — load sheds at the edge instead of
    accumulating unbounded memory. Without the context manager (or
    ``start()``), ``flush()`` drains the queue synchronously in the caller's
    thread — the deterministic path tests and benchmarks use.
    """

    def __init__(
        self,
        base: ClaimsDataset,
        base_p: np.ndarray,
        cfg: CopyConfig,
        *,
        mode: str = "bucketed",
        max_batch_requests: int = 8,
        max_pending_rows: int = 256,
        **engine_options,
    ):
        """Build the service around a fresh engine.

        max_batch_requests: requests folded into one engine pass (the bench
          sweeps this; ≥ 3× throughput at 8 on the serve benchmark).
        max_pending_rows: backpressure bound on queued query rows.
        engine_options: forwarded to ``EngineOptions`` (tile, devices, ...).
        """
        if mode == "incremental":
            raise ValueError(
                "DetectionService requires a stateless engine mode "
                "(incremental bookkeeping assumes a fixed source axis)")
        self.engine = DetectionEngine(cfg, mode=mode, **engine_options)
        self.max_batch_requests = int(max_batch_requests)
        self.max_pending_rows = int(max_pending_rows)
        # ONE resident buffer for the service's lifetime: corpus written
        # here once, every batch stages only its query rows (DESIGN.md §6).
        # base/base_p are then rebound to views of it, so the service holds
        # a single corpus copy (the caller's arrays are theirs to drop).
        self.resident = ResidentCorpus(base, np.asarray(base_p, np.float32),
                                       max_query_rows=self.max_pending_rows)
        self.base = self.resident.corpus_view()
        self.base_p = self.resident.p_claim[: self.resident.n_corpus]
        self.stats = ServiceStats()
        self._pending: deque = deque()   # (request, future, t_submit)
        self._pending_rows = 0
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._stopping = False

    # -- submission ---------------------------------------------------------

    def submit(self, request: DetectRequest,
               timeout: Optional[float] = 30.0) -> Future:
        """Enqueue a request; returns a Future resolving to DetectResponse.

        Blocks while the pending-row budget is full (backpressure); raises
        ``ServiceOverloaded`` if it stays full past ``timeout`` seconds, and
        ``ValueError`` for a request that could never fit the budget.
        """
        if request.n_rows > self.max_pending_rows:
            raise ValueError(
                f"request {request.rid}: {request.n_rows} rows exceeds "
                f"max_pending_rows={self.max_pending_rows}")
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._stopping:
                # after the worker's final drain a queued entry would never
                # resolve — refuse instead of stranding the future
                raise RuntimeError("service is stopping; submit rejected")
            while self._pending_rows + request.n_rows > self.max_pending_rows:
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    self.stats.rejected += 1
                    raise ServiceOverloaded(
                        f"queue full ({self._pending_rows} rows pending)")
                self._cv.wait(wait)
                if self._stopping:
                    # stop() drained the queue while we waited — enqueueing
                    # now would strand the future past the worker's exit
                    raise RuntimeError("service is stopping; submit rejected")
            fut: Future = Future()
            self._pending.append((request, fut, time.monotonic()))
            self._pending_rows += request.n_rows
            self._cv.notify_all()
        return fut

    # -- draining -----------------------------------------------------------

    def _take_batch(self) -> list:
        """Pop up to max_batch_requests pending entries (caller holds _cv)."""
        batch = []
        while self._pending and len(batch) < self.max_batch_requests:
            entry = self._pending.popleft()
            self._pending_rows -= entry[0].n_rows
            batch.append(entry)
        if batch:
            self._cv.notify_all()        # wake blocked submitters
        return batch

    @staticmethod
    def _resolve(fut: Future, *, result=None, exc=None) -> None:
        """Resolve a future, tolerating client-side cancellation — a
        cancelled future must never take down the worker thread."""
        if not fut.set_running_or_notify_cancel():
            return                                   # client cancelled it
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)

    def _run_batch(self, batch: list) -> None:
        """One serve_batch call; resolve (or fail) every future in it."""
        reqs = [entry[0] for entry in batch]
        try:
            responses = serve_batch(self.base, self.base_p, self.engine, reqs,
                                    resident=self.resident)
        except Exception as exc:                      # noqa: BLE001
            for _, fut, _ in batch:
                self._resolve(fut, exc=exc)
            return
        done = time.monotonic()
        for (_, fut, t_sub), resp in zip(batch, responses):
            resp.latency_s = done - t_sub
            self._resolve(fut, result=resp)
        self.stats.requests += len(batch)
        self.stats.batches += 1
        self.stats.rows += sum(r.n_rows for r in reqs)
        self.stats.host_copy_bytes += responses[0].host_copy_bytes if responses else 0

    def flush(self) -> int:
        """Synchronously drain the queue in the caller's thread.

        Returns the number of requests served. Only valid when no worker
        thread is running (deterministic tests / benchmarks) — the engine is
        stateful per pass, so two threads must never drive it concurrently."""
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError(
                "flush() while the worker thread is running would drive the "
                "engine from two threads; use the futures instead")
        served = 0
        while True:
            with self._cv:
                batch = self._take_batch()
            if not batch:
                return served
            self._run_batch(batch)
            served += len(batch)

    # -- worker lifecycle ---------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stopping:
                    self._cv.wait()
                if self._stopping and not self._pending:
                    return
                batch = self._take_batch()
            if batch:
                self._run_batch(batch)

    def start(self) -> "DetectionService":
        """Start the background worker (idempotent)."""
        if self._worker is None or not self._worker.is_alive():
            self._stopping = False
            self._worker = threading.Thread(
                target=self._worker_loop, name="detection-service", daemon=True)
            self._worker.start()
        return self

    def stop(self) -> None:
        """Drain remaining requests, then join the worker."""
        if self._worker is None:
            self.flush()
            return
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._worker.join()
        self._worker = None
        with self._cv:
            # back to idle under the lock, so a submitter that raced the
            # shutdown either saw _stopping (and raised) or lands in the
            # defined idle state: enqueued for a later flush()/start()
            self._stopping = False

    def __enter__(self) -> "DetectionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


__all__ = ["DetectRequest", "DetectResponse", "DetectionService",
           "ResidentCorpus", "ServiceOverloaded", "ServiceStats",
           "serve_batch"]
