"""Batched copy-detection serving (DESIGN.md §5).

A detection service answers *queries against a shared corpus*: each request
carries a handful of query sources — dataset deltas (new or re-crawled
sources) or per-item queries (sparse rows claiming only the items the caller
cares about) — and asks which corpus sources they copy from. Running the
`DetectionEngine` once per request wastes the engine's fixed costs (index
build, bucketize, tile pruning, kernel dispatch) on a tile grid that is
~identical across requests.

``serve_batch`` instead answers the batch with ONE tiled engine pass over
the union of corpus and query rows. The union is never concatenated: a
``ResidentCorpus`` preallocates the claims buffers once with ``S_max`` slack
rows (DESIGN.md §6), each batch writes only its query rows into the slack
(O(q·D), not O(S·D)), and the engine sees a zero-copy row view. Each
request's row-slice of the decision matrix is then scattered back into its
own response. This is sound
because a pair's exact-INDEX decision is intrinsic to the two sources'
claims (DESIGN.md §5): co-batched strangers can create new index entries,
but those entries only ever contribute to pairs that actually share the
value, so batched decisions equal the per-request ones — asserted by
tests/test_serving.py and re-checked by the `serve` benchmark in CI.
Cross-request pairs are computed (they ride along in the same tiles for
free) but never reported: each response sees only its rows vs the corpus
plus its own intra-request block.

The invariant is about *decisions*: ``copying``/``intra_copying`` are
batch-independent. The continuous fields (``c_fwd``, ``pr_independent``)
are the engine's bucketed approximation, and the bucket p̂-quantiles shift
with the union index — away from the decision boundary (where the engine
never exact-rescores) they can differ between batch compositions. Treat
them as decision-grade diagnostics, not calibrated evidence.

``DetectionService`` is the async layer on top: a worker thread drains a
bounded queue into ``serve_batch`` calls, ``submit`` hands back a
``concurrent.futures.Future`` and *blocks* once ``max_pending_rows`` query
rows are queued (backpressure — the caller slows down instead of the queue
growing without bound). ``launch/serve.py --task detect`` is the CLI on top
of this module.

Live corpus mutation (DESIGN.md §7): ``DetectionService.commit`` folds
accepted query rows into the resident corpus AND the service's committed
``InvertedIndex`` (``index.commit_rows`` — delta chunks, no rebuild);
per-batch unions reuse that index through a transient commit + rollback, so
the per-batch index rebuild is gone for index-backed modes. A ``ResultCache``
memoizes per-request responses across batches, keyed by request content and
corpus epoch, and invalidates an entry exactly when a commit since its epoch
touches a claim key the request shares (the provable-unaffected rule §7
argues). ``ReplicaRouter`` fans submits over N service replicas and
broadcasts commits under one lock — reads scale, writes stay serialized with
epoch-consistent state; a replica that fails mid-broadcast rolls the
already-committed replicas back LIFO and surfaces one typed
``ReplicaBroadcastError``.

Durability (DESIGN.md §8, OPERATIONS.md): pass ``durability=
DurabilityOptions(state_dir=...)`` and every ``commit()`` appends one
fsync'd, checksummed record to ``core/wal.py``'s commit log before
returning, with periodic full-state snapshots (resident corpus, committed
index, stats, touched-key log, result-cache entries).
``DetectionService.restore(state_dir)`` loads the newest valid snapshot,
truncates any torn log tail, deterministically replays the log records past
the snapshot epoch, and resumes serving with a warm cache — decisions
bit-equal to a never-restarted service.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
import dataclasses
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.core.engine import DetectionEngine
from repro.core.index import (
    InvertedIndex,
    build_index,
    commit_rows,
    rollback_commit,
)
from repro.core.index import retract_rows as index_retract_rows
from repro.core.shardplan import (
    ShardScanError,
    ShardedCorpusStore,
    make_shard_plan,
    shard_store,
)
from repro.core.types import ClaimsDataset, CopyConfig, claim_value_keys
from repro.core.wal import (
    LOG_NAME,
    MANIFEST_NAME,
    CommitLog,
    CommitRecord,
    DurabilityOptions,
    ReplayDivergenceError,
    RestoreInfo,
    RetractRecord,
    latest_valid_snapshot,
    list_snapshots,
    read_manifest,
    write_manifest,
    write_snapshot,
)

#: Engine modes that consume a prebuilt InvertedIndex — for these the service
#: maintains ONE committed index across batches (per-batch transient commits
#: replace the per-batch rebuild); other modes index internally per pass.
INDEXED_MODES = ("exact", "bound", "bound+", "hybrid", "bucketed")


class ServiceOverloaded(TimeoutError):
    """Raised by ``DetectionService.submit`` when backpressure wins: the
    pending-row budget stayed full for the whole submit timeout."""


class DeadlineExceeded(TimeoutError):
    """A request's ``deadline_s`` cannot (or did not) hold.

    Distinct from ``ServiceOverloaded``: backpressure means the QUEUE is
    full; a deadline miss means this request's time budget is spent —
    either shed on arrival (the EWMA of recent batch latency predicts the
    queue wait alone exceeds the deadline — admission control, DESIGN.md
    §9) or expired while queued. The caller can retry with a looser
    deadline; retrying immediately with the same one will shed again.
    """


class ServiceStopped(RuntimeError):
    """Typed rejection for a submit that raced ``stop()``: the worker's
    final drain already ran (or is running), so enqueueing would strand the
    future. A ``RuntimeError`` subclass — pre-existing callers catching that
    still work."""


@dataclass
class DetectRequest:
    """One detection query: ``values.shape[0]`` query sources vs the corpus.

    Query rows must use the corpus's value coding — ``values[r, d]`` equal to
    a corpus source's code on item d means "the same value" (−1 = item not
    claimed; a per-item query is simply a row that claims few items).
    """

    rid: int                      # caller-chosen id, echoed on the response
    values: np.ndarray            # (q, D) int32 — same item axis as the corpus
    accuracy: np.ndarray          # (q,) float32 — accuracy estimate per row
    p_claim: np.ndarray           # (q, D) float32 — truth prob of each claim
    deadline_s: Optional[float] = None  # seconds from submit the caller is
                                  # willing to wait; the service sheds the
                                  # request (DeadlineExceeded) rather than
                                  # serve it late (DESIGN.md §9)

    def __post_init__(self):
        self.values = np.asarray(self.values, dtype=np.int32)
        self.accuracy = np.asarray(self.accuracy, dtype=np.float32)
        self.p_claim = np.asarray(self.p_claim, dtype=np.float32)
        if self.values.ndim != 2 or self.p_claim.shape != self.values.shape:
            raise ValueError("values/p_claim must both be (q, D)")
        if self.accuracy.shape != (self.values.shape[0],):
            raise ValueError("accuracy must be (q,)")

    @property
    def n_rows(self) -> int:
        """Number of query sources in this request."""
        return self.values.shape[0]


@dataclass
class DetectResponse:
    """Per-request slice of one batched engine pass.

    Row r of every matrix is the request's r-th query source; columns of the
    ``*_vs_corpus`` fields are corpus sources. Pairs with other requests in
    the same batch are never included. ``copying``/``intra_copying`` are
    batch-independent (equal to a solo engine pass); ``c_fwd`` and
    ``pr_independent`` carry the bucketed approximation away from the
    decision boundary and can vary with batch composition (module docstring).
    """

    rid: int
    copying: np.ndarray           # (q, S_corpus) bool — query copies corpus?
    pr_independent: np.ndarray    # (q, S_corpus) Pr(⊥ | Φ), approximate
    c_fwd: np.ndarray             # (q, S_corpus) C→ (bucketed approximation)
    intra_copying: np.ndarray     # (q, q) bool — within-request pairs
    batch_requests: int = 1       # how many requests shared the engine pass
    batch_rows: int = 0           # total query rows in that pass
    engine_wall_s: float = 0.0    # wall time of the shared pass
    latency_s: float = 0.0        # submit → result (filled by the service)
    host_copy_bytes: int = 0      # bytes staged into the resident buffers
                                  # for this batch (query rows only)
    cache_hit: bool = False       # served from the cross-batch ResultCache
                                  # (decisions provably unaffected by every
                                  # commit since the cached epoch — §7)

    def copying_sources(self, row: int = 0) -> np.ndarray:
        """Corpus source indices the given query row is detected to copy."""
        return np.nonzero(self.copying[row])[0]


class ResidentCorpus:
    """Preallocated corpus + query-slack claims buffers (DESIGN.md §6).

    The corpus rows are written ONCE at construction; every batch after that
    writes only its query rows into the ``max_query_rows`` slack and hands
    the engine a zero-copy row view — the O(S·D) per-batch union
    concatenation the legacy ``serve_batch`` did is gone. The buffers mirror
    the ``CorpusStore`` row-slack protocol (``store.append_rows``) one level
    up, at the claims layer the per-batch index build streams from.
    """

    def __init__(self, base: ClaimsDataset, base_p: np.ndarray,
                 max_query_rows: int):
        S0, D = base.values.shape
        self.n_corpus = S0
        self.max_query_rows = int(max_query_rows)
        self.capacity = S0 + self.max_query_rows
        self.values = np.full((self.capacity, D), -1, np.int32)
        self.accuracy = np.full(self.capacity, 0.5, np.float32)
        self.p_claim = np.zeros((self.capacity, D), np.float32)
        self.values[:S0] = base.values
        self.accuracy[:S0] = base.accuracy
        self.p_claim[:S0] = base_p
        self._item_names = base.item_names
        self._full = ClaimsDataset(values=self.values, accuracy=self.accuracy,
                                   item_names=base.item_names)

    @property
    def n_items(self) -> int:
        """D — item columns of the resident buffers."""
        return self.values.shape[1]

    def corpus_view(self) -> ClaimsDataset:
        """Zero-copy dataset over the corpus rows only (no query slack).

        Long-lived owners (``DetectionService``) rebind their corpus
        reference to this view so the resident buffers are the SINGLE copy
        of the corpus in memory — not a second one next to the caller's."""
        return self._full.row_view(self.n_corpus)

    def stage(self, requests: Sequence[DetectRequest]
              ) -> tuple[ClaimsDataset, np.ndarray, int]:
        """Write the batch's query rows into the slack; return the union view.

        Returns ``(union_dataset, union_p, bytes_written)`` where both union
        arrays are zero-copy views of the resident buffers covering the
        corpus plus the staged rows, and ``bytes_written`` counts only the
        query-row bytes (the measurable win over the legacy concat).
        """
        off = self.n_corpus
        written = 0
        for r in requests:
            if off + r.n_rows > self.capacity:
                raise ValueError(
                    f"batch of {sum(q.n_rows for q in requests)} query rows "
                    f"exceeds resident slack "
                    f"({self.capacity - self.n_corpus} rows)")
            rows = slice(off, off + r.n_rows)
            self.values[rows] = r.values
            self.accuracy[rows] = r.accuracy
            self.p_claim[rows] = r.p_claim
            written += r.values.nbytes + r.accuracy.nbytes + r.p_claim.nbytes
            off += r.n_rows
        return self._full.row_view(off), self.p_claim[:off], written

    # -- permanent commits (corpus mutation, DESIGN.md §7) -------------------

    def _grow(self, new_capacity: int) -> None:
        """Reallocate the resident buffers at a larger row capacity."""
        D = self.n_items
        values = np.full((new_capacity, D), -1, np.int32)
        accuracy = np.full(new_capacity, 0.5, np.float32)
        p_claim = np.zeros((new_capacity, D), np.float32)
        values[: self.capacity] = self.values
        accuracy[: self.capacity] = self.accuracy
        p_claim[: self.capacity] = self.p_claim
        self.values, self.accuracy, self.p_claim = values, accuracy, p_claim
        self.capacity = new_capacity
        self._full = ClaimsDataset(values=self.values, accuracy=self.accuracy,
                                   item_names=self._item_names)

    def commit_rows(self, values: np.ndarray, accuracy: np.ndarray,
                    p_claim: np.ndarray) -> int:
        """Make query rows PERMANENT corpus rows (they stop being slack).

        Grows the buffers geometrically when the committed corpus would eat
        into the ``max_query_rows`` staging slack — the invariant
        ``capacity ≥ n_corpus + max_query_rows`` survives any number of
        commits. Returns the new corpus row count. Callers holding views
        from ``corpus_view()`` must re-acquire them after a commit (growth
        reallocates; ``DetectionService.commit`` rebinds its own).
        """
        q = values.shape[0]
        needed = self.n_corpus + q + self.max_query_rows
        if needed > self.capacity:
            self._grow(max(needed, 2 * self.capacity))
        rows = slice(self.n_corpus, self.n_corpus + q)
        self.values[rows] = values
        self.accuracy[rows] = accuracy
        self.p_claim[rows] = p_claim
        self.n_corpus += q
        return self.n_corpus

    def truncate_corpus(self, n_rows: int) -> None:
        """Undo trailing ``commit_rows`` calls: corpus shrinks to ``n_rows``.

        The freed rows return to staging slack, reset to the buffer's inert
        fill (−1 / 0.5 / 0) so a later ``stage``/``commit_rows`` finds them
        exactly as preallocation left them. LIFO counterpart of
        ``commit_rows``, used by ``DetectionService.rollback_last_commit``.
        """
        n_rows = int(n_rows)
        if n_rows > self.n_corpus:
            raise ValueError(
                f"truncate_corpus({n_rows}) above n_corpus={self.n_corpus}")
        rows = slice(n_rows, self.n_corpus)
        self.values[rows] = -1
        self.accuracy[rows] = 0.5
        self.p_claim[rows] = 0.0
        self.n_corpus = n_rows

    def retract_rows(self, row_ids: np.ndarray) -> int:
        """Remove ARBITRARY corpus rows (source retraction, DESIGN.md §9).

        The surviving rows compact upward (fancy-index gather — a copy, so
        overlapping source/destination is safe), the freed tail returns to
        the inert fill, and ``n_corpus`` drops. Returns the new corpus row
        count. Mirrors ``CorpusStore.retract_rows`` one level up, at the
        claims layer.
        """
        row_ids = np.unique(np.asarray(row_ids, np.int64))
        if len(row_ids) and (row_ids[0] < 0 or row_ids[-1] >= self.n_corpus):
            raise ValueError(
                f"retract_rows: ids out of range [0, {self.n_corpus})")
        keep = np.ones(self.n_corpus, bool)
        keep[row_ids] = False
        n_keep = int(keep.sum())
        self.values[:n_keep] = self.values[: self.n_corpus][keep]
        self.accuracy[:n_keep] = self.accuracy[: self.n_corpus][keep]
        self.p_claim[:n_keep] = self.p_claim[: self.n_corpus][keep]
        tail = slice(n_keep, self.n_corpus)
        self.values[tail] = -1
        self.accuracy[tail] = 0.5
        self.p_claim[tail] = 0.0
        self.n_corpus = n_keep
        return self.n_corpus

    def unretract(self, row_ids: np.ndarray, values: np.ndarray,
                  accuracy: np.ndarray, p_claim: np.ndarray) -> int:
        """Re-insert retracted rows at their original indices (rollback).

        LIFO counterpart of ``retract_rows`` for the router's broadcast
        recovery: the saved rows scatter back to ``row_ids`` and the
        survivors shift back to their pre-retraction positions, so the row
        coordinate system is restored exactly. Returns the new row count.
        """
        row_ids = np.unique(np.asarray(row_ids, np.int64))
        k = len(row_ids)
        n_new = self.n_corpus + k
        if n_new > self.capacity - self.max_query_rows:
            raise ValueError("unretract would eat into the staging slack")
        keep_pos = np.setdiff1d(np.arange(n_new), row_ids)
        cur_v = self.values[: self.n_corpus].copy()
        cur_a = self.accuracy[: self.n_corpus].copy()
        cur_p = self.p_claim[: self.n_corpus].copy()
        self.values[keep_pos] = cur_v
        self.accuracy[keep_pos] = cur_a
        self.p_claim[keep_pos] = cur_p
        self.values[row_ids] = values
        self.accuracy[row_ids] = accuracy
        self.p_claim[row_ids] = p_claim
        self.n_corpus = n_new
        return self.n_corpus


def serve_batch(
    base: ClaimsDataset,
    base_p: np.ndarray,
    engine: DetectionEngine,
    requests: Sequence[DetectRequest],
    resident: Optional[ResidentCorpus] = None,
    index: Optional[InvertedIndex] = None,
) -> list[DetectResponse]:
    """Answer a batch of requests with ONE tiled engine pass (DESIGN.md §5).

    Args:
      base: the shared corpus (S, D).
      base_p: (S, D) per-claim truth probabilities of the corpus.
      engine: any stateless-mode DetectionEngine (``bucketed`` for exact
        serving, ``sample_verify`` for sampled serving at scale);
        ``incremental`` is rejected — its bookkeeping assumes a fixed source
        axis, which batching changes every call.
      requests: the pending requests; their rows are staged into the
        resident slack under the corpus rows, in order.
      resident: the preallocated buffers to stage into. ``DetectionService``
        passes its own (built once); a standalone call builds a transient
        one sized for this batch — the corpus copy then happens once here
        rather than once per batch.
      index: a committed ``InvertedIndex`` over the corpus rows (DESIGN.md
        §7). When given (and the engine mode consumes indexes), the batch's
        query rows join it through a TRANSIENT ``commit_rows`` — membership
        bits + delta chunks for newly-shared values — which is rolled back
        bit-exact after the pass, even on failure. This replaces the
        per-batch index rebuild the engine would otherwise do.

    Returns one ``DetectResponse`` per request, in request order.
    """
    if engine.mode == "incremental":
        raise ValueError("serve_batch requires a stateless engine mode")
    if not requests:
        return []
    D = base.n_items
    for r in requests:
        if r.values.shape[1] != D:
            raise ValueError(
                f"request {r.rid}: {r.values.shape[1]} items, corpus has {D}")
    S0 = base.n_sources
    n_rows = sum(r.n_rows for r in requests)
    if resident is None:
        resident = ResidentCorpus(base, base_p, max_query_rows=n_rows)
    elif resident.n_corpus != S0 or resident.n_items != D:
        # detection would silently run against the resident's corpus, not
        # ``base``, and the response slices would misalign — fail fast
        raise ValueError(
            f"resident corpus is {resident.n_corpus}×{resident.n_items}, "
            f"base is {S0}×{D}; serve_batch requires the resident to be "
            f"built over the same corpus")
    union, p, copied = resident.stage(requests)

    if index is not None and engine.mode in INDEXED_MODES:
        index.store.ensure_row_capacity(union.n_sources)
        info = commit_rows(index, union, p, engine.cfg,
                           union.n_sources - S0, compact=False)
        # carry the transient commit's delta into the engine's block-OR
        # mask cache so the batch detect updates O(touched) cells instead
        # of regathering all K chunk reductions (DESIGN.md §11)
        token = engine.apply_mask_delta(info.delta)
        try:
            res = engine.detect(union, p, index=index)
        finally:
            # bit-exact unwind — a mid-batch engine failure must never leave
            # the batch's transient rows/deltas in the committed index
            rollback_commit(index, info)
            if token is not None:
                engine.undo_mask_delta(token)
            else:
                # no cache existed before this transient commit — whatever
                # the detect pass adopted is anchored mid-transient; shrink
                # it back onto the restored base so the next batch chains
                engine.rebase_mask_cache(info.delta)
    else:
        res = engine.detect(union, p)

    out = []
    off = S0
    for r in requests:
        rows = slice(off, off + r.n_rows)
        out.append(DetectResponse(
            rid=r.rid,
            copying=res.copying[rows, :S0].copy(),
            pr_independent=res.pr_independent[rows, :S0].copy(),
            c_fwd=res.c_fwd[rows, :S0].copy(),
            intra_copying=res.copying[rows, rows].copy(),
            batch_requests=len(requests),
            batch_rows=n_rows,
            engine_wall_s=res.wall_time_s,
            host_copy_bytes=copied,
        ))
        off += r.n_rows
    return out


#: Queue-wait samples kept for the p50/p99 properties (ring-buffer bound).
_MAX_WAIT_SAMPLES = 4096


@dataclass
class ServiceStats:
    """Counters the service accumulates across batches (read via .stats)."""

    requests: int = 0
    batches: int = 0
    rows: int = 0
    rejected: int = 0             # submits that timed out on backpressure
    host_copy_bytes: int = 0      # total bytes staged into the resident
                                  # buffers (query rows only — the corpus is
                                  # written once, at service construction)
    cache_hits: int = 0           # requests served from the ResultCache
    cache_misses: int = 0         # requests that needed an engine pass
    cache_invalidations: int = 0  # cached entries killed by a commit's
                                  # touched-key overlap (DESIGN.md §7)
    commits: int = 0              # corpus mutations applied
    committed_rows: int = 0       # query rows folded into the corpus
    new_entries: int = 0          # delta entries appended across commits
    reindexed_entries: int = 0    # existing entries re-scored (providers grew)
    delta_chunks: int = 0         # delta chunks appended across commits
    compactions: int = 0          # delta→base folds
    failed_batches: int = 0       # engine passes that raised (DESIGN.md §9)
    failed_requests: int = 0      # requests whose pass raised (not cache hits)
    shed: int = 0                 # admitted-control rejections on arrival:
                                  # the EWMA predicted the deadline can't hold
    expired: int = 0              # queued requests whose deadline passed
                                  # before their batch ran
    retractions: int = 0          # source retractions applied (§9)
    retracted_rows: int = 0       # corpus rows removed by retractions
    gc_entries: int = 0           # entries GC'd (< 2 providers after retract)
    batch_shrinks: int = 0        # adaptive batch-limit halvings
    batch_grows: int = 0          # adaptive batch-limit regrowth steps
    breaker_trips: int = 0        # replica breakers tripped open (router)
    breaker_open: int = 0         # replicas currently open/half-open (router)
    queue_wait_samples: list = dataclasses.field(default_factory=list,
                                                 repr=False)

    def record_wait(self, seconds: float) -> None:
        """Record one request's submit→batch-start queue wait."""
        self.queue_wait_samples.append(float(seconds))
        if len(self.queue_wait_samples) > _MAX_WAIT_SAMPLES:
            del self.queue_wait_samples[: -_MAX_WAIT_SAMPLES]

    @property
    def queue_wait_p50(self) -> float:
        """Median queue wait (seconds) over the recent sample window."""
        s = self.queue_wait_samples
        return float(np.percentile(s, 50)) if s else 0.0

    @property
    def queue_wait_p99(self) -> float:
        """p99 queue wait (seconds) over the recent sample window."""
        s = self.queue_wait_samples
        return float(np.percentile(s, 99)) if s else 0.0

    @property
    def mean_batch(self) -> float:
        """Mean requests per engine pass (1.0 ⇒ batching never kicked in)."""
        return self.requests / self.batches if self.batches else 0.0

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of requests answered without an engine pass."""
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0


def _stat_counter_fields() -> list:
    """The int counter fields of ``ServiceStats`` (snapshot/aggregation
    currency — the wait-sample buffer is runtime-only and is skipped)."""
    return [f for f in dataclasses.fields(ServiceStats) if f.type == "int"]


class ResultCache:
    """Cross-batch response cache with commit-exact invalidation (§7).

    Entries are keyed by request CONTENT (a digest of values/accuracy/
    p_claim — the rid is echoed, not keyed) and stamped with the corpus
    epoch they were computed at. The conceptual key is (source pair, epoch):
    a cached response is the request's row-slice of pair decisions vs the
    corpus. On lookup, the entry is replayed against every commit since its
    epoch: if any commit's ``touched_keys`` (ALL claim keys of its committed
    rows) intersects the request's claim keys, some (query row, corpus
    source) pair may share a touched entry and the cache entry dies;
    otherwise NO pair the response reports can share any value a delta
    created or extended, so its decisions provably equal a fresh pass —
    including vs corpus sources committed later, which are padded in as
    independent (a pair sharing no value can never reach the copying
    threshold for α < .25, and is never *considered*, so the padding's
    False / 1.0 / 0.0 matches the fresh pass bit-for-bit, continuous
    fields included). DESIGN.md §7 carries the full argument.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self._entries: OrderedDict = OrderedDict()

    @staticmethod
    def digest(request: DetectRequest) -> bytes:
        """Content digest of a request (rid excluded — it is echoed back)."""
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(request.values).tobytes())
        h.update(np.ascontiguousarray(request.accuracy).tobytes())
        h.update(np.ascontiguousarray(request.p_claim).tobytes())
        return h.digest()

    def lookup(self, request: DetectRequest, epoch: int, n_corpus: int,
               touched_log: Sequence) -> Optional[DetectResponse]:
        """Serve a request from cache, or None on miss/invalidation.

        ``touched_log`` is the service's [(epoch, touched_keys)] history;
        only commits AFTER the entry's validation epoch are replayed, and a
        surviving entry is re-stamped at ``epoch`` so each commit is tested
        at most once per entry.
        """
        key = self.digest(request)
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            return None
        for e, touched in touched_log:
            if e <= ent["epoch"]:
                continue
            if np.isin(ent["claim_keys"], touched,
                       assume_unique=True).any():
                del self._entries[key]
                self.invalidations += 1
                self.misses += 1
                return None
        s_at = ent["copying"].shape[1]
        if s_at < n_corpus:
            # corpus sources committed since the entry: provably independent
            # of these rows (no shared touched key), pad the columns in
            q = ent["copying"].shape[0]
            grow = n_corpus - s_at
            ent["copying"] = np.concatenate(
                [ent["copying"], np.zeros((q, grow), bool)], axis=1)
            ent["pr_independent"] = np.concatenate(
                [ent["pr_independent"], np.ones((q, grow), np.float32)], axis=1)
            ent["c_fwd"] = np.concatenate(
                [ent["c_fwd"], np.zeros((q, grow), np.float32)], axis=1)
        ent["epoch"] = epoch
        self._entries.move_to_end(key)
        self.hits += 1
        return DetectResponse(
            rid=request.rid,
            copying=ent["copying"].copy(),
            pr_independent=ent["pr_independent"].copy(),
            c_fwd=ent["c_fwd"].copy(),
            intra_copying=ent["intra_copying"].copy(),
            cache_hit=True,
        )

    def oldest_epoch(self, default: int) -> int:
        """The oldest validation epoch any cached entry carries.

        Commits at or before this epoch can never be replayed again (every
        lookup skips them), so the service prunes its touched-key log down
        to this floor. ``default`` is returned for an empty cache.
        """
        if not self._entries:
            return default
        return min(e["epoch"] for e in self._entries.values())

    def put(self, request: DetectRequest, response: DetectResponse,
            epoch: int) -> None:
        """Memoize a freshly computed response at the given epoch (LRU)."""
        key = self.digest(request)
        self._entries[key] = {
            "epoch": epoch,
            "claim_keys": claim_value_keys(request.values),
            "copying": response.copying.copy(),
            "pr_independent": response.pr_independent.copy(),
            "c_fwd": response.c_fwd.copy(),
            "intra_copying": response.intra_copying.copy(),
        }
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def apply_retraction(self, removed_cols: np.ndarray,
                         touched_keys: np.ndarray, n_before: int) -> int:
        """Eagerly reconcile every cached entry with a source retraction.

        The touched-key rule (§7) still decides life or death: an entry
        sharing a claim key with a retracted row may have paired with it —
        it dies. A survivor shares NO key with any retracted row, so its
        pairs against those sources were never copying (False / 1.0 / 0.0);
        its response just loses those columns. Survivors whose matrices
        predate ``n_before`` columns are padded first (the standard
        later-commit padding — if a commit between the entry's epoch and now
        actually touched it, the lookup-time replay will kill it anyway, so
        padding here is harmless). Done eagerly (not at lookup) because the
        retraction renumbers the corpus column axis — lookups after this
        point compare against the POST-retraction corpus. Returns the number
        of entries invalidated.
        """
        removed_cols = np.asarray(removed_cols, np.int64)
        dead = [key for key, ent in self._entries.items()
                if np.isin(ent["claim_keys"], touched_keys,
                           assume_unique=True).any()]
        for key in dead:
            del self._entries[key]
            self.invalidations += 1
        for ent in self._entries.values():
            q, s_at = ent["copying"].shape
            if s_at < n_before:
                grow = n_before - s_at
                ent["copying"] = np.concatenate(
                    [ent["copying"], np.zeros((q, grow), bool)], axis=1)
                ent["pr_independent"] = np.concatenate(
                    [ent["pr_independent"], np.ones((q, grow), np.float32)],
                    axis=1)
                ent["c_fwd"] = np.concatenate(
                    [ent["c_fwd"], np.zeros((q, grow), np.float32)], axis=1)
            for name in ("copying", "pr_independent", "c_fwd"):
                ent[name] = np.delete(ent[name], removed_cols, axis=1)
        return len(dead)

    def clear(self) -> int:
        """Drop every entry (counters survive). Returns the number dropped.

        Used by ``rollback_last_retract``: the eager column surgery of
        ``apply_retraction`` is not invertible entry-by-entry, so unwinding
        a retraction starts the cache cold.
        """
        n = len(self._entries)
        self._entries.clear()
        return n

    def drop_after(self, epoch: int) -> int:
        """Purge entries validated at an epoch later than ``epoch``.

        ``rollback_last_commit`` unwinds the corpus to ``epoch``; entries
        stamped later were validated (or memoized) against corpus state that
        no longer exists, so re-admitting them would skip the invalidation
        replay for the undone commit. Returns the number purged.
        """
        dead = [k for k, e in self._entries.items() if e["epoch"] > epoch]
        for k in dead:
            del self._entries[k]
        return len(dead)

    # -- (de)serialization (durability layer, DESIGN.md §8) ------------------

    def state_dict(self) -> dict:
        """Flat ``{key: ndarray}`` dict of every cached entry, in LRU order.

        Entries ride inside the service snapshot so a restored service wakes
        with a WARM cache: each entry keeps its digest, validation epoch and
        claim keys, which is exactly what the lookup-time invalidation
        replay needs to prove (or refute) that the entry survives the
        commits replayed after the snapshot (DESIGN.md §8.3).
        """
        d = {"cache/meta": np.array([len(self._entries), self.max_entries],
                                    np.int64)}
        for i, (key, ent) in enumerate(self._entries.items()):
            pre = f"cache/{i:05d}/"
            d[pre + "digest"] = np.frombuffer(key, np.uint8)
            d[pre + "epoch"] = np.array([ent["epoch"]], np.int64)
            d[pre + "claim_keys"] = ent["claim_keys"]
            d[pre + "copying"] = ent["copying"]
            d[pre + "pr_independent"] = ent["pr_independent"]
            d[pre + "c_fwd"] = ent["c_fwd"]
            d[pre + "intra_copying"] = ent["intra_copying"]
        return d

    def load_state_dict(self, d: dict) -> None:
        """Re-admit persisted entries (inverse of ``state_dict``)."""
        n = int(np.asarray(d["cache/meta"])[0])
        for i in range(n):
            pre = f"cache/{i:05d}/"
            key = np.asarray(d[pre + "digest"], np.uint8).tobytes()
            self._entries[key] = {
                "epoch": int(np.asarray(d[pre + "epoch"])[0]),
                "claim_keys": np.asarray(d[pre + "claim_keys"], np.int64),
                "copying": np.asarray(d[pre + "copying"], bool),
                "pr_independent": np.asarray(d[pre + "pr_independent"],
                                             np.float32),
                "c_fwd": np.asarray(d[pre + "c_fwd"], np.float32),
                "intra_copying": np.asarray(d[pre + "intra_copying"], bool),
            }
            self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)


class DetectionService:
    """Queue + worker thread that batches requests through one engine.

    Lifecycle::

        svc = DetectionService(corpus, p, cfg, max_batch_requests=8)
        with svc:                       # starts the worker thread
            futs = [svc.submit(r) for r in reqs]   # blocks when queue full
            results = [f.result() for f in futs]

    ``submit`` applies backpressure: once ``max_pending_rows`` query rows are
    waiting, it blocks (up to ``timeout``) until the worker drains the queue,
    then raises ``ServiceOverloaded`` — load sheds at the edge instead of
    accumulating unbounded memory. Without the context manager (or
    ``start()``), ``flush()`` drains the queue synchronously in the caller's
    thread — the deterministic path tests and benchmarks use.
    """

    def __init__(
        self,
        base: ClaimsDataset,
        base_p: np.ndarray,
        cfg: CopyConfig,
        *,
        mode: str = "bucketed",
        max_batch_requests: int = 8,
        max_pending_rows: int = 256,
        result_cache: bool = True,
        cache_entries: int = 256,
        compact_threshold: float = 0.25,
        durability: Optional[DurabilityOptions] = None,
        _index_state: Optional[dict] = None,
        _shared_index: Optional[InvertedIndex] = None,
        **engine_options,
    ):
        """Build the service around a fresh engine.

        max_batch_requests: requests folded into one engine pass (the bench
          sweeps this; ≥ 3× throughput at 8 on the serve benchmark).
        max_pending_rows: backpressure bound on queued query rows.
        result_cache: keep the cross-batch ``ResultCache`` (DESIGN.md §7);
          False disables memoization (every request runs an engine pass).
        cache_entries: LRU capacity of the result cache.
        compact_threshold: delta fraction above which a ``commit`` folds
          delta chunks back into the score-sorted base.
        durability: a ``DurabilityOptions`` to make commits survive the
          process (commit log + snapshots under its state dir, DESIGN.md
          §8); None keeps the service in-memory only.
        _index_state: restore-path internal — a serialized committed index
          (``InvertedIndex.state_dict``) loaded instead of ``build_index``,
          which is the dominant cost restore exists to skip.
        _shared_index: shard-owner internal (DESIGN.md §12) — adopt another
          service's committed index instead of building one. This replica
          NEVER mutates the shared object (the primary's commit path does);
          its own commits apply the claims state and log owner-range-tagged
          WAL records only, so its ``replica-<i>/`` dir restores
          independently.
        engine_options: forwarded to ``EngineOptions`` (tile, devices, ...).
        """
        if mode == "incremental":
            raise ValueError(
                "DetectionService requires a stateless engine mode "
                "(incremental bookkeeping assumes a fixed source axis)")
        self.engine = DetectionEngine(cfg, mode=mode, **engine_options)
        self.max_batch_requests = int(max_batch_requests)
        self.max_pending_rows = int(max_pending_rows)
        self.compact_threshold = float(compact_threshold)
        # ONE resident buffer for the service's lifetime: corpus written
        # here once, every batch stages only its query rows (DESIGN.md §6).
        # base/base_p are then rebound to views of it, so the service holds
        # a single corpus copy (the caller's arrays are theirs to drop).
        self.resident = ResidentCorpus(base, np.asarray(base_p, np.float32),
                                       max_query_rows=self.max_pending_rows)
        self.base = self.resident.corpus_view()
        self.base_p = self.resident.p_claim[: self.resident.n_corpus]
        # committed index (DESIGN.md §7): built ONCE for index-backed modes,
        # then mutated by commit() and reused by every batch through the
        # transient commit/rollback in serve_batch — no per-batch rebuild
        opt = self.engine.options
        self._index: Optional[InvertedIndex] = None
        self._index_shared = _shared_index is not None
        if _shared_index is not None:
            self._index = _shared_index
        elif mode in INDEXED_MODES:
            row_cap = self.resident.n_corpus + self.max_pending_rows
            if _index_state is not None:
                self._index = InvertedIndex.from_state_dict(
                    _index_state, row_capacity=row_cap)
            else:
                self._index = build_index(
                    self.base, self.base_p, cfg,
                    chunk_entries=opt.store_chunk_entries,
                    chunk_bytes=opt.store_chunk_bytes,
                    row_capacity=row_cap)
                if opt.n_shards and opt.n_shards > 1:
                    # row-range-sharded data plane (DESIGN.md §10): the
                    # committed store becomes per-shard row slices; commits,
                    # retractions, snapshots, and the engine's per-shard
                    # scans all flow through the facade. A restored index
                    # re-establishes its persisted plan instead (the
                    # shard_starts key in the state dict).
                    self._index.store = shard_store(
                        self._index.store,
                        make_shard_plan(self._index.store.n_rows,
                                        opt.n_shards))
        self.epoch = 0
        # the cache's exactness argument (§7.5) needs (a) considered-gated
        # decisions — pairwise scores EVERY pair, so disjoint-pair padding
        # would diverge from it; sampled nets shift as the corpus grows —
        # and (b) α < ¼ so no-shared-value pairs stay sub-threshold
        cacheable = mode in INDEXED_MODES and cfg.alpha < 0.25
        self.cache = (ResultCache(cache_entries)
                      if result_cache and cacheable else None)
        self._result_cache_requested = bool(result_cache)
        self._touched_log: list = []     # [(epoch, touched_keys)] per commit
        self.stats = ServiceStats()
        self._pending: deque = deque()   # (request, future, t_submit, t_ddl)
        self._pending_rows = 0
        self._cv = threading.Condition()
        self._corpus_lock = threading.Lock()   # serializes batches & commits
        self._worker: Optional[threading.Thread] = None
        self._stopping = False
        # traffic hardening (DESIGN.md §9): injectable clock (fault tests
        # skew it), EWMA of recent batch latency (admission control), and
        # the adaptive batch limit in [1, max_batch_requests]
        self._clock = time.monotonic
        self._ewma_batch_s = 0.0         # 0 = no estimate yet
        self._batch_limit = self.max_batch_requests
        self._ok_streak = 0              # deadline-clean batches in a row
        # durability state (all None/empty for an in-memory service)
        self.durability: Optional[DurabilityOptions] = None
        self.restore_info: Optional[RestoreInfo] = None
        self._log: Optional[CommitLog] = None
        self._last_commit: Optional[dict] = None   # rollback receipt
        self._last_retract: Optional[dict] = None  # rollback receipt (§9)
        if durability is not None:
            self._attach_durability(durability)

    # -- submission ---------------------------------------------------------

    def _admission_wait_estimate(self) -> float:
        """Predicted submit→result latency for a request arriving NOW.

        Queue depth in batches (at the current adaptive batch limit) times
        the EWMA of recent batch latency, plus one more batch for the
        request's own pass. 0.0 while no batch has completed yet (no
        estimate — admission control stands down rather than shed blind).
        """
        if self._ewma_batch_s <= 0.0:
            return 0.0
        batches_ahead = -(-len(self._pending) // max(self._batch_limit, 1))
        return (batches_ahead + 1) * self._ewma_batch_s

    def submit(self, request: DetectRequest,
               timeout: Optional[float] = 30.0) -> Future:
        """Enqueue a request; returns a Future resolving to DetectResponse.

        Blocks while the pending-row budget is full (backpressure); raises
        ``ServiceOverloaded`` if it stays full past ``timeout`` seconds,
        ``ValueError`` for a request that could never fit the budget, and —
        for a request carrying ``deadline_s`` — ``DeadlineExceeded`` ON
        ARRIVAL when the EWMA of recent batch latency predicts the deadline
        cannot hold (admission control: the engine pass is never wasted on
        a request that would miss anyway, DESIGN.md §9).
        """
        if request.n_rows > self.max_pending_rows:
            raise ValueError(
                f"request {request.rid}: {request.n_rows} rows exceeds "
                f"max_pending_rows={self.max_pending_rows}")
        deadline = None if timeout is None else self._clock() + timeout
        with self._cv:
            if self._stopping:
                # after the worker's final drain a queued entry would never
                # resolve — refuse instead of stranding the future
                raise ServiceStopped("service is stopping; submit rejected")
            if request.deadline_s is not None:
                est = self._admission_wait_estimate()
                if est > request.deadline_s:
                    self.stats.shed += 1
                    raise DeadlineExceeded(
                        f"request {request.rid}: predicted wait "
                        f"{est:.3f}s exceeds deadline "
                        f"{request.deadline_s:.3f}s — shed on arrival")
            while self._pending_rows + request.n_rows > self.max_pending_rows:
                wait = (None if deadline is None
                        else deadline - self._clock())
                if wait is not None and wait <= 0:
                    self.stats.rejected += 1
                    raise ServiceOverloaded(
                        f"queue full ({self._pending_rows} rows pending)")
                self._cv.wait(wait)
                if self._stopping:
                    # stop() drained the queue while we waited — enqueueing
                    # now would strand the future past the worker's exit
                    raise ServiceStopped(
                        "service is stopping; submit rejected")
            fut: Future = Future()
            now = self._clock()
            t_ddl = (None if request.deadline_s is None
                     else now + request.deadline_s)
            self._pending.append((request, fut, now, t_ddl))
            self._pending_rows += request.n_rows
            self._cv.notify_all()
        return fut

    # -- draining -----------------------------------------------------------

    def _take_batch(self) -> list:
        """Pop up to ``_batch_limit`` pending entries (caller holds _cv).

        The limit is the ADAPTIVE bound — ``max_batch_requests`` shrunk
        while deadline misses accumulate, regrown when headroom returns
        (DESIGN.md §9) — so an overloaded service trades batching
        efficiency for per-batch latency exactly when latency is what
        deadlines are missing on.
        """
        batch = []
        while self._pending and len(batch) < self._batch_limit:
            entry = self._pending.popleft()
            self._pending_rows -= entry[0].n_rows
            batch.append(entry)
        if batch:
            self._cv.notify_all()        # wake blocked submitters
        return batch

    @staticmethod
    def _resolve(fut: Future, *, result=None, exc=None) -> None:
        """Resolve a future, tolerating client-side cancellation — a
        cancelled future must never take down the worker thread."""
        if not fut.set_running_or_notify_cancel():
            return                                   # client cancelled it
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)

    def _expire_stale(self, batch: list) -> list:
        """Shed queued entries whose deadline already passed (DESIGN.md §9).

        Runs at batch start, BEFORE the engine pass: a request that cannot
        possibly be answered in time must not ride the pass (it would only
        slow every co-batched request down). Resolves the stale futures
        with ``DeadlineExceeded`` and returns the live remainder.
        """
        now = self._clock()
        live = []
        for entry in batch:
            request, fut, _, t_ddl = entry
            if t_ddl is not None and now >= t_ddl:
                self.stats.expired += 1
                self._resolve(fut, exc=DeadlineExceeded(
                    f"request {request.rid}: deadline passed while queued"))
            else:
                live.append(entry)
        return live

    def _adapt_batch_limit(self, missed: int) -> None:
        """Shrink/regrow the adaptive batch limit from deadline outcomes.

        Any miss halves the limit (a smaller batch is faster, so queued
        deadlines get a fighting chance); a streak of clean batches regrows
        it one step at a time back toward ``max_batch_requests`` — the
        classic multiplicative-decrease / additive-increase shape.
        """
        if missed:
            self._ok_streak = 0
            if self._batch_limit > 1:
                self._batch_limit = max(1, self._batch_limit // 2)
                self.stats.batch_shrinks += 1
        else:
            self._ok_streak += 1
            if (self._ok_streak >= 4
                    and self._batch_limit < self.max_batch_requests):
                self._batch_limit += 1
                self.stats.batch_grows += 1
                self._ok_streak = 0

    def _run_batch(self, batch: list) -> None:
        """One batch: shed stale deadlines, cache lookups, ONE serve_batch
        for the misses, resolve.

        Runs under ``_corpus_lock`` so commits never interleave with a
        batch's cache-validate → detect → memoize sequence (the cache entry
        epoch must match the corpus the engine saw). Every completed batch
        feeds the latency EWMA (admission control) and the adaptive batch
        limit; a batch that raises feeds the ``failed_batches`` /
        ``failed_requests`` counters instead of vanishing from the stats.
        """
        t_start = self._clock()
        batch = self._expire_stale(batch)
        if not batch:
            return
        for _, _, t_sub, _ in batch:
            self.stats.record_wait(t_start - t_sub)
        with self._corpus_lock:
            reqs = [entry[0] for entry in batch]
            responses: list = [None] * len(batch)
            miss_idx = list(range(len(batch)))
            if self.cache is not None:
                miss_idx = []
                inv0 = self.cache.invalidations
                for i, r in enumerate(reqs):
                    hit = self.cache.lookup(r, self.epoch,
                                            self.resident.n_corpus,
                                            self._touched_log)
                    if hit is None:
                        miss_idx.append(i)
                    else:
                        hit.batch_requests = len(batch)
                        hit.batch_rows = sum(q.n_rows for q in reqs)
                        responses[i] = hit
                self.stats.cache_hits += len(batch) - len(miss_idx)
                self.stats.cache_misses += len(miss_idx)
                # accumulate the delta so the counter survives the
                # stats-reset pattern the benchmarks use
                self.stats.cache_invalidations += \
                    self.cache.invalidations - inv0
            try:
                fresh = (serve_batch(self.base, self.base_p, self.engine,
                                     [reqs[i] for i in miss_idx],
                                     resident=self.resident,
                                     index=self._index)
                         if miss_idx else [])
            except Exception as exc:                  # noqa: BLE001
                # cache hits already have their exact responses in hand —
                # only the futures waiting on the failed engine pass fail
                done = self._clock()
                n_failed = 0
                for i, (_, fut, t_sub, _) in enumerate(batch):
                    if responses[i] is None:
                        n_failed += 1
                        self._resolve(fut, exc=exc)
                    else:
                        responses[i].latency_s = done - t_sub
                        self._resolve(fut, result=responses[i])
                self.stats.failed_batches += 1
                self.stats.failed_requests += n_failed
                return
            for i, resp in zip(miss_idx, fresh):
                responses[i] = resp
                if self.cache is not None:
                    self.cache.put(reqs[i], resp, self.epoch)
        done = self._clock()
        missed = 0
        for (request, fut, t_sub, t_ddl), resp in zip(batch, responses):
            resp.latency_s = done - t_sub
            if t_ddl is not None and done > t_ddl:
                missed += 1
            self._resolve(fut, result=resp)
        self.stats.requests += len(batch)
        self.stats.batches += 1
        self.stats.rows += sum(r.n_rows for r in reqs)
        self.stats.host_copy_bytes += fresh[0].host_copy_bytes if fresh else 0
        # EWMA of batch latency — what admission control predicts waits with
        dt = done - t_start
        self._ewma_batch_s = (dt if self._ewma_batch_s <= 0.0
                              else 0.7 * self._ewma_batch_s + 0.3 * dt)
        self._adapt_batch_limit(missed)

    # -- corpus mutation (DESIGN.md §7) --------------------------------------

    def commit(self, values: np.ndarray, accuracy: np.ndarray,
               p_claim: np.ndarray, *, compact: bool = True,
               _owner_range=None):
        """Fold accepted query rows into the corpus, permanently.

        Appends the rows to the resident buffers, advances the committed
        index through ``index.commit_rows`` (membership bits, delta chunks,
        refreshed scores, Ē mask — optionally compacting once deltas exceed
        ``compact_threshold``), bumps the corpus epoch, and records the
        commit's touched claim keys for the cache's exact invalidation.
        Serialized against in-flight batches by ``_corpus_lock`` — reads
        keep flowing between commits, writes never interleave with a pass.

        On a durable service the commit is also appended to the commit log
        (fsync'd per ``DurabilityOptions.fsync`` — the durability point is
        this method returning) and a full snapshot is written every
        ``snapshot_every`` commits.

        Returns the ``CommitInfo`` receipt (None for index-less modes).
        """
        with self._corpus_lock:
            return self._commit_locked(values, accuracy, p_claim,
                                       compact=compact,
                                       owner_range=_owner_range)

    def _commit_locked(self, values: np.ndarray, accuracy: np.ndarray,
                       p_claim: np.ndarray, *, compact: bool = True,
                       log: bool = True, owner_range=None):
        """Apply one commit; caller holds ``_corpus_lock``.

        ``log=False`` is the replay path (``restore``): the commit being
        applied already IS a log record, so appending it again would double
        it. Everything else — index mutation, epoch, touched-key log, stats
        — is identical, which is what makes replay reproduce the live
        commit bit-for-bit (DESIGN.md §8.2).

        On a shared-index replica (``_shared_index``) the committed index
        belongs to the primary and is mutated exactly once — there; this
        replica applies the claims state, bumps its epoch, and logs the
        record (tagged with ``owner_range`` when the router routed it).
        """
        values = np.asarray(values, np.int32)
        accuracy = np.asarray(accuracy, np.float32)
        p_claim = np.asarray(p_claim, np.float32)
        if values.shape[1] != self.resident.n_items:
            raise ValueError(
                f"commit: {values.shape[1]} items, corpus has "
                f"{self.resident.n_items}")
        q = values.shape[0]
        n_before = self.resident.n_corpus
        touched = claim_value_keys(values)
        self.resident.commit_rows(values, accuracy, p_claim)
        # growth may have reallocated — rebind the corpus views
        self.base = self.resident.corpus_view()
        self.base_p = self.resident.p_claim[: self.resident.n_corpus]
        info = None
        if self._index is not None and not self._index_shared:
            self._index.store.ensure_row_capacity(
                self.resident.n_corpus + self.max_pending_rows)
            info = commit_rows(
                self._index, self.base, self.base_p, self.engine.cfg, q,
                compact=compact,
                compact_threshold=self.compact_threshold)
            self.stats.new_entries += info.new_entries
            self.stats.reindexed_entries += info.touched_entries
            self.stats.delta_chunks += info.delta_chunks_added
            self.stats.compactions += int(info.compacted)
            # permanent commit: fold the changed cells into the engine's
            # block-OR mask cache so the next detect skips the full
            # regather (router broadcasts run this per replica)
            self.engine.apply_mask_delta(info.delta)
        self.epoch += 1
        if self.cache is not None:
            self._touched_log.append((self.epoch, touched))
            # log entries no surviving cache entry predates are dead
            # (lookups skip commits ≤ the entry's validation epoch) —
            # prune them so a long-lived service stays O(live entries)
            floor = self.cache.oldest_epoch(self.epoch)
            self._touched_log = [t for t in self._touched_log
                                 if t[0] > floor]
        self.stats.commits += 1
        self.stats.committed_rows += q
        snap_path = None
        if self._log is not None and log:
            lo, hi = owner_range if owner_range is not None else (-1, -1)
            self._log.append(CommitRecord(
                epoch=self.epoch, values=values, accuracy=accuracy,
                p_claim=p_claim, touched_keys=touched, compact=compact,
                compacted=bool(info.compacted) if info is not None else False,
                owner_lo=int(lo), owner_hi=int(hi)))
            every = self.durability.snapshot_every
            if every and self.epoch % every == 0:
                snap_path = self._write_snapshot_locked()
        # rollback receipt for rollback_last_commit (LIFO, router recovery)
        self._last_commit = {"info": info, "rows": q, "n_before": n_before,
                             "epoch": self.epoch, "touched": touched,
                             "logged": self._log is not None and log,
                             "snapshot": snap_path}
        self._last_retract = None    # LIFO: only the newest mutation unwinds
        return info

    def rollback_last_commit(self) -> None:
        """Undo the LAST ``commit()``, bit-exact (LIFO only).

        The recovery half of ``ReplicaRouter.commit``'s broadcast protocol:
        when a later replica fails mid-broadcast, each replica that already
        applied the commit unwinds it — index (``rollback_commit``),
        resident rows (``truncate_corpus``), epoch, touched-key log, stats,
        cache entries stamped at the undone epoch, the commit's log record,
        and any snapshot the commit triggered. Raises ``RuntimeError`` when
        there is no commit to unwind (or it was already unwound).
        """
        with self._corpus_lock:
            last = self._last_commit
            if last is None:
                raise RuntimeError("no commit to roll back")
            if last["epoch"] != self.epoch:
                raise RuntimeError(
                    f"rollback_last_commit: last receipt is epoch "
                    f"{last['epoch']}, service is at {self.epoch} — only the "
                    f"immediately-preceding commit can be unwound")
            info = last["info"]
            if info is not None:
                rollback_commit(self._index, info)
                # the mask cache's delta chain is broken by the unwind —
                # drop it; the next indexed detect rebuilds it fresh
                self.engine.invalidate_mask_cache()
                self.stats.new_entries -= info.new_entries
                self.stats.reindexed_entries -= info.touched_entries
                self.stats.delta_chunks -= info.delta_chunks_added
                self.stats.compactions -= int(info.compacted)
            self.resident.truncate_corpus(last["n_before"])
            self.base = self.resident.corpus_view()
            self.base_p = self.resident.p_claim[: self.resident.n_corpus]
            self.epoch -= 1
            self._touched_log = [t for t in self._touched_log
                                 if t[0] <= self.epoch]
            if self.cache is not None:
                # entries memoized/re-validated while the commit was live
                # assumed its corpus — they must not survive the unwind
                self.cache.drop_after(self.epoch)
            self.stats.commits -= 1
            self.stats.committed_rows -= last["rows"]
            if last["logged"] and self._log is not None:
                self._log.rollback_last()
            if last["snapshot"] is not None:
                try:
                    os.remove(last["snapshot"])
                except OSError:
                    pass
            self._last_commit = None

    # -- source retraction (DESIGN.md §9) ------------------------------------

    def retract(self, row_ids, *, _owner_range=None):
        """Remove committed corpus sources, permanently (DESIGN.md §9).

        ``row_ids`` index the CURRENT corpus rows to drop (a takedown, a
        poisoned crawl, a revoked source). The retraction compacts the
        resident corpus, unwinds the rows' membership bits in the committed
        index, GCs entries left below two providers (no longer *shared*
        values), re-scores surviving touched entries, re-derives the Ē
        boundary, eagerly reconciles the result cache (entries sharing a
        claim key with a retracted row die; survivors lose the columns),
        bumps the epoch, and — on a durable service — appends a
        ``RetractRecord`` to the commit log before returning, replayed on
        ``restore`` exactly like commits. Post-state decisions equal a
        service rebuilt without the retracted sources, for every mode
        (asserted by tests/test_retraction.py across all nine).

        Returns the ``RetractInfo`` receipt (None for index-less modes).
        """
        with self._corpus_lock:
            return self._retract_locked(row_ids, log=True,
                                        owner_range=_owner_range)

    def _retract_locked(self, row_ids, *, log: bool = True,
                        owner_range=None):
        """Apply one retraction; caller holds ``_corpus_lock``.

        ``log=False`` is the replay path (``restore``), mirroring
        ``_commit_locked`` — the retraction being applied already IS a log
        record.
        """
        row_ids = np.unique(np.asarray(row_ids, np.int64).ravel())
        n_before = self.resident.n_corpus
        if row_ids.size == 0:
            raise ValueError("retract: no rows given")
        if row_ids[0] < 0 or row_ids[-1] >= n_before:
            raise ValueError(
                f"retract: row ids must be in [0, {n_before}), got "
                f"[{row_ids[0]}, {row_ids[-1]}]")
        # save the rows before they vanish — the rollback receipt restores
        # them bit-exact, and their claim keys drive cache invalidation
        saved_values = self.resident.values[row_ids].copy()
        saved_accuracy = self.resident.accuracy[row_ids].copy()
        saved_p = self.resident.p_claim[row_ids].copy()
        touched = claim_value_keys(saved_values)
        self.resident.retract_rows(row_ids)
        self.base = self.resident.corpus_view()
        self.base_p = self.resident.p_claim[: self.resident.n_corpus]
        info = None
        if self._index is not None and not self._index_shared:
            info = index_retract_rows(self._index, self.base,
                                      self.engine.cfg, row_ids)
            self.stats.gc_entries += info.gc_entries
            # incremental mask-cache maintenance: recompute only the block
            # rows the compaction shifted, zero the GC'd columns
            self.engine.apply_mask_delta(info.delta)
        self.epoch += 1
        if self.cache is not None:
            # eager reconciliation, NOT a touched-log entry: the retraction
            # renumbers the corpus column axis, so lookup-time replay could
            # never re-align a surviving entry after the fact
            self.stats.cache_invalidations += self.cache.apply_retraction(
                row_ids, touched, n_before)
        self.stats.retractions += 1
        self.stats.retracted_rows += int(row_ids.size)
        snap_path = None
        if self._log is not None and log:
            lo, hi = owner_range if owner_range is not None else (-1, -1)
            self._log.append(RetractRecord(
                epoch=self.epoch, row_ids=row_ids, touched_keys=touched,
                n_before=n_before, owner_lo=int(lo), owner_hi=int(hi)))
            every = self.durability.snapshot_every
            if every and self.epoch % every == 0:
                snap_path = self._write_snapshot_locked()
        self._last_retract = {
            "info": info, "row_ids": row_ids, "n_before": n_before,
            "epoch": self.epoch, "values": saved_values,
            "accuracy": saved_accuracy, "p_claim": saved_p,
            "logged": self._log is not None and log, "snapshot": snap_path}
        self._last_commit = None     # LIFO: only the newest mutation unwinds
        return info

    def rollback_last_retract(self) -> None:
        """Undo the LAST ``retract()``, bit-exact (LIFO only).

        The recovery half of ``ReplicaRouter``'s broadcast protocol for
        retractions: restores the retracted rows at their original indices
        (``ResidentCorpus.unretract``), unwinds the index through the same
        snapshot receipt ``rollback_commit`` uses for commits, drops the
        epoch, the retraction's log record and any snapshot it triggered.
        The result cache restarts cold — ``apply_retraction``'s column
        surgery is not invertible entry-by-entry.
        """
        with self._corpus_lock:
            last = self._last_retract
            if last is None:
                raise RuntimeError("no retraction to roll back")
            if last["epoch"] != self.epoch:
                raise RuntimeError(
                    f"rollback_last_retract: last receipt is epoch "
                    f"{last['epoch']}, service is at {self.epoch} — only the "
                    f"immediately-preceding retraction can be unwound")
            info = last["info"]
            if info is not None:
                rollback_commit(self._index, info)
                # retraction applies are not invertible cell-by-cell —
                # drop the cache and let the next detect rebuild it
                self.engine.invalidate_mask_cache()
                self.stats.gc_entries -= info.gc_entries
            self.resident.unretract(last["row_ids"], last["values"],
                                    last["accuracy"], last["p_claim"])
            self.base = self.resident.corpus_view()
            self.base_p = self.resident.p_claim[: self.resident.n_corpus]
            self.epoch -= 1
            if self.cache is not None:
                self.cache.clear()
            self.stats.retractions -= 1
            self.stats.retracted_rows -= int(last["row_ids"].size)
            if last["logged"] and self._log is not None:
                self._log.rollback_last()
            if last["snapshot"] is not None:
                try:
                    os.remove(last["snapshot"])
                except OSError:
                    pass
            self._last_retract = None

    # -- durability (commit log + snapshots, DESIGN.md §8) -------------------

    def _attach_durability(self, opts: DurabilityOptions) -> None:
        """Wire this service to a state dir (called from ``__init__``).

        Creates the dir, writes the manifest when absent (the config needed
        to reconstruct the service at restore time), truncates any torn log
        tail, opens the log for appending, and — when the dir holds no
        snapshot yet — writes the initial one, so a restore never needs the
        original corpus arrays.
        """
        os.makedirs(opts.state_dir, exist_ok=True)
        self.durability = opts
        if not os.path.exists(os.path.join(opts.state_dir, MANIFEST_NAME)):
            write_manifest(opts.state_dir, self._manifest())
        log_path = os.path.join(opts.state_dir, LOG_NAME)
        CommitLog.recover(log_path)
        self._log = CommitLog(log_path, fsync=opts.fsync)
        if not list_snapshots(opts.state_dir):
            with self._corpus_lock:
                self._write_snapshot_locked()

    def _manifest(self) -> dict:
        """The JSON-serializable config a restore needs to rebuild ``self``."""
        return {
            "cfg": dataclasses.asdict(self.engine.cfg),
            "service": {
                "mode": self.engine.mode,
                "max_batch_requests": self.max_batch_requests,
                "max_pending_rows": self.max_pending_rows,
                "result_cache": self._result_cache_requested,
                "cache_entries": (self.cache.max_entries
                                  if self.cache is not None else 256),
                "compact_threshold": self.compact_threshold,
            },
            "engine_options": dataclasses.asdict(self.engine.options),
            "durability": {
                "snapshot_every": self.durability.snapshot_every,
                "fsync": self.durability.fsync,
                "retention": self.durability.retention,
            },
        }

    def _write_snapshot_locked(self) -> str:
        """Serialize full service state as the current epoch's snapshot.

        Caller holds ``_corpus_lock``. Captures the resident corpus rows,
        the committed index (``InvertedIndex.state_dict`` — the base+delta
        layout exactly as commits left it), the stats counters, the
        touched-key log, and the result-cache entries. Returns the path.
        """
        n = self.resident.n_corpus
        # a shared index belongs to the primary replica — it snapshots it;
        # this replica's snapshot carries only the claims state
        own_index = self._index is not None and not self._index_shared
        arrays = {
            "service/meta": np.array(
                [self.epoch, n, int(own_index),
                 int(self.cache is not None)], np.int64),
            "service/values": self.resident.values[:n],
            "service/accuracy": self.resident.accuracy[:n],
            "service/p_claim": self.resident.p_claim[:n],
            "service/stats": np.array(
                [getattr(self.stats, f.name)
                 for f in _stat_counter_fields()], np.int64),
            "service/touched_epochs": np.array(
                [e for e, _ in self._touched_log], np.int64),
            "service/touched_offsets": np.cumsum(
                [0] + [len(k) for _, k in self._touched_log]).astype(np.int64),
            "service/touched_keys": (
                np.concatenate([k for _, k in self._touched_log])
                if self._touched_log else np.zeros(0, np.int64)),
        }
        if own_index:
            arrays.update(self._index.state_dict())
        if self.cache is not None:
            arrays.update(self.cache.state_dict())
        return write_snapshot(self.durability.state_dir, self.epoch, arrays,
                              retention=self.durability.retention)

    @classmethod
    def restore(cls, state_dir: str, **overrides) -> "DetectionService":
        """Resurrect a durable service from its state dir.

        Reads the manifest, loads the newest snapshot that validates
        (corrupt ones are skipped), truncates the commit log's torn tail,
        replays the records past the snapshot epoch through the exact
        in-memory commit path, and reopens the log for appending — the
        returned service continues the SAME state dir. The warm cache's
        entries keep their pre-crash epochs, so the standard lookup-time
        invalidation replays them against whatever the log tail committed
        (DESIGN.md §8.3). ``overrides`` patch manifest config (e.g.
        ``devices=8`` for a different host shape — engine knobs only;
        overriding corpus-shaping config would diverge from the log).

        Raises ``NoValidSnapshotError`` when nothing loads and
        ``ReplayDivergenceError`` when a replayed commit does not land on
        the epoch/compaction outcome its record logged. The receipt is left
        on ``service.restore_info``.
        """
        t0 = time.perf_counter()
        manifest = read_manifest(state_dir)
        epoch_s, snap_file, arrays, skipped = latest_valid_snapshot(state_dir)
        t_load = time.perf_counter() - t0
        rec = CommitLog.recover(os.path.join(state_dir, LOG_NAME))

        meta = np.asarray(arrays["service/meta"], np.int64)
        snap_epoch, n_corpus, has_index, has_cache = (int(x) for x in meta[:4])
        base = ClaimsDataset(
            values=np.asarray(arrays["service/values"], np.int32),
            accuracy=np.asarray(arrays["service/accuracy"], np.float32))
        base_p = np.asarray(arrays["service/p_claim"], np.float32)

        kw = dict(manifest["service"])
        kw.update(manifest["engine_options"])
        dur = dict(manifest["durability"])
        for k, v in overrides.items():
            (dur if k in dur else kw)[k] = v
        cfg = CopyConfig(**manifest["cfg"])
        svc = cls(base, base_p, cfg,
                  _index_state=arrays if has_index else None, **kw)

        # snapshot-time dynamic state: epoch, stats, touched log, warm cache
        svc.epoch = snap_epoch
        # zip tolerates snapshots from older builds with fewer counters
        for f, v in zip(_stat_counter_fields(),
                        np.asarray(arrays["service/stats"], np.int64)):
            setattr(svc.stats, f.name, int(v))
        epochs = np.asarray(arrays["service/touched_epochs"], np.int64)
        offs = np.asarray(arrays["service/touched_offsets"], np.int64)
        keys = np.asarray(arrays["service/touched_keys"], np.int64)
        svc._touched_log = [(int(e), keys[offs[i]: offs[i + 1]])
                            for i, e in enumerate(epochs)]
        if has_cache and svc.cache is not None:
            svc.cache.load_state_dict(arrays)

        # replay the log tail: records past the snapshot epoch, in order,
        # through the exact live-commit path (no re-logging)
        t1 = time.perf_counter()
        replayed = 0
        records, _, _ = CommitLog.scan(os.path.join(state_dir, LOG_NAME))
        for record in records:
            if record.epoch <= svc.epoch:
                continue
            if record.epoch != svc.epoch + 1:
                raise ReplayDivergenceError(
                    f"log record for epoch {record.epoch} follows service "
                    f"epoch {svc.epoch} — a record is missing")
            if isinstance(record, RetractRecord):
                if record.n_before != svc.resident.n_corpus:
                    raise ReplayDivergenceError(
                        f"retraction record at epoch {record.epoch} was "
                        f"logged against {record.n_before} corpus rows, "
                        f"replay reached it with {svc.resident.n_corpus}")
                with svc._corpus_lock:
                    svc._retract_locked(record.row_ids, log=False)
                if svc.epoch != record.epoch:
                    raise ReplayDivergenceError(
                        f"replaying retraction for epoch {record.epoch} "
                        f"landed on epoch {svc.epoch}")
                replayed += 1
                continue
            with svc._corpus_lock:
                info = svc._commit_locked(
                    record.values, record.accuracy, record.p_claim,
                    compact=record.compact, log=False)
            if svc.epoch != record.epoch or (
                    info is not None
                    and bool(info.compacted) != record.compacted):
                raise ReplayDivergenceError(
                    f"replaying epoch {record.epoch} landed on epoch "
                    f"{svc.epoch} (compacted="
                    f"{None if info is None else info.compacted}, record "
                    f"said {record.compacted})")
            replayed += 1
        t_replay = time.perf_counter() - t1
        # the last replayed mutation's rollback receipt is unusable: its log
        # record predates this process (rollback could not unwind it there)
        svc._last_commit = None
        svc._last_retract = None

        svc._attach_durability(DurabilityOptions(state_dir=state_dir, **dur))
        svc.restore_info = RestoreInfo(
            snapshot_epoch=snap_epoch, snapshot_path=snap_file,
            replayed_commits=replayed, discarded_bytes=rec.discarded_bytes,
            skipped_snapshots=skipped, snapshot_load_s=t_load,
            replay_s=t_replay, wall_s=time.perf_counter() - t0)
        return svc

    def flush(self) -> int:
        """Synchronously drain the queue in the caller's thread.

        Returns the number of requests served. Only valid when no worker
        thread is running (deterministic tests / benchmarks) — the engine is
        stateful per pass, so two threads must never drive it concurrently."""
        if self._worker is not None and self._worker.is_alive():
            raise RuntimeError(
                "flush() while the worker thread is running would drive the "
                "engine from two threads; use the futures instead")
        served = 0
        while True:
            with self._cv:
                batch = self._take_batch()
            if not batch:
                return served
            self._run_batch(batch)
            served += len(batch)

    # -- worker lifecycle ---------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stopping:
                    self._cv.wait()
                if self._stopping and not self._pending:
                    return
                batch = self._take_batch()
            if batch:
                self._run_batch(batch)

    def start(self) -> "DetectionService":
        """Start the background worker (idempotent)."""
        if self._worker is None or not self._worker.is_alive():
            self._stopping = False
            self._worker = threading.Thread(
                target=self._worker_loop, name="detection-service", daemon=True)
            self._worker.start()
        return self

    def stop(self) -> None:
        """Drain remaining requests, then join the worker."""
        if self._worker is None:
            self.flush()
            return
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._worker.join()
        self._worker = None
        with self._cv:
            # back to idle under the lock, so a submitter that raced the
            # shutdown either saw _stopping (and raised) or lands in the
            # defined idle state: enqueued for a later flush()/start()
            self._stopping = False

    def __enter__(self) -> "DetectionService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class CircuitBreaker:
    """Classic closed → open → half-open breaker around one replica (§9).

    ``record_failure`` counts CONSECUTIVE failures; at ``failure_threshold``
    the breaker trips open and ``allow()`` refuses the protected operation
    until ``cooldown_s`` elapses, after which ONE probe is admitted
    (half-open). A half-open failure re-opens immediately (and restarts the
    cooldown); a success closes the breaker and resets the count. The clock
    is injectable so fault tests can drive the cooldown deterministically.
    """

    def __init__(self, failure_threshold: int = 5, cooldown_s: float = 5.0,
                 clock=time.monotonic):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be ≥ 1, got {failure_threshold}")
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.state = "closed"            # "closed" | "open" | "half-open"
        self.failures = 0                # consecutive, resets on success
        self.trips = 0                   # lifetime closed/half-open → open
        self._opened_at = 0.0

    def allow(self) -> bool:
        """May the protected operation be attempted right now?

        Closed: yes. Open: no until the cooldown elapses, then the breaker
        moves to half-open and admits the probe. Half-open: yes (the probe).
        """
        if self.state == "open":
            if self._clock() - self._opened_at < self.cooldown_s:
                return False
            self.state = "half-open"
        return True

    def record_success(self) -> None:
        """The protected operation succeeded — close and reset the count."""
        self.failures = 0
        self.state = "closed"

    def record_failure(self) -> None:
        """The protected operation failed — count it, trip at threshold.

        A half-open failure trips regardless of the count: the probe just
        proved the replica is still unhealthy.
        """
        self.failures += 1
        if (self.state == "half-open"
                or self.failures >= self.failure_threshold):
            self.trips += 1
            self.state = "open"
            self._opened_at = self._clock()


class ReplicaBroadcastError(RuntimeError):
    """A write broadcast failed on one replica and was rolled back.

    Raised by ``ReplicaRouter.commit``/``retract`` after every replica that
    had already applied the write unwound it (LIFO) — the fleet is back at
    the pre-write epoch, consistent. ``replica`` is the index of the service
    that raised (-1 when no replica could accept the write at all);
    ``__cause__`` carries its exception.
    """

    def __init__(self, replica: int, cause: Optional[BaseException] = None):
        if cause is not None:
            msg = (f"commit broadcast failed on replica {replica}: "
                   f"{cause!r}; preceding replicas rolled back")
        else:
            msg = ("broadcast rejected: every replica's circuit breaker "
                   "is open — no replica applied the write")
        super().__init__(msg)
        self.replica = replica


class ReplicaRouter:
    """Fan requests across N ``DetectionService`` replicas (DESIGN.md §7).

    Reads scale: ``submit`` round-robins over the replicas, each with its
    own engine, resident corpus, committed index, and result cache, so
    independent batches run concurrently. Writes stay serialized:
    ``commit`` holds the router's write lock while broadcasting the same
    rows to EVERY replica in order — each replica's own ``_corpus_lock``
    fences the commit against its in-flight batches, and because every
    replica applies the identical commit sequence, their corpus epochs stay
    equal (asserted after each broadcast — the epoch protocol §7 documents).
    A read routed to any replica therefore sees some prefix of the commit
    history, and the responses it returns are exactly the decisions of that
    epoch's corpus — never a torn mix of two epochs.

    Failure handling is two-tier (DESIGN.md §9). A replica that raises
    mid-broadcast *below* its breaker's failure threshold triggers LIFO
    rollback of the replicas that already applied (bit-exact), so the
    failed write leaves the fleet at the pre-write epoch instead of
    split-brained; the caller sees one ``ReplicaBroadcastError``. A replica
    that keeps failing trips its per-replica ``CircuitBreaker`` and is
    EJECTED instead: the fleet keeps committing without it, its missed
    writes queue in a per-replica backlog, reads route around it, and after
    the breaker cooldown one probe write replays the backlog (catch-up) —
    on success the replica rejoins with epoch equality, asserted by the
    post-broadcast check over in-sync replicas.
    """

    def __init__(self, base: ClaimsDataset, base_p: np.ndarray,
                 cfg: CopyConfig, *, n_replicas: int = 2,
                 breaker_threshold: int = 5, breaker_cooldown_s: float = 5.0,
                 shard_owners: Optional[int] = None,
                 breaker_clock=time.monotonic,
                 **service_kw):
        """Build ``n_replicas`` identical services over one corpus.

        A ``durability=DurabilityOptions(...)`` in ``service_kw`` is split
        into per-replica ``replica-<i>/`` subdirectories of its state dir —
        replicas must never interleave records in one commit log.
        ``breaker_threshold`` consecutive write failures eject a replica
        (circuit opens); ``breaker_cooldown_s`` later it is probed for
        recovery. ``breaker_clock`` is the breakers' time source (fault
        tests inject a fake one to drive the cooldown deterministically).

        ``shard_owners=n`` switches the fleet to SHARD-OWNER mode
        (DESIGN.md §12): replica count becomes ``n`` and each replica owns
        one row range of a single shared row-range-sharded index instead of
        a full corpus copy. Replica 0 (the primary) builds the index with
        ``n_shards=n``; replicas 1.. adopt it (``_shared_index``) and hold
        only the claims state + their own WAL. Reads in a tiled fan-out
        mode (``DetectionEngine.OWNER_FANOUT_MODES``) scatter per-owner
        tile scans gated by each owner's breaker and merge the partial
        grids with the exact rule; commits/retractions stamp the owning
        row range into every replica's WAL records.
        """
        self.shard_owners = (int(shard_owners)
                             if shard_owners is not None else None)
        if self.shard_owners is not None:
            if self.shard_owners < 1:
                raise ValueError(
                    f"shard_owners must be ≥ 1, got {shard_owners}")
            n_replicas = self.shard_owners
            if self.shard_owners > 1:
                # the shared index's store IS the placement: one slice per
                # owner replica, under a balanced row-range ShardPlan
                service_kw["n_shards"] = self.shard_owners
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be ≥ 1, got {n_replicas}")
        dur = service_kw.pop("durability", None)
        self.replicas = []
        for i in range(n_replicas):
            kw = dict(service_kw)
            if dur is not None:
                kw["durability"] = dataclasses.replace(
                    dur, state_dir=os.path.join(dur.state_dir, f"replica-{i}"))
            if (self.shard_owners and i > 0
                    and self.replicas[0]._index is not None):
                kw["_shared_index"] = self.replicas[0]._index
            self.replicas.append(DetectionService(base, base_p, cfg, **kw))
        self.breakers = [
            CircuitBreaker(breaker_threshold, breaker_cooldown_s,
                           clock=breaker_clock)
            for _ in range(n_replicas)]
        self._backlogs = [deque() for _ in range(n_replicas)]
        self._rr = 0
        self._route_lock = threading.Lock()
        self._write_lock = threading.Lock()

    def _in_sync(self) -> list:
        """Replica indices at the fleet epoch: breaker closed, no backlog."""
        return [i for i in range(len(self.replicas))
                if self.breakers[i].state == "closed"
                and not self._backlogs[i]]

    def _epoch_locked(self) -> int:
        """Common epoch check over IN-SYNC replicas; caller must hold
        ``_write_lock`` (a read during a commit broadcast would otherwise
        see a healthy mid-broadcast prefix as divergence). An ejected
        replica is legitimately behind — its backlog measures by how much —
        so it is excluded until catch-up rejoins it."""
        sync = self._in_sync()
        if not sync:
            raise RuntimeError("no in-sync replica (all circuit-open)")
        epochs = {self.replicas[i].epoch for i in sync}
        if len(epochs) != 1:
            raise RuntimeError(f"replica epochs diverged: {sorted(epochs)}")
        return epochs.pop()

    @property
    def epoch(self) -> int:
        """The (common) corpus epoch; raises if replicas ever diverge."""
        with self._write_lock:
            return self._epoch_locked()

    @property
    def stats(self) -> ServiceStats:
        """Aggregate counters summed over every replica, plus the router's
        breaker gauges (``breaker_trips`` lifetime, ``breaker_open`` now)."""
        agg = ServiceStats()
        for svc in self.replicas:
            for f in dataclasses.fields(ServiceStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(svc.stats, f.name))
        agg.breaker_trips = sum(b.trips for b in self.breakers)
        agg.breaker_open = sum(1 for b in self.breakers
                               if b.state != "closed")
        return agg

    def submit(self, request: DetectRequest,
               timeout: Optional[float] = 30.0) -> Future:
        """Route one request to the next IN-SYNC replica (round-robin).

        An ejected replica is missing commits its backlog holds — serving
        reads from it would answer with a stale corpus, so reads route
        around open breakers until catch-up rejoins the replica. Raises
        ``ServiceOverloaded`` when every replica is circuit-open.

        In shard-owner mode there is no full-copy replica to round-robin
        over: a tiled fan-out mode scatters the scan across ALL owner
        replicas (``_submit_owner_fanout``); any other mode reads through
        the primary, whose shard facade assembles rows from every owner's
        slice.
        """
        if self.shard_owners and self.shard_owners > 1:
            if (self.replicas[0].engine.mode
                    in DetectionEngine.OWNER_FANOUT_MODES):
                return self._submit_owner_fanout(request)
            return self.replicas[0].submit(request, timeout=timeout)
        with self._route_lock:
            sync = self._in_sync()
            if not sync:
                raise ServiceOverloaded(
                    "no in-sync replica to serve reads (all circuit-open)")
            self._rr = self._rr % len(sync)
            svc = self.replicas[sync[self._rr]]
            self._rr = (self._rr + 1) % len(sync)
        return svc.submit(request, timeout=timeout)

    # -- shard-owner mode (DESIGN.md §12) ------------------------------------

    def _owner_plan(self):
        """The fleet's row-range placement (owner i ↔ shard slice i)."""
        idx = self.replicas[0]._index
        if idx is not None and isinstance(idx.store, ShardedCorpusStore):
            return idx.store.plan
        # index-less modes carry no persistent store — derive the balanced
        # plan the engine's one-shot build will use at the current size
        return make_shard_plan(self.replicas[0].resident.n_corpus,
                               self.shard_owners or 1)

    def owner_of_row(self, r: int) -> int:
        """Which owner replica's slice holds corpus row ``r``."""
        return int(self._owner_plan().owner_of_row(int(r)))

    def _submit_owner_fanout(self, request: DetectRequest) -> Future:
        """Serve one request by fanning the tile scan across owner replicas.

        Synchronous (the caller's thread runs the pass): stage the request
        on the primary's resident buffers, build ONE owner scan context,
        collect each owner's partial tile stacks — gated by that owner's
        circuit breaker, so a dead owner surfaces ONE typed
        ``ShardScanError`` carrying its id and NO partial grids are merged
        — then merge with the exact rule (counts summed, p̂-error bounds
        maxed) and finalize into decisions bit-equal to a single-host pass.
        The returned future is already resolved (result or exception).
        """
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        svc = self.replicas[0]
        t0 = time.perf_counter()
        try:
            # writes never interleave with a fan-out pass: the router's
            # write lock orders it in the broadcast history, the primary's
            # corpus lock fences its own worker/commits
            with self._write_lock, svc._corpus_lock:
                resp = self._owner_pass_locked(svc, request)
            resp.latency_s = time.perf_counter() - t0
            svc.stats.requests += 1
            svc.stats.batches += 1
            svc.stats.rows += request.n_rows
            fut.set_result(resp)
        except BaseException as exc:  # noqa: BLE001 — future carries it
            svc.stats.failed_batches += 1
            svc.stats.failed_requests += 1
            fut.set_exception(exc)
        return fut

    def _owner_pass_locked(self, svc: DetectionService,
                           request: DetectRequest) -> DetectResponse:
        """One owner-fan-out engine pass; caller holds the locks.

        Mirrors ``serve_batch``'s transient-commit protocol around the
        primary's committed index, but replaces the monolithic
        ``engine.detect`` with owner_scan_context → per-owner
        ``detect_owner_partial`` (breaker-gated) → ``finalize_owner_partials``.
        """
        eng = svc.engine
        S0 = svc.base.n_sources
        if request.values.shape[1] != svc.base.n_items:
            raise ValueError(
                f"request {request.rid}: {request.values.shape[1]} items, "
                f"corpus has {svc.base.n_items}")
        union, p, copied = svc.resident.stage([request])
        idx = svc._index
        info = token = None
        if idx is not None and eng.mode in INDEXED_MODES:
            idx.store.ensure_row_capacity(union.n_sources)
            info = commit_rows(idx, union, p, eng.cfg,
                               union.n_sources - S0, compact=False)
            token = eng.apply_mask_delta(info.delta)
        try:
            ctx = eng.owner_scan_context(union, p, index=idx)
            partials = []
            for i in range(len(self.replicas)):
                br = self.breakers[i]
                if not br.allow():
                    raise ShardScanError(
                        i, "owner replica is circuit-open (ejected); "
                           "refusing the scan before any partial merge")
                try:
                    part = eng.detect_owner_partial(union, p, i, ctx=ctx)
                except ShardScanError:
                    br.record_failure()
                    raise          # already typed with the owner id;
                                   # partials are discarded, never merged
                except Exception as exc:
                    br.record_failure()
                    raise ShardScanError(
                        i, f"owner scan failed: "
                           f"{type(exc).__name__}: {exc}") from exc
                br.record_success()
                partials.append(part)
            res = eng.finalize_owner_partials(union, p, ctx, partials)
        finally:
            if info is not None:
                rollback_commit(idx, info)
                if token is not None:
                    eng.undo_mask_delta(token)
                else:
                    eng.rebase_mask_cache(info.delta)
        rows = slice(S0, S0 + request.n_rows)
        svc.stats.host_copy_bytes += copied
        return DetectResponse(
            rid=request.rid,
            copying=res.copying[rows, :S0].copy(),
            pr_independent=res.pr_independent[rows, :S0].copy(),
            c_fwd=res.c_fwd[rows, :S0].copy(),
            intra_copying=res.copying[rows, rows].copy(),
            batch_requests=1,
            batch_rows=request.n_rows,
            engine_wall_s=res.wall_time_s,
            host_copy_bytes=copied,
        )

    def catch_up(self) -> list:
        """Replay backlogged writes into replicas whose cooldown elapsed.

        The read-side rejoin hook (``_broadcast`` does the same inline on
        the next write): for each replica with a backlog whose breaker
        admits a probe, replay its missed writes in order — success closes
        the breaker and rejoins the replica at the fleet epoch, a failure
        re-opens it with exactly the still-missing suffix queued. Returns
        per-replica counts of writes replayed.
        """
        replayed = [0] * len(self.replicas)
        with self._write_lock:
            for i, svc in enumerate(self.replicas):
                br = self.breakers[i]
                if not self._backlogs[i] or not br.allow():
                    continue
                try:
                    while self._backlogs[i]:
                        b_op, b_args, b_kw = self._backlogs[i][0]
                        getattr(svc, b_op)(*b_args, **b_kw)
                        self._backlogs[i].popleft()
                        replayed[i] += 1
                except Exception:  # noqa: BLE001 — breaker records it
                    br.record_failure()
                    continue
                br.record_success()
            if self._in_sync():
                self._epoch_locked()
        return replayed

    def rebalance(self, tolerance: float = 0.25) -> bool:
        """Unseal → rebalance → reseal the shared sharded store.

        The operator drill OPERATIONS.md §10 describes: when commit/retract
        growth skews the row-range placement past ``1 + tolerance``, re-split
        the rows evenly — unsealing first when the store is packed/spilled,
        and resealing with the engine's shard options afterward so the
        per-owner byte budgets re-apply under the NEW plan. Decisions are
        placement-independent (the merge rule is exact), so no cache entry
        is invalidated; the engine's block-OR mask caches are dropped
        because the store's membership sequence restarts. Returns True when
        rows moved.
        """
        svc = self.replicas[0]
        idx = svc._index
        if idx is None or not isinstance(idx.store, ShardedCorpusStore):
            raise RuntimeError(
                "rebalance needs a row-range-sharded committed index "
                "(shard_owners=n or n_shards>1 on an indexed mode)")
        opt = svc.engine.options
        with self._write_lock, svc._corpus_lock:
            store = idx.store
            was_sealed = store.sealed
            if was_sealed:
                store.unseal()
            moved = store.rebalance(tolerance)
            if was_sealed:
                store.seal(pack=opt.shard_pack,
                           spill_dir=opt.shard_spill_dir,
                           resident_bytes=opt.shard_spill_bytes)
            if moved:
                for r in self.replicas:
                    r.engine.invalidate_mask_cache()
        return moved

    def _broadcast(self, op: str, args: tuple, kw: dict) -> list:
        """Apply one write op to the fleet; caller holds ``_write_lock``.

        Per replica: an open breaker buffers the op in that replica's
        backlog (it stays ejected); a half-open breaker first replays the
        backlog (catch-up), then the live op. A failure below the breaker
        threshold aborts the wave — applied replicas roll back LIFO,
        tentatively-buffered ops pop back out, ``ReplicaBroadcastError``
        raises. A failure AT the threshold (or on a probe) ejects the
        replica instead: the wave continues and succeeds on the healthy
        rest. If no replica at all applies, the op never happened —
        buffered copies pop and ``ReplicaBroadcastError(-1)`` raises.
        """
        rollback = ("rollback_last_commit" if op == "commit"
                    else "rollback_last_retract")
        infos: list = [None] * len(self.replicas)
        applied: list = []       # replica indices that applied the live op
        deferred: list = []      # replicas that buffered it this wave
        for i, svc in enumerate(self.replicas):
            br = self.breakers[i]
            if not br.allow():
                self._backlogs[i].append((op, args, kw))
                deferred.append(i)
                continue
            try:
                # half-open probe: catch up on the missed writes first, in
                # order — each success pops, so a mid-catch-up failure
                # leaves exactly the still-missing suffix queued
                while self._backlogs[i]:
                    b_op, b_args, b_kw = self._backlogs[i][0]
                    getattr(svc, b_op)(*b_args, **b_kw)
                    self._backlogs[i].popleft()
                infos[i] = getattr(svc, op)(*args, **kw)
            except Exception as exc:               # noqa: BLE001
                br.record_failure()
                if br.state == "open":
                    # threshold (or probe) failure: eject, don't abort —
                    # the fleet keeps accepting writes without this replica
                    self._backlogs[i].append((op, args, kw))
                    deferred.append(i)
                    continue
                for j in reversed(applied):
                    getattr(self.replicas[j], rollback)()
                for j in deferred:
                    self._backlogs[j].pop()
                raise ReplicaBroadcastError(i, exc) from exc
            br.record_success()
            applied.append(i)
        if not applied:
            for j in deferred:
                self._backlogs[j].pop()
            raise ReplicaBroadcastError(-1)
        self._epoch_locked()                       # divergence check
        return infos

    def commit(self, values: np.ndarray, accuracy: np.ndarray,
               p_claim: np.ndarray, *, compact: bool = True) -> list:
        """Broadcast one commit to every replica, serialized (§7 protocol).

        Returns per-replica ``CommitInfo`` receipts (None at the index of a
        replica whose breaker deferred the commit to its backlog). A
        replica that raises below its breaker threshold aborts the
        broadcast: the replicas that already applied are rolled back in
        reverse order (``rollback_last_commit`` is LIFO-safe and
        bit-exact), and ONE ``ReplicaBroadcastError`` surfaces with the
        failing replica's index and cause — the fleet stays consistent at
        the pre-commit epoch. A replica that trips its breaker is ejected
        instead and the commit proceeds on the rest (§9 — see
        ``_broadcast``). The post-broadcast epoch check turns any remaining
        divergence among in-sync replicas (a replica that saw a different
        write order) into a hard error instead of silent split-brain.

        In shard-owner mode the commit additionally ROUTES: the appended
        rows land in ``owner_of_row(n_before)``'s slice (appends go to the
        plan's tail range; the shard facade places the bytes), and every
        replica's WAL record is stamped with the owning row range so each
        ``replica-<i>/`` dir restores independently (DESIGN.md §12).
        """
        with self._write_lock:
            kw: dict = {"compact": compact}
            if self.shard_owners:
                n_before = self.replicas[0].resident.n_corpus
                q = int(np.asarray(values).shape[0])
                kw["_owner_range"] = (n_before, n_before + q)
            return self._broadcast(
                "commit", (values, accuracy, p_claim), kw)

    def retract(self, row_ids) -> list:
        """Broadcast one source retraction to every replica, serialized.

        Same protocol as ``commit`` — LIFO rollback below the breaker
        threshold (``rollback_last_retract``), ejection + backlog at it —
        so retractions interleave with commits in one total write order,
        which is what keeps every replica's (and the WAL's) mutation
        history identical. Returns per-replica ``RetractInfo`` receipts.
        In shard-owner mode the WAL records carry the [lo, hi) row span
        covering the retracted ids (see ``commit``).
        """
        with self._write_lock:
            kw = {}
            if self.shard_owners:
                ids = np.asarray(row_ids, np.int64).ravel()
                if ids.size:
                    kw["_owner_range"] = (int(ids.min()), int(ids.max()) + 1)
            return self._broadcast("retract", (row_ids,), kw)

    def flush(self) -> int:
        """Drain every replica synchronously; returns requests served."""
        return sum(svc.flush() for svc in self.replicas)

    def start(self) -> "ReplicaRouter":
        """Start every replica's worker thread."""
        for svc in self.replicas:
            svc.start()
        return self

    def stop(self) -> None:
        """Drain and join every replica's worker."""
        for svc in self.replicas:
            svc.stop()

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


__all__ = ["CircuitBreaker", "DeadlineExceeded", "DetectRequest",
           "DetectResponse", "DetectionService", "DurabilityOptions",
           "ReplicaBroadcastError", "ReplicaRouter", "ResidentCorpus",
           "ResultCache", "ServiceOverloaded", "ServiceStats",
           "ServiceStopped", "serve_batch", "INDEXED_MODES"]
