"""FAGININPUT baseline (§II-B, Table X).

The paper explored Fagin's NRA top-k algorithm: maintain, per index entry,
a list of (pair, contribution score) sorted by decreasing score, plus one
list of accumulated different-value scores. NRA then merges the lists. The
paper's finding — which we reproduce as a benchmark — is that merely
*generating the input lists* (a score for every pair sharing every entry,
plus the sort) already costs more than HYBRID, because it cannot prune:
every (pair, shared value) score must be materialized.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.index import InvertedIndex, build_index
from repro.core.scoring import score_same_np
from repro.core.types import ClaimsDataset, CopyConfig
from repro.utils.counters import ComputeCounter


def fagin_input(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    index: InvertedIndex | None = None,
):
    """Generate NRA input lists. Returns (per-entry lists, diff list, counter,
    wall seconds)."""
    t0 = time.perf_counter()
    idx = index if index is not None else build_index(ds, p_claim, cfg)
    acc = ds.accuracy.astype(np.float64)
    S = ds.n_sources

    entry_lists = []
    n_scores = 0
    for e in range(idx.n_entries):
        srcs = idx.providers(e)
        a = acc[srcs]
        f = score_same_np(float(idx.entry_p[e]), a[:, None], a[None, :], cfg.s, cfg.n)
        ii, jj = np.triu_indices(len(srcs), 1)
        scores = np.maximum(f[ii, jj], f[jj, ii])  # pair's max-direction score
        order = np.argsort(-scores)
        entry_lists.append((srcs[ii][order], srcs[jj][order], scores[order]))
        n_scores += 2 * len(ii)

    # different-value list: (l − n)·ln(1−s) per pair that has differences
    n_counts = idx.store.cooccurrence()
    diff = (idx.l_counts - n_counts) * cfg.ln_1ms
    iu = np.triu_indices(S, 1)
    mask = (idx.l_counts[iu] - n_counts[iu]) > 0
    order = np.argsort(diff[iu][mask])  # ascending (most negative first)
    diff_list = (iu[0][mask][order], iu[1][mask][order], diff[iu][mask][order])

    counter = ComputeCounter(
        pairs_considered=int((n_counts[iu] > 0).sum()),
        shared_values_examined=n_scores // 2,
        score_computations=n_scores,
    )
    return entry_lists, diff_list, counter, time.perf_counter() - t0
