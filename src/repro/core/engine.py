"""DetectionEngine — the single entry point for scalable copy detection.

Every detection workload (one-shot exact, production bucketed, bound /
hybrid early termination, iterative incremental rounds, sampled detection)
goes through ``DetectionEngine.detect``. The production ``bucketed`` mode is
the sharded, pair-tiled dataflow of DESIGN.md §3:

  1. build the inverted index (§III — streamed into the chunked
     ``CorpusStore``, never a dense (S, E) array) and re-chunk it p-sorted
     on each side of the Ē boundary (``engine_chunks`` — the accumulation
     is order-insensitive, so p-homogeneous chunks shrink the p̂ error;
     chunks double as the kernel's entry blocks);
  2. cut the S×S pair space into T×T tiles and prune, up front, every tile
     whose sources co-occur only inside the low-contribution suffix Ē — by
     Proposition 3.4 those pairs can never flip to copying, so the whole
     tile is skipped without touching a device (the tile-level test uses the
     OR-reduced incidence, an upper bound on any pair's co-occurrence); the
     keep matrix is symmetric, so only unordered (r ≤ c) tiles survive —
     the triangular schedule halves the tiles scheduled. The OR-reduction
     is kept per chunk, so tile pruning composes with chunk pruning
     (DESIGN.md §6);
  3. stream chunk GROUPS (default one chunk per device pass — the peak
     resident incidence is a single chunk; an optional byte budget groups
     chunks for dispatch-bound meshes) over a 1-D device
     mesh (shard_map); each device scans its surviving
     tiles, slicing the int8 chunk slab and feeding the fused
     dual-direction copyscore kernel one unordered tile at a time — one
     count matmul per entry block emits C→, C←, the shared count, the
     non-Ē count, and the error bound; per-tile accumulators stay on
     device across groups;
  4. scatter both orientations of every tile back into (S, S) (C← transposed
     lands at the mirrored coordinate), apply the INDEX step-3
     different-value adjustment, exactly rescore every pair whose decision
     margin is within its accumulated error bound, and decide — binary
     decisions match ``index_detect_exact`` (asserted by the engine tests
     and cross-checked by the scaling benchmark on every run).

Modes
  pairwise      exhaustive oracle (§II-B)
  exact         entry-sequential INDEX with paper-metric accounting (§III)
  bucketed      tiled + sharded production INDEX (this module)
  bound/bound+  early-terminating BOUND, optionally with timers (§IV)
  hybrid        BOUND+ for pairs sharing > l_threshold items (§IV-C)
  incremental   stateful rounds: first call bootstraps HYBRID + bookkeeping,
                later calls apply per-round deltas (§V)
  sampled       item sampling (§VI) then the tiled path on the subset
  sample_verify SCALESAMPLE candidate discovery, then an exact gathered
                rescore of only the candidate pairs — decisions on the
                candidate set equal ``index_detect_exact`` (DESIGN.md §4)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import tilecache
from repro.core.bound import bound_detect
from repro.core.bucketed import index_detect_exact
from repro.core.distributed import sharded_tile_scores, sharded_tile_scores_2d
from repro.core.pipeline import ChunkPrefetcher, PipelineStageError
from repro.core.incremental import (
    incremental_detect,
    make_incremental_state,
    rescore_pairs_exact,
)
from repro.core.index import InvertedIndex, build_index, engine_chunks
from repro.core.sampling import sample_by_cell, sample_by_item, scale_sample
from repro.core.shardplan import (
    OwnerPartial,
    ShardScanError,
    ShardedCorpusStore,
    make_shard_plan,
    merge_owner_partials,
    merge_shard_partials,
    scatter_tile_stacks,
    shard_store,
)
from repro.core.scoring import (
    bucket_score_deltas,
    decide_copying_np,
    pairwise_detect,
    posterior_independence_np,
)
from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult
from repro.utils.counters import ComputeCounter

MODES = ("pairwise", "exact", "bucketed", "bound", "bound+", "hybrid",
         "incremental", "sampled", "sample_verify")


@dataclass
class EngineOptions:
    """Tuning knobs; mode-specific fields are ignored by other modes."""

    # entry buckets per index (count). 64 keeps the within-bucket p̂ error —
    # and with it the rescore set — small while the bucket scan stays matmul-
    # bound (DESIGN.md §3.1).
    n_buckets: int = 64
    # pair-tile edge (sources per tile side). 256 divides into two 128-wide
    # MXU pair blocks; clamped down for tiny datasets (see _tile_edge).
    tile: int = 256
    # 1-D tile-mesh size (device count); None → every local device.
    devices: Optional[int] = None
    # decision-margin band (log-odds units) around z = 0 that triggers an
    # exact rescore on top of the accumulated p̂-error bound. 1.0 adds slack
    # for the float32 accumulation order; the bound itself carries the
    # approximation error (DESIGN.md §3.4).
    rescore_margin: float = 1.0
    # kernel dispatch: auto (Pallas on TPU, jnp reference elsewhere) |
    # pallas | interpret | ref.
    kernel_impl: str = "auto"
    # incidence element type: auto (→ int8; exact int32 MXU accumulation at
    # half the HBM traffic) | int8 | bf16 | f32 (microbenchmark ablations).
    incidence_dtype: str = "auto"
    # hybrid crossover: apply BOUND checks only to pairs sharing more than
    # this many items; None → 16, the paper's §IV-C empirical crossover.
    l_threshold: Optional[int] = None
    # sampled / sample_verify: fraction of item columns to keep (0..1].
    # 0.1 reproduces the paper's §VI operating point (Table IX).
    sample_rate: float = 0.1
    # sampling strategy: scale (SCALESAMPLE) | item (BYITEM) | cell (BYCELL).
    sample_strategy: str = "scale"
    # SCALESAMPLE floor (items per source): every source keeps ≥ this many
    # sampled items when it has them. 4 is the paper's N (§VI-E).
    min_per_source: int = 4
    # RNG seed for the item sample — fixed so detection runs are replayable.
    sample_seed: int = 1
    # incremental: |ΔM̂| (log-odds units) above which an entry is treated as
    # a big change and replayed exactly (§V-A; 1.0 ≈ the paper's ρ).
    rho: float = 1.0
    # incremental: |ΔA| accuracy drift that forces a pair rescore
    # unconditionally (fraction, 0..1). 0.2 is the paper's ρ_acc.
    rho_acc: float = 0.2
    # sample_verify: initial half-width (log-odds units, sampled-score scale)
    # of the candidate net below the copying boundary z = 0. 2.0 ≈ the
    # decision band where sampling noise plausibly hides a true pair.
    verify_slack: float = 2.0
    # sample_verify: multiplicative step of the recall-slack sweep (> 1).
    verify_slack_growth: float = 1.6
    # sample_verify: stop widening when the next shell of near-miss pairs
    # holds fewer than this fraction of the current candidate set — the
    # empirical bound on pairs the net might still miss.
    verify_miss_frac: float = 0.02
    # chunks of the engine store shipped per device pass (count). 1 (the
    # default) is strict streaming — peak resident incidence is ONE chunk —
    # and also measured fastest on CPU at S=2048 (8.5 s vs 13.3 s shipping
    # 63 chunks at once: the chunk-sized working set stays in cache). None →
    # auto-size from chunk_group_bytes, capped at K−1 so a chunked store's
    # full incidence is never resident in one allocation.
    chunk_group: Optional[int] = 1
    # HARD byte ceiling on the incidence slab shipped per device pass: it
    # narrows the engine chunk width when one n_buckets-derived chunk would
    # exceed it (floored at 8 entries × S_pad rows) and clamps any
    # requested/auto chunk_group. With chunk_group=None it doubles as the
    # auto group-size target for meshes where dispatch latency, not cache
    # locality, dominates.
    chunk_group_bytes: int = 64 << 20
    # canonical CorpusStore chunk width (entries) for indexes this engine
    # builds; None → store default (512). Rounded up to a multiple of 8.
    store_chunk_entries: Optional[int] = None
    # byte budget for the largest single incidence allocation during index
    # build (wins over store_chunk_entries; width = bytes // rows).
    store_chunk_bytes: Optional[int] = None
    # row-range shards of the corpus data plane (DESIGN.md §10). None/1 →
    # unsharded. Indexes this engine builds are wrapped in a
    # ShardedCorpusStore; each shard scans only the pair tiles whose ROW
    # block it owns (assembling just the row blocks those tiles touch) and
    # the per-shard partial grids merge — error channel by MAX — into
    # decisions bit-equal to the unsharded engine.
    n_shards: Optional[int] = None
    # bitpack each shard's chunk blocks to 1 bit/entry when the engine
    # store is sealed for the scan (8× over int8; unpacked per assembly).
    shard_pack: bool = False
    # per-shard resident-set byte cap: cold blocks spill to checksummed
    # frames (WAL container) under shard_spill_dir, LRU. None → no cap.
    shard_spill_bytes: Optional[int] = None
    # spill directory; None → a fresh temp dir when a byte cap is set.
    shard_spill_dir: Optional[str] = None
    # 2-D device mesh (data, pod) for the tile scan: tiles shard over
    # `data`, entry chunks over `pod`, one psum combines (DESIGN.md §10).
    # None → the 1-D tile mesh.
    mesh_shape: Optional[tuple] = None
    # chunk groups staged host→device AHEAD of the running kernel by the
    # async pipeline (DESIGN.md §11): a producer thread assembles and
    # transfers group G+1's v-slab while group G computes, double-buffered
    # at depth 2. 0 → fully synchronous staging (the pre-pipeline path);
    # stall telemetry (stage_wait_s / compute_wait_s) lands in last_stats
    # either way.
    prefetch_depth: int = 2


@dataclass
class TileScanContext:
    """The deterministic prologue of one tiled pass, reified (DESIGN.md §12).

    Everything the tile scans and the finalize step consume — resolved
    index, engine chunk store, bucket deltas, the tile∘chunk keep masks,
    the surviving unordered tile coords, group sizing — computed ONCE.
    ``_detect_tiled`` builds and consumes it inline; the shard-owner
    fan-out builds it once on the router's engine and hands the SAME
    context to every owner's ``detect_owner_partial``, so the per-owner
    scans see identical kernel operands and the merged decisions stay
    bit-equal to the single-host pass. For sampled modes ``ds``/``p_claim``
    are the item-subset views the scan runs over and ``items`` records the
    deterministic sample (``sample_seed``) for the verify stage.
    """

    t0: float
    ds: ClaimsDataset
    p_claim: np.ndarray
    base_idx: InvertedIndex
    ech: object                    # EngineChunks — p-ordered scan store
    delta: np.ndarray              # per-chunk p̂-error bound δ_k
    sharded: bool
    S: int
    T: int
    n_blocks: int
    S_pad: int
    acc_pad: np.ndarray
    block: int
    dtype: object                  # jnp incidence dtype
    chunk_keep: np.ndarray         # (K, n_blocks, n_blocks) bool
    coords: np.ndarray             # (n_tiles, 2) int32 — surviving r ≤ c tiles
    tiles_total: int
    n_tiles: int
    Gc: int                        # chunks per device pass
    chunk_nbytes: int
    resident_nbytes: int
    mask_source: str
    items: Optional[np.ndarray] = None   # sampled/sample_verify item subset


class DetectionEngine:
    """One engine instance per detection workload.

    Stateless for one-shot modes; ``incremental`` carries the paper's §V
    bookkeeping across ``detect`` calls (``reset()`` drops it).
    """

    def __init__(self, cfg: CopyConfig, mode: str = "bucketed", **options):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
        self.cfg = cfg
        self.mode = mode
        self.options = EngineOptions(**options)
        self.last_stats: dict = {}
        self._mesh: Optional[Mesh] = None
        self._mesh2: Optional[Mesh] = None
        self._inc_state = None
        self._last_considered: Optional[np.ndarray] = None
        # incremental block-OR mask cache (DESIGN.md §11): per-entry tile-
        # block incidence over the LAST persistent index this engine
        # detected against, delta-updated at commit/retract time
        self._mask_cache = None
        self._mask_cache_hits = 0
        self._mask_full_builds = 0
        # pipeline-stall telemetry accumulated across the current pass
        self._pipe = {"stage_wait_s": 0.0, "compute_wait_s": 0.0,
                      "staging_s": 0.0}

    # -- lifecycle ----------------------------------------------------------

    def reset(self) -> None:
        """Drop incremental bookkeeping (next detect() bootstraps afresh)."""
        self._inc_state = None

    @property
    def incremental_state(self):
        """§V bookkeeping (None until an incremental detect() has run)."""
        return self._inc_state

    def mesh(self) -> Mesh:
        """The 1-D tile mesh (built lazily so XLA_FLAGS can be set first)."""
        if self._mesh is None:
            n = self.options.devices or len(jax.devices())
            self._mesh = Mesh(np.array(jax.devices()[:n]), ("shards",))
        return self._mesh

    def mesh2(self) -> Mesh:
        """The 2-D ``data``×``pod`` tile mesh (``mesh_shape`` option)."""
        if self._mesh2 is None:
            d, p = self.options.mesh_shape
            devs = jax.devices()
            if d * p > len(devs):
                raise ValueError(
                    f"mesh_shape {d}x{p} needs {d * p} devices, "
                    f"{len(devs)} available")
            self._mesh2 = Mesh(np.array(devs[: d * p]).reshape(d, p),
                               ("data", "pod"))
        return self._mesh2

    # -- incremental tile-prune mask cache (DESIGN.md §11) ------------------

    def apply_mask_delta(self, delta):
        """Propagate a commit/retract ``MutationDelta`` into the mask cache.

        Called by the serving layer right after ``commit_rows`` /
        ``retract_rows`` so the next ``detect(..., index=...)`` reuses the
        cached block incidence (updated in O(touched cells)) instead of
        regathering all K chunk reductions. Returns an opaque undo token
        for commits — pair it with ``undo_mask_delta`` around a transient
        commit→detect→rollback — and None otherwise. Safe no-op when no
        cache exists yet; a delta that doesn't chain (wrong ``from_mseq``,
        compaction) just marks the cache stale for a fresh rebuild.
        """
        cache = self._mask_cache
        if cache is None or delta is None:
            return None
        inner = cache.apply(delta)
        return None if inner is None else (cache, inner)

    def undo_mask_delta(self, token) -> None:
        """Reverse ``apply_mask_delta`` after the index store rolled back.

        Re-adopts the cache object the token came from (a detect between
        apply and undo may have swapped ``_mask_cache``), so the restored
        incidence — bit-exact to the pre-commit state — serves the next
        pass. ``None`` tokens are no-ops.
        """
        if token is None:
            return
        cache, inner = token
        cache.undo(inner)
        self._mask_cache = cache

    def rebase_mask_cache(self, delta) -> None:
        """Re-anchor a cache adopted DURING a transient commit onto the base.

        The serving layer calls this (instead of ``undo_mask_delta``) when
        ``apply_mask_delta`` returned no token — i.e. no cache existed
        before the transient commit, so whatever the detect pass adopted is
        anchored on the mid-transient store state and would die with the
        rollback. ``BlockOrCache.rebase`` shrinks it back onto the restored
        base store so the NEXT batch chains off it incrementally.
        """
        cache = self._mask_cache
        if cache is None:
            return
        if delta is None:
            self.invalidate_mask_cache()
            return
        cache.rebase(delta)
        if cache.stale:
            self._mask_cache = None

    def invalidate_mask_cache(self) -> None:
        """Drop the mask cache (the next indexed detect rebuilds it fresh)."""
        if self._mask_cache is not None:
            self._mask_cache.stale = True
        self._mask_cache = None

    # -- dispatch -----------------------------------------------------------

    def detect(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        index: InvertedIndex | None = None,
        items: np.ndarray | None = None,
    ) -> DetectionResult:
        """Run one detection pass in this engine's mode (DESIGN.md §3).

        Args:
          ds: the (S, D) claims dataset.
          p_claim: (S, D) float32 — truth probability of the value each
            source provides per item (equal across providers of one value;
            ignored where values[s, d] < 0).
          index: a prebuilt ``InvertedIndex`` to reuse (modes that index);
            None → built here.
          items: sampled/sample_verify only — an explicit item-column subset
            overriding the configured sampler.

        Returns a ``DetectionResult`` over every ordered source pair;
        per-run diagnostics land in ``self.last_stats``.
        """
        opt = self.options
        if self.mode == "pairwise":
            return pairwise_detect(ds, p_claim, self.cfg)
        if index is None and self.mode in ("exact", "bound", "bound+", "hybrid"):
            index = self._build_index(ds, p_claim)
        if self.mode == "exact":
            return index_detect_exact(ds, p_claim, self.cfg, index=index)
        if self.mode in ("bound", "bound+", "hybrid"):
            l_thr = opt.l_threshold
            if l_thr is None:
                l_thr = 16 if self.mode == "hybrid" else 0
            return bound_detect(
                ds, p_claim, self.cfg, n_buckets=opt.n_buckets,
                use_timers=self.mode in ("bound+", "hybrid"),
                l_threshold=l_thr, rescore_margin=opt.rescore_margin,
                index=index)
        if self.mode == "incremental":
            if self._inc_state is None:
                if index is None and opt.n_shards and opt.n_shards > 1:
                    index = self._build_index(ds, p_claim)
                result, self._inc_state = make_incremental_state(
                    ds, p_claim, self.cfg, n_buckets=opt.n_buckets,
                    chunk_entries=opt.store_chunk_entries,
                    chunk_bytes=opt.store_chunk_bytes, index=index)
                return result
            return incremental_detect(ds, p_claim, self.cfg, self._inc_state,
                                      rho=opt.rho, rho_acc=opt.rho_acc)
        if self.mode == "sampled":
            if items is None:
                items = self._sample_items(ds)
            sub = ds.subset_items(items)
            return self._detect_tiled(sub, p_claim[:, items])
        if self.mode == "sample_verify":
            return self._detect_sample_verify(ds, p_claim, items=items)
        return self._detect_tiled(ds, p_claim, index=index)

    def _sample_items(self, ds: ClaimsDataset) -> np.ndarray:
        opt = self.options
        if opt.sample_strategy == "item":
            return sample_by_item(ds, opt.sample_rate, seed=opt.sample_seed)
        if opt.sample_strategy == "cell":
            return sample_by_cell(ds, opt.sample_rate, seed=opt.sample_seed)
        return scale_sample(ds, opt.sample_rate,
                            min_per_source=opt.min_per_source,
                            seed=opt.sample_seed)

    # -- sample-then-verify (§VI sampling + exact candidate rescore) --------

    def _detect_sample_verify(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        items: np.ndarray | None = None,
    ) -> DetectionResult:
        """SCALESAMPLE for candidate-pair discovery, exact rescore to decide.

        DESIGN.md §4: the sampled tiled pass is only a *net* — every pair
        whose sampled decision margin lands within the recall slack of the
        copying boundary becomes a candidate, the slack widening until the
        shell of near-miss pairs thins below ``verify_miss_frac`` (the
        empirical bound on pairs the net might still miss). Candidates are
        then rescored exactly on the FULL dataset with the gathered dense
        rescore op (``rescore_pairs_exact``), so the final decision of every
        candidate pair provably equals ``index_detect_exact`` — sampling
        error survives only as recall loss of the net, never as a wrong
        decision on a discovered pair.
        """
        t0 = time.perf_counter()
        if items is None:
            items = self._sample_items(ds)

        # -- 1. cheap discovery: the tiled path on the sampled columns ------
        sub = ds.subset_items(items)
        sampled = self._detect_tiled(sub, p_claim[:, items])
        return self._sample_verify_finalize(
            ds, p_claim, items, sampled, self.last_stats,
            self._last_considered, t0)

    def _sample_verify_finalize(self, ds, p_claim, items, sampled,
                                sampled_stats, considered_s, t0):
        """Steps 2+3 of sample_verify: slack sweep + exact candidate rescore.

        Split from ``_detect_sample_verify`` so the shard-owner fan-out can
        run the sampled discovery pass as per-owner partials and still
        finish with the identical verification sweep
        (``finalize_owner_partials``).
        """
        cfg = self.cfg
        opt = self.options
        S = ds.n_sources

        # -- 2. recall-slack sweep: widen the candidate net -----------------
        # z < 0 ⇔ independent; sampling noise can push a true copying pair
        # below 0, so candidates are all pairs with z ≥ -slack. The sweep
        # widens slack geometrically until the next shell (-g·slack, -slack]
        # is nearly empty relative to the net — once the margin distribution
        # has a gap there, further widening buys ~no recall but rescores
        # strictly more pairs.
        z = (np.log(cfg.alpha / cfg.beta)
             + np.logaddexp(sampled.c_fwd, sampled.c_fwd.T))
        tri = np.triu(np.ones((S, S), bool), 1) & considered_s
        slack = float(opt.verify_slack)
        growth = max(float(opt.verify_slack_growth), 1.0 + 1e-6)
        z_floor = float(z[tri].min()) if tri.any() else 0.0
        sweep_rounds = 1
        while True:
            cand = tri & (z >= -slack)
            shell = tri & (z >= -slack * growth) & (z < -slack)
            n_cand, n_shell = int(cand.sum()), int(shell.sum())
            if (n_shell <= opt.verify_miss_frac * max(n_cand, 1)
                    or -slack <= z_floor):
                break
            slack *= growth
            sweep_rounds += 1

        # -- 3. exact gathered rescore of only the candidate pairs ----------
        pi, pj = np.nonzero(cand)
        c_fwd = np.zeros((S, S), np.float32)
        rescore_pairs_exact(ds, p_claim, cfg, pi, pj, c_fwd)
        considered = np.zeros((S, S), bool)
        considered[pi, pj] = considered[pj, pi] = True

        copying = decide_copying_np(c_fwd, c_fwd.T, cfg) & considered
        pr_ind = np.where(considered,
                          posterior_independence_np(c_fwd, c_fwd.T, cfg),
                          1.0).astype(np.float32)
        np.fill_diagonal(pr_ind, 1.0)
        np.fill_diagonal(copying, False)
        self._last_considered = considered     # == the candidate set

        prov = ds.provided_mask
        values_exact = (int(np.count_nonzero(prov[pi] & prov[pj]))
                        if len(pi) else 0)
        counter = ComputeCounter(
            pairs_considered=n_cand,
            shared_values_examined=(
                sampled.counter.shared_values_examined + values_exact),
            score_computations=(
                sampled.counter.score_computations + 2 * values_exact),
            index_entries=sampled.counter.index_entries,
        )
        self.last_stats = {
            "items_sampled": int(len(items)),
            "item_rate": round(len(items) / max(ds.n_items, 1), 4),
            "slack_final": round(slack, 3),
            "sweep_rounds": sweep_rounds,
            "candidate_pairs": n_cand,
            "shell_pairs": n_shell,
            "sampled_copying_pairs": len(sampled.copying_pairs()),
            "sampled_stats": sampled_stats,
        }
        return DetectionResult(c_fwd=c_fwd, pr_independent=pr_ind,
                               copying=copying, counter=counter,
                               wall_time_s=time.perf_counter() - t0)

    # -- the tiled + sharded production path --------------------------------

    def _build_index(self, ds: ClaimsDataset, p_claim: np.ndarray,
                     streaming: bool = False) -> InvertedIndex:
        """Build an index honoring this engine's store-chunking options.

        With ``n_shards`` set, the index's store is wrapped in a
        ``ShardedCorpusStore`` under a balanced row-range plan — every
        consumer (exact, bound, tiled, incremental) then reads rows through
        the shard facade, and the tiled path scans shard by shard.

        ``streaming=True`` (the one-shot tiled path) additionally streams
        the seal through the wrap when pack/spill options are set: blocks
        bitpack and spill under the LRU cap AS they are sliced, and source
        chunks release behind the slicing, so no host's peak-resident bytes
        exceed its slice budget even DURING the build (DESIGN.md §12). The
        mutating consumers (services, incremental state) keep the dense
        wrap — a sealed store refuses commits.
        """
        opt = self.options
        idx = build_index(ds, p_claim, self.cfg,
                          chunk_entries=opt.store_chunk_entries,
                          chunk_bytes=opt.store_chunk_bytes)
        if opt.n_shards and opt.n_shards > 1:
            plan = make_shard_plan(idx.store.n_rows, opt.n_shards)
            if streaming and (opt.shard_pack
                              or opt.shard_spill_bytes is not None):
                idx.store = shard_store(
                    idx.store, plan, pack=opt.shard_pack,
                    spill_dir=opt.shard_spill_dir,
                    resident_bytes=opt.shard_spill_bytes, consume=True)
            else:
                idx.store = shard_store(idx.store, plan)
        return idx

    def _tile_edge(self, s_sources: int) -> int:
        """Tile edge: the smallest multiple of 8 (f32 sublane alignment) that
        is ≥ min(S, requested tile) — tiny datasets pad by at most 7 sources
        instead of being blown up to a fixed 64-wide tile."""
        t = min(self.options.tile, max(1, s_sources))
        return max(8, -(-t // 8) * 8)

    # Inflation + slack constants live in scoring.bucket_score_deltas now
    # (shared with BOUND's error-aware freezes); kept as class attributes for
    # back-compat with callers that tuned them per engine.
    DELTA_INFLATION = 1.5
    DELTA_SLACK = 2e-3

    def _bucket_deltas(self, p_hat, p_lo, p_hi, acc: np.ndarray) -> np.ndarray:
        """Per-chunk bound δ_k ≳ |f(A_i, A_j, p) − f(A_i, A_j, p̂_k)| for any
        entry p in chunk k (``scoring.bucket_score_deltas``). Together with
        ``rescore_margin`` this makes the tiled decisions provably equal the
        exact INDEX — and the scaling benchmark cross-checks decision
        equality on every run."""
        return bucket_score_deltas(p_hat, p_lo, p_hi, acc, self.cfg,
                                   inflation=self.DELTA_INFLATION,
                                   slack=self.DELTA_SLACK)

    def _tile_kernel(self, v_dev, acc_vec, p_g, coords_g, T, d_g, o_g,
                     block, donate=False):
        """One group pass: 1-D tile mesh, or data×pod when mesh_shape is set."""
        opt = self.options
        if opt.mesh_shape is not None:
            return sharded_tile_scores_2d(
                self.mesh2(), v_dev, acc_vec, p_g, coords_g, self.cfg,
                tile=T, delta=d_g, nout=o_g, impl=opt.kernel_impl,
                block_i=block, block_j=block)
        return sharded_tile_scores(
            self.mesh(), v_dev, acc_vec, p_g, coords_g, self.cfg, tile=T,
            delta=d_g, nout=o_g, impl=opt.kernel_impl,
            block_i=block, block_j=block, donate=donate)

    def _stage_v(self, v_np, dtype):
        """Host→device conversion of one group's v-slab.

        Runs on the prefetch thread when ``prefetch_depth`` ≥ 1, so the
        transfer of group G+1 hides behind group G's kernel. The 2-D
        (``mesh_shape``) path pod-pads the chunk axis host-side inside
        ``sharded_tile_scores_2d`` — v stays host-resident there and only
        the (dominant) host assembly is overlapped.
        """
        if self.options.mesh_shape is not None:
            return (v_np if dtype == jnp.int8
                    else jnp.asarray(v_np, dtype=dtype))
        return jnp.asarray(v_np, dtype=dtype)

    def _donate_ok(self) -> bool:
        """Donate staged v-slabs to the kernel? Only when the pipeline is
        double-buffering fresh per-group device arrays on the 1-D mesh —
        and never on CPU, where XLA can't use the donation and warns."""
        return (self.options.prefetch_depth > 0
                and self.options.mesh_shape is None
                and jax.default_backend() != "cpu")

    # scatter lives in shardplan (shared with OwnerPartial.to_grids); the
    # staticmethod survives for callers that patched/tuned it per engine
    _scatter_tiles = staticmethod(scatter_tile_stacks)

    def _scan_shards(self, ech, coords, chunk_keep, acc_pad, T, n_blocks,
                     Gc, delta, block, dtype):
        """Per-shard tile scans over compact row-block slabs (DESIGN.md §10).

        Each shard owns the tiles whose ROW block falls inside its row
        range and assembles only the row blocks its tiles touch (row AND
        column sides) — never the full S_pad incidence. Per-tile kernel
        operands are identical to the unsharded scan, so per-tile outputs
        are bit-identical; tile placement across shards is disjoint, so
        the merge is exact. A shard failing mid-scan surfaces as ONE
        ``ShardScanError`` before any merge happens — no partial decision
        grids escape to the caller.
        """
        store = ech.store
        plan = store.plan
        S_pad = n_blocks * T
        last_row = max(plan.n_rows - 1, 0)
        owner = np.array([plan.owner_of_row(min(r * T, last_row))
                          for r in range(n_blocks)], np.int64)
        tile_keep = chunk_keep[:, coords[:, 0], coords[:, 1]]
        partials = []
        run_total = 0
        for s in range(store.n_shards):
            grids = [np.zeros((S_pad, S_pad), np.float32) for _ in range(4)]
            mine = owner[coords[:, 0]] == s
            if mine.any():
                try:
                    stacks, run = self._scan_one_shard(
                        ech, coords[mine], tile_keep[:, mine], acc_pad, T,
                        n_blocks, Gc, delta, block, dtype)
                except Exception as e:
                    # surface the ROOT fault as the cause: a staging
                    # failure arrives wrapped in PipelineStageError, but
                    # callers triage on the underlying I/O error
                    root = e.__cause__ if isinstance(
                        e, PipelineStageError) and e.__cause__ else e
                    raise ShardScanError(
                        s, f"tile scan failed: "
                           f"{type(e).__name__}: {e}") from root
                run_total += run
                if stacks is not None:
                    self._scatter_tiles(grids, coords[mine], stacks,
                                        n_blocks, T)
            partials.append(tuple(grids))
        return partials, run_total

    def _scan_one_shard(self, ech, coords_s, tile_keep_s, acc_pad, T,
                        n_blocks, Gc, delta, block, dtype):
        """Stream chunk groups for ONE shard's tiles over its compact slab.

        Group descriptors are enumerated up front on the caller's thread;
        slab assembly (the shard reads) + device staging run on the
        prefetcher's stage thread, ``prefetch_depth`` groups ahead of the
        kernel. Returns ``(stacks, chunk_tiles_run)`` — the five per-tile
        kernel channels as host float32 ``(len(coords_s), T, T)`` arrays
        (None when every group was pruned), which is exactly the
        ``OwnerPartial`` transport payload of the shard-owner fan-out.
        """
        store = ech.store
        K = ech.n_chunks
        b = ech.width
        blocks_needed = np.unique(coords_s)
        pos = np.full(n_blocks, -1, np.int64)
        pos[blocks_needed] = np.arange(len(blocks_needed))
        slab_rows = len(blocks_needed) * T
        coords_c = pos[coords_s].astype(np.int32)
        acc_slab = np.ascontiguousarray(
            acc_pad.reshape(n_blocks, T)[blocks_needed]).reshape(slab_rows)
        stacks = None
        run = 0
        groups = []
        for g0 in range(0, K, Gc):
            ks = list(range(g0, min(g0 + Gc, K)))
            gmask = tile_keep_s[ks].any(axis=0)
            if not gmask.any():
                continue
            run += int(gmask.sum()) * len(ks)
            groups.append((ks, gmask))

        def _stage(desc):
            ks, gmask = desc
            coords_g = np.where(gmask[:, None], coords_c, -1).astype(np.int32)
            p_g = np.full(Gc, 0.5, np.float32)
            d_g = np.zeros(Gc, np.float32)
            o_g = np.zeros(Gc, np.float32)
            v_np = np.zeros((slab_rows, Gc, b), np.int8)
            for i, k in enumerate(ks):
                for bi, blk in enumerate(blocks_needed):
                    v_np[bi * T:(bi + 1) * T, i, :] = store.assemble_rows(
                        int(k), int(blk) * T, (int(blk) + 1) * T)
                p_g[i] = ech.p_hat[k]
                d_g[i] = delta[k]
                o_g[i] = ech.nout[k]
            return self._stage_v(v_np, dtype), p_g, d_g, o_g, coords_g

        donate = self._donate_ok()
        pf = ChunkPrefetcher(groups, _stage,
                             depth=self.options.prefetch_depth)
        try:
            for v_dev, p_g, d_g, o_g, coords_g in pf:
                outs = self._tile_kernel(v_dev, acc_slab, p_g, coords_g, T,
                                         d_g, o_g, block, donate=donate)
                stacks = (list(outs) if stacks is None
                          else [st + o for st, o in zip(stacks, outs)])
        finally:
            pf.close()
            for key in self._pipe:
                self._pipe[key] += getattr(pf, key)
        if stacks is not None:
            stacks = [np.asarray(s, np.float32)[: len(coords_s)]
                      for s in stacks]
        return stacks, run

    def _detect_tiled(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        index: InvertedIndex | None = None,
    ) -> DetectionResult:
        ctx = self._tiled_prologue(ds, p_claim, index)
        grids, chunk_tiles_run = self._run_tiled_scan(ctx)
        return self._tiled_finalize(ctx, grids, chunk_tiles_run)

    def _tiled_prologue(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        index: InvertedIndex | None = None,
    ) -> TileScanContext:
        """Steps 1–2 of the tiled pass: index, chunking, pruning, sizing."""
        t0 = time.perf_counter()
        opt = self.options
        S = ds.n_sources
        T = self._tile_edge(S)
        n_blocks = -(-S // T)
        S_pad = n_blocks * T
        self._pipe = {"stage_wait_s": 0.0, "compute_wait_s": 0.0,
                      "staging_s": 0.0}
        base_idx = (index if index is not None
                    else self._build_index(ds, p_claim, streaming=True))
        # Incidence element type, resolved first: the chunk width depends on
        # its itemsize. 0/1 incidence makes int8 (the default) lossless —
        # the kernel accumulates it exactly in int32 on the MXU at half the
        # HBM traffic of bf16; bf16/f32 remain selectable for the
        # microbenchmark.
        dtypes = {"auto": jnp.int8, "int8": jnp.int8, "bf16": jnp.bfloat16,
                  "f32": jnp.float32}
        if opt.incidence_dtype not in dtypes:
            raise ValueError(
                f"unknown incidence_dtype {opt.incidence_dtype!r}; "
                f"expected one of {sorted(dtypes)}")
        dtype = dtypes[opt.incidence_dtype]
        itemsize = np.dtype(np.int8 if dtype == jnp.int8 else
                            np.float32 if dtype == jnp.float32
                            else np.float16).itemsize
        # p-ordered, region-padded, uniform-width chunk store; rows carry the
        # tile-grid padding so chunks slice straight into pair tiles. The
        # byte budget caps the chunk width so even ONE shipped chunk
        # respects it (floored at 8 entries inside engine_chunks).
        ech = engine_chunks(
            base_idx, opt.n_buckets, row_capacity=S_pad,
            max_width=opt.chunk_group_bytes // max(S_pad * itemsize, 1))
        K = ech.n_chunks
        b = ech.width
        delta = self._bucket_deltas(ech.p_hat, ech.p_lo, ech.p_hi, ds.accuracy)
        # row-range sharded plane (DESIGN.md §10): the engine store is a
        # ShardedCorpusStore whenever the index's store was (gather_entries
        # preserves the plan). Sealing freezes it for the scan — optionally
        # bitpacked to 1 bit/entry and/or under a per-shard LRU byte cap
        # with cold blocks spilled to checksummed frames.
        sharded = isinstance(ech.store, ShardedCorpusStore)
        if sharded and (opt.shard_pack or opt.shard_spill_bytes is not None):
            ech.store.seal(pack=opt.shard_pack,
                           spill_dir=opt.shard_spill_dir,
                           resident_bytes=opt.shard_spill_bytes)

        # ---- tile ∘ chunk pruning on the OR-reduced incidence -------------
        # Per chunk k, G_k[r] ORs the chunk's incidence over tile r's rows;
        # chunk_keep[k][r, c] ⇔ some row-block-r source shares some entry of
        # chunk k with some col-block-c source (an upper bound on any member
        # pair's co-occurrence, so both prunes are exact). A tile survives
        # if any NON-Ē chunk keeps it (the Ē suffix bound — pairs that
        # co-occur only inside Ē can never flip to copying); a surviving
        # tile then skips every chunk whose chunk_keep bit is off (its
        # contribution to all five channels would be zero). The keep matrix
        # is symmetric, so only unordered (r ≤ c) tiles are scheduled.
        keep = np.zeros((n_blocks, n_blocks), bool)
        chunk_keep = np.zeros((K, n_blocks, n_blocks), bool)
        base_store = base_idx.store
        cache = self._mask_cache if index is not None else None
        mask_source = "fresh"
        if (cache is not None and cache.matches(base_store, T)
                and cache.block_inc.shape == (n_blocks,
                                              base_store.n_entries)):
            # delta-maintained cache hit (DESIGN.md §11): derive each
            # GATHERED chunk's mask by permuting cached base columns
            # through the gather order — bit-equal to a fresh reduction
            # of the gathered chunk, with zero full-chunk regathers
            mask_source = "cache"
            self._mask_cache_hits += 1
            for k in range(K):
                g_k = cache.chunk_mask(
                    ech.order[k * b:(k + 1) * b]).astype(np.int32)
                chunk_keep[k] = (g_k @ g_k.T) > 0
                if k < ech.ebar_chunk:
                    keep |= chunk_keep[k]
        else:
            # fresh full reduction (sharded stores reduce shard by shard —
            # no host assembles the full chunk). When detecting against a
            # persistent index, adopt the result as the new mask cache at
            # zero extra reduction cost: scatter each gathered chunk's
            # columns back to base entry order.
            base_inc = None
            base_mseq = -1
            if index is not None:
                base_inc = np.zeros((n_blocks, base_store.n_entries), bool)
                base_mseq = getattr(base_store, "mseq", -1)
            for k in range(K):
                g_bool = tilecache.chunk_block_inc(ech.store, k, T, n_blocks)
                if base_inc is not None:
                    sel = ech.order[k * b: k * b + g_bool.shape[1]]
                    live = sel >= 0
                    if live.any():
                        base_inc[:, sel[live]] = g_bool[:, live]
                g_k = g_bool.astype(np.int32)
                chunk_keep[k] = (g_k @ g_k.T) > 0
                if k < ech.ebar_chunk:
                    keep |= chunk_keep[k]
            if base_inc is not None:
                self._mask_cache = tilecache.BlockOrCache(
                    base_store, T, base_mseq, base_inc)
                self._mask_full_builds += 1
        coords = np.argwhere(np.triu(keep)).astype(np.int32)  # r ≤ c tiles
        tiles_total = n_blocks * (n_blocks + 1) // 2
        n_tiles = len(coords)

        # ---- stream chunk groups over the 1-D mesh ------------------------
        acc_pad = np.pad(ds.accuracy.astype(np.float32), (0, S_pad - S),
                         constant_values=0.5)

        block = 128 if T % 128 == 0 else T
        chunk_nbytes = S_pad * b * itemsize   # shipped (unpacked) slab bytes
        # the byte budget clamps every group (floored at one chunk) against
        # TRUE resident bytes: a sealed bitpacked shard plane holds 1
        # bit/entry, so packed stores stream 8× larger groups under the
        # same budget (each group's shipped slab is still unpacked per
        # assembly — peak_group_bytes reports that separately)
        if sharded and opt.shard_pack and ech.store.sealed:
            resident_nbytes = S_pad * (-(-b // 8))
        else:
            resident_nbytes = chunk_nbytes
        budget_chunks = max(
            1, opt.chunk_group_bytes // max(resident_nbytes, 1))
        if opt.chunk_group is not None:
            Gc = min(max(1, int(opt.chunk_group)), budget_chunks)
        else:
            # auto: fill the byte budget, but never ship ALL chunks in one
            # pass when the store is chunked — the full incidence is never
            # resident in a single allocation
            Gc = min(budget_chunks, max(1, K - 1))
        return TileScanContext(
            t0=t0, ds=ds, p_claim=p_claim, base_idx=base_idx, ech=ech,
            delta=delta, sharded=sharded, S=S, T=T, n_blocks=n_blocks,
            S_pad=S_pad, acc_pad=acc_pad, block=block, dtype=dtype,
            chunk_keep=chunk_keep, coords=coords, tiles_total=tiles_total,
            n_tiles=n_tiles, Gc=Gc, chunk_nbytes=chunk_nbytes,
            resident_nbytes=resident_nbytes, mask_source=mask_source)

    def _run_tiled_scan(self, ctx: TileScanContext):
        """Step 3: the tile∘chunk scan — the four pair grids + run count."""
        opt = self.options
        ech, coords, delta = ctx.ech, ctx.coords, ctx.delta
        K, b = ech.n_chunks, ech.width
        T, n_blocks, S_pad, Gc = ctx.T, ctx.n_blocks, ctx.S_pad, ctx.Gc
        acc_pad, block, dtype = ctx.acc_pad, ctx.block, ctx.dtype
        n_tiles, chunk_keep = ctx.n_tiles, ctx.chunk_keep
        c_same = np.zeros((S_pad, S_pad), np.float32)
        n_cnt = np.zeros((S_pad, S_pad), np.float32)
        n_out = np.zeros((S_pad, S_pad), np.float32)
        err = np.zeros((S_pad, S_pad), np.float32)
        chunk_tiles_run = 0
        if n_tiles and K and ctx.sharded:
            # per-shard scans over compact row-block slabs; the merge takes
            # the MAX of the error channel (and the sum of the others —
            # placement is disjoint, so both are exact)
            partials, chunk_tiles_run = self._scan_shards(
                ech, coords, chunk_keep, acc_pad, T, n_blocks, Gc, delta,
                block, dtype)
            c_same, n_cnt, n_out, err = merge_shard_partials(
                partials, shape=(S_pad, S_pad))
        elif n_tiles and K:
            # per-tile accumulators live on device, KEEPING the mesh-padded
            # tile sharding (slicing mid-stream would reshard every group);
            # one host transfer at the end feeds the scatter. Peak resident
            # incidence = one group: S_pad · Gc · b elements.
            stacks = None
            tile_keep = chunk_keep[:, coords[:, 0], coords[:, 1]]  # (K, n_tiles)
            groups = []
            for g0 in range(0, K, Gc):
                ks = list(range(g0, min(g0 + Gc, K)))
                gmask = tile_keep[ks].any(axis=0)
                if not gmask.any():
                    continue
                # actual kernel work: a tile shipped with a group scans ALL
                # the group's chunks (the kernel can't skip single chunks),
                # so grouped streaming realizes less chunk pruning than the
                # per-chunk masks would allow — count what really runs
                chunk_tiles_run += int(gmask.sum()) * len(ks)
                groups.append((ks, gmask))

            def _stage(desc):
                ks, gmask = desc
                # chunk-pruned tiles short-circuit via the (-1,-1) marker
                coords_g = np.where(gmask[:, None], coords,
                                    -1).astype(np.int32)
                p_g = np.full(Gc, 0.5, np.float32)
                d_g = np.zeros(Gc, np.float32)
                o_g = np.zeros(Gc, np.float32)
                if Gc == 1:
                    # store chunks are already contiguous (S_pad, b) — ship
                    # a zero-copy view instead of re-copying the incidence
                    v_np = ech.store.chunks[ks[0]].reshape(S_pad, 1, b)
                else:
                    v_np = np.zeros((S_pad, Gc, b), np.int8)
                for i, k in enumerate(ks):
                    if Gc > 1:
                        v_np[:, i, :] = ech.store.chunks[k]
                    p_g[i] = ech.p_hat[k]
                    d_g[i] = delta[k]
                    o_g[i] = ech.nout[k]
                return self._stage_v(v_np, dtype), p_g, d_g, o_g, coords_g

            donate = self._donate_ok()
            pf = ChunkPrefetcher(groups, _stage, depth=opt.prefetch_depth)
            try:
                for v_dev, p_g, d_g, o_g, coords_g in pf:
                    outs = self._tile_kernel(v_dev, acc_pad, p_g, coords_g,
                                             T, d_g, o_g, block,
                                             donate=donate)
                    stacks = (list(outs) if stacks is None
                              else [s + o for s, o in zip(stacks, outs)])
            finally:
                pf.close()
                for key in self._pipe:
                    self._pipe[key] += getattr(pf, key)
            if stacks is None:
                stacks = [jnp.zeros((n_tiles, T, T), jnp.float32)] * 5
            self._scatter_tiles([c_same, n_cnt, n_out, err], coords, stacks,
                                n_blocks, T)
        return (c_same, n_cnt, n_out, err), chunk_tiles_run

    def _tiled_finalize(self, ctx: TileScanContext, grids,
                        chunk_tiles_run: int) -> DetectionResult:
        """Step 4: INDEX step 3 + error-bounded exact rescore + decide."""
        cfg = self.cfg
        opt = self.options
        ds, p_claim = ctx.ds, ctx.p_claim
        ech, base_idx, S = ctx.ech, ctx.base_idx, ctx.S
        K, b = ech.n_chunks, ech.width
        T, Gc = ctx.T, ctx.Gc
        tiles_total, n_tiles = ctx.tiles_total, ctx.n_tiles
        dtype, sharded, mask_source = ctx.dtype, ctx.sharded, ctx.mask_source
        chunk_nbytes, resident_nbytes = ctx.chunk_nbytes, ctx.resident_nbytes
        t0 = ctx.t0
        c_same, n_cnt, n_out, err = grids
        c_same = c_same[:S, :S]
        n_cnt = n_cnt[:S, :S]
        err = err[:S, :S]
        considered = n_out[:S, :S] > 0.5
        np.fill_diagonal(considered, False)

        # ---- INDEX step 3 + error-bounded exact rescore -------------------
        c_fwd = np.where(considered,
                         c_same + (base_idx.l_counts - n_cnt) * cfg.ln_1ms,
                         0.0).astype(np.float32)
        np.fill_diagonal(c_fwd, 0.0)

        # a pair's decision can only differ from the exact INDEX if the
        # accumulated p̂ error reaches its decision margin — rescore exactly
        # every such pair (err bounds |Δ C→|; |Δz| ≤ max of both directions)
        z = np.log(cfg.alpha / cfg.beta) + np.logaddexp(c_fwd, c_fwd.T)
        near = considered & (np.abs(z) <
                             opt.rescore_margin + np.maximum(err, err.T))
        near &= np.triu(np.ones_like(near), 1).astype(bool)
        pi, pj = np.nonzero(near)
        n_rescored = rescore_pairs_exact(ds, p_claim, cfg, pi, pj, c_fwd)

        pr_ind = posterior_independence_np(c_fwd, c_fwd.T, cfg)
        copying = decide_copying_np(c_fwd, c_fwd.T, cfg) & considered
        pr_ind = np.where(considered, pr_ind, 1.0).astype(np.float32)
        np.fill_diagonal(pr_ind, 1.0)
        np.fill_diagonal(copying, False)
        self._last_considered = considered

        # semantic (paper-metric) accounting, identical to the exact INDEX
        iu = np.triu_indices(S, 1)
        values_examined = int(n_cnt[iu][considered[iu]].sum())
        n_pairs = int(considered[iu].sum())
        counter = ComputeCounter(
            pairs_considered=n_pairs,
            shared_values_examined=values_examined,
            score_computations=2 * values_examined + 2 * n_pairs + 2 * n_rescored,
            index_entries=ech.n_live,
        )
        self.last_stats = {
            "tile": T,
            "tiles_total": tiles_total,        # unordered (r ≤ c) tiles
            "tiles_kept": n_tiles,
            "tiles_pruned": tiles_total - n_tiles,
            "schedule": "triangular",
            "incidence_dtype": str(np.dtype(dtype)),
            "n_devices": (int(np.prod(opt.mesh_shape)) if opt.mesh_shape
                          else self.mesh().shape["shards"]),
            "rescored_pairs": n_rescored,
            # chunked-store telemetry (DESIGN.md §6)
            "chunks": K,
            "chunk_width": b,
            "chunk_group": Gc,
            # chunk pairs over tiles that SURVIVED tile pruning — run/total
            # isolates the chunk-prune win (pre-tile-prune total = K·tiles_total)
            "chunk_tiles_total": K * n_tiles,
            "chunk_tiles_run": chunk_tiles_run,
            "peak_group_bytes": int(Gc * chunk_nbytes),
            "resident_chunk_bytes": int(resident_nbytes),
            # async staging pipeline (DESIGN.md §11)
            "prefetch_depth": int(opt.prefetch_depth),
            "stage_wait_s": round(self._pipe["stage_wait_s"], 6),
            "compute_wait_s": round(self._pipe["compute_wait_s"], 6),
            "staging_s": round(self._pipe["staging_s"], 6),
            # incremental tile-prune mask cache (DESIGN.md §11)
            "mask_source": mask_source,
            "mask_cache_hits": self._mask_cache_hits,
            "mask_full_builds": self._mask_full_builds,
            "mask_blocks_updated": (self._mask_cache.blocks_updated
                                    if self._mask_cache is not None else 0),
        }
        if sharded:
            # shard-plane telemetry (DESIGN.md §10): what each host actually
            # held; the scaling bench asserts the peak against 1/shards of
            # the unsharded footprint
            self.last_stats.update({
                "n_shards": ech.store.n_shards,
                "shard_plan": ech.store.plan.sizes().tolist(),
                "shard_resident_bytes": ech.store.shard_resident_bytes(),
                "shard_peak_resident_bytes": ech.store.shard_peak_bytes(),
                "mesh_shape": (list(opt.mesh_shape) if opt.mesh_shape
                               else None),
            })
        return DetectionResult(c_fwd=c_fwd, pr_independent=pr_ind,
                               copying=copying, counter=counter,
                               wall_time_s=time.perf_counter() - t0)

    # -- shard-owner fan-out (DESIGN.md §12) --------------------------------

    #: engine modes the router fans out as per-owner partial tile scans;
    #: the remaining (host) modes read through the shard facade on one
    #: replica instead — both routes are bit-equal to single-host.
    OWNER_FANOUT_MODES = ("bucketed", "sampled", "sample_verify")

    def owner_scan_context(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        index: InvertedIndex | None = None,
    ) -> TileScanContext:
        """The shared fan-out prologue, computed once for all owners.

        Deterministic given (ds, p_claim, index, options): the router
        builds it on ONE engine and hands it to every owner's
        ``detect_owner_partial``, so index build, engine chunking, bucket
        deltas, and tile∘chunk pruning never rerun per owner. Sampled
        modes resolve their deterministic item subset here (the scan then
        runs over the subset views; ``items`` rides on the context for the
        sample_verify finalize). Requires a tiled fan-out mode and a
        row-range-sharded engine store.
        """
        if self.mode not in self.OWNER_FANOUT_MODES:
            raise ValueError(
                f"owner fan-out supports modes {self.OWNER_FANOUT_MODES}, "
                f"engine mode is {self.mode!r}")
        items = None
        if self.mode in ("sampled", "sample_verify"):
            items = self._sample_items(ds)
            sub = ds.subset_items(items)
            ctx = self._tiled_prologue(sub, p_claim[:, items])
        else:
            ctx = self._tiled_prologue(ds, p_claim, index)
        ctx.items = items
        if not ctx.sharded:
            raise ValueError(
                "owner fan-out requires a row-range-sharded engine store "
                "(build the index with n_shards > 1)")
        return ctx

    def detect_owner_partial(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        owner: int,
        index: InvertedIndex | None = None,
        ctx: TileScanContext | None = None,
    ) -> OwnerPartial:
        """ONE owner's share of the tiled pass (DESIGN.md §12).

        Scans only the surviving tiles whose ROW block falls in ``owner``'s
        row range — assembling just the row blocks those tiles touch, never
        the full incidence — and returns the per-tile kernel outputs as an
        ``OwnerPartial`` transport payload. Kernel operands are identical
        to the single-host scan, so per-tile outputs are bit-identical; a
        failure surfaces as one typed ``ShardScanError`` carrying the owner
        id (the router merges nothing for a failed wave).
        """
        if ctx is None:
            ctx = self.owner_scan_context(ds, p_claim, index=index)
        ech = ctx.ech
        store = ech.store
        owner = int(owner)
        if not 0 <= owner < store.n_shards:
            raise ValueError(
                f"owner {owner} out of range for {store.n_shards} owners")
        plan = store.plan
        T, n_blocks = ctx.T, ctx.n_blocks
        last_row = max(plan.n_rows - 1, 0)
        owners = np.array([plan.owner_of_row(min(r * T, last_row))
                           for r in range(n_blocks)], np.int64)
        mine = owners[ctx.coords[:, 0]] == owner
        coords_s = ctx.coords[mine]
        stacks = None
        run = 0
        if len(coords_s) and ech.n_chunks:
            tile_keep = ctx.chunk_keep[:, ctx.coords[:, 0], ctx.coords[:, 1]]
            try:
                stacks, run = self._scan_one_shard(
                    ech, coords_s, tile_keep[:, mine], ctx.acc_pad, T,
                    n_blocks, ctx.Gc, ctx.delta, ctx.block, ctx.dtype)
            except Exception as e:
                root = e.__cause__ if isinstance(
                    e, PipelineStageError) and e.__cause__ else e
                raise ShardScanError(
                    owner, f"owner tile scan failed: "
                           f"{type(e).__name__}: {e}") from root
        return OwnerPartial(owner=owner, n_blocks=n_blocks, tile=T,
                            coords=coords_s, stacks=stacks,
                            chunk_tiles_run=run)

    def finalize_owner_partials(
        self,
        ds: ClaimsDataset,
        p_claim: np.ndarray,
        ctx: TileScanContext,
        partials: list,
    ) -> DetectionResult:
        """Merge per-owner partials and finish the pass (router-side).

        Refuses to merge unless EVERY owner contributed exactly one partial
        — after an owner failure nothing merges, per the fault contract.
        Counts sum, the p̂-error bound maxes (``merge_owner_partials``), and
        the standard finalize (INDEX step 3, error-bounded exact rescore,
        decide) runs on the merged grids; for sample_verify the sampled
        merge then feeds the identical recall-slack sweep + exact candidate
        rescore over the FULL dataset. Decisions are bit-equal to the
        single-host engine by the §3.4 rescore argument.
        """
        store = ctx.ech.store
        got = sorted(int(p.owner) for p in partials)
        if got != list(range(store.n_shards)):
            raise ValueError(
                f"finalize_owner_partials: partials cover owners {got}, "
                f"need each of 0..{store.n_shards - 1} exactly once")
        grids = merge_owner_partials(list(partials), ctx.n_blocks, ctx.T)
        run = sum(int(p.chunk_tiles_run) for p in partials)
        result = self._tiled_finalize(ctx, grids, run)
        if self.mode == "sample_verify":
            return self._sample_verify_finalize(
                ds, p_claim, ctx.items, result, self.last_stats,
                self._last_considered, ctx.t0)
        return result


__all__ = ["DetectionEngine", "EngineOptions", "MODES", "TileScanContext"]
