"""INCREMENTAL detection across fusion rounds (§V).

After round 2 the per-round changes in value probability / source accuracy
are small and rarely flip decisions. We keep the paper's structure:

* classify entries into big / small score changes (|ΔM̂| > ρ, with M̂
  recomputed on the *same* two accuracies as the recorded round — §V-A);
* pass 1: apply exact per-pair deltas for big-change entries (before each
  pair's decision point) and a conservative batched bound Δρ·|Ē↘| for
  small changes; pairs still safely on their side of the threshold keep
  their decision — the paper observes ≥86–99% settle here (Table VIII);
* passes 2–3 (compensation with Ē⋈ / Ē↑ and exact small-change replay)
  are collapsed into one *exact rescoring of the flip-candidate set*
  (DESIGN.md §2.3): on TPU a gathered exact rescore of ≲2% of pairs is one
  dense batched op, strictly cheaper and decision-equivalent to the paper's
  entry-wise compensation walk. Pairs containing a source with a big
  accuracy change (|ΔA| > ρ_acc = .2) are rescored unconditionally, as in
  the paper.

The public entry point is ``DetectionEngine(cfg, mode="incremental")``
(core/engine.py), which owns the round lifecycle: the first ``detect`` call
bootstraps the state here, later calls apply per-round deltas.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.bound import bound_detect
from repro.core.index import (
    BucketedIndex,
    InvertedIndex,
    bucketize,
    build_index,
    entry_extreme_accuracies,
    prop31_reference_accs,
)
from repro.core.scoring import (
    decide_copying_np,
    pair_scores_subset,
    posterior_independence_np,
    score_same_np,
)
from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult
from repro.utils.counters import ComputeCounter


@dataclass
class IncrementalState:
    """Bookkeeping carried across rounds (§V preparation step)."""

    index: InvertedIndex          # canonical (round-2) entry order — V is fixed
    bucketed: BucketedIndex
    entry_bucket: np.ndarray      # (E,) bucket id per entry
    first_provider: np.ndarray    # (E,) a provider per entry (for p lookup)
    p_old: np.ndarray             # (E,) last-recomputed P(E)
    score_old: np.ndarray         # (E,) M̂ with p_old
    a1_ref: np.ndarray            # (E,) Prop-3.1 accuracies of the reference round
    a2_ref: np.ndarray
    acc_old: np.ndarray           # (S,) accuracies of the reference round
    c_hat: np.ndarray             # (S,S) Ĉ→ starting scores
    copying: np.ndarray           # (S,S) current decisions
    considered: np.ndarray        # (S,S)
    dec_bucket: np.ndarray        # (S,S)
    l_counts: np.ndarray
    pass1_settled: float = 1.0
    err: np.ndarray = None        # (S,S) accumulated p̂-error bound on c_hat
                                  # (0 where a round has rescored exactly)


def rescore_pairs_exact(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    pi: np.ndarray,
    pj: np.ndarray,
    c_fwd: np.ndarray,
) -> int:
    """Gathered dense exact rescore of an explicit flip-candidate pair list.

    This is the batched op DESIGN.md §2.3 collapses the paper's §V
    compensation passes into, shared by every caller that must replace
    approximate pair scores with exact ones: INCREMENTAL's flip candidates,
    the engine's error-bounded near-threshold pairs (DESIGN.md §3 step 4),
    and SAMPLE-THEN-VERIFY's candidate set (DESIGN.md §4).

    Args:
      ds, p_claim, cfg: the *full* dataset, per-claim truth probabilities
        (S, D), and model config the exact scores are computed against.
      pi, pj: (P,) int arrays of source indices — the unordered pairs to
        rescore (each listed once; both orientations are written).
      c_fwd: (S, S) float32 C→ matrix, mutated in place at [pi, pj] and
        [pj, pi] with exact Eq. 2–8 scores over all shared items.

    Returns the number of pairs rescored (0 for an empty list).
    """
    if len(pi) == 0:
        return 0
    c_fwd[pi, pj] = pair_scores_subset(ds, p_claim, cfg, pi, pj)
    c_fwd[pj, pi] = pair_scores_subset(ds, p_claim, cfg, pj, pi)
    return len(pi)


def make_incremental_state(
    ds: ClaimsDataset, p_claim: np.ndarray, cfg: CopyConfig,
    n_buckets: int = 64,
    chunk_entries: int | None = None,
    chunk_bytes: int | None = None,
    index: InvertedIndex | None = None,
) -> tuple[DetectionResult, IncrementalState]:
    """Run HYBRID from scratch and capture the bookkeeping for later rounds.

    ``chunk_entries`` / ``chunk_bytes`` forward to ``build_index`` — they
    pick the CorpusStore chunking the bookkeeping will iterate forever after.
    ``index`` bootstraps from a prebuilt index instead — including a
    COMMITTED one (base + delta chunk sequence, Ē as a mask): the
    bookkeeping below iterates whatever chunk layout the store has, and the
    per-entry arrays are position-indexed, so the delta layout rides along
    (DESIGN.md §7).
    """
    idx = index if index is not None else build_index(
        ds, p_claim, cfg, chunk_entries=chunk_entries, chunk_bytes=chunk_bytes)
    bucketed = bucketize(idx, n_buckets)
    result, bstate = bound_detect(
        ds, p_claim, cfg, use_timers=True, l_threshold=16,
        index=idx, bucketed=bucketed, return_state=True,
    )
    E = idx.n_entries
    entry_bucket = (np.searchsorted(bucketed.starts, np.arange(E), side="right") - 1
                    ).astype(np.int32)
    # a provider per entry, chunk by chunk (column argmax over live rows)
    first_provider = (
        np.concatenate([ch.V.argmax(axis=0) for ch in idx.store.iter_chunks()])
        if idx.store.n_chunks else np.zeros(0, np.int64)
    ).astype(np.int32)

    # Prop-3.1 reference accuracies per entry (vectorized case split)
    acc = ds.accuracy.astype(np.float64)
    amin, asec, amax = entry_extreme_accuracies(idx.store, acc)
    a1_ref, a2_ref = prop31_reference_accs(
        idx.entry_p.astype(np.float64), amin, asec, amax, cfg)

    state = IncrementalState(
        index=idx, bucketed=bucketed, entry_bucket=entry_bucket,
        first_provider=first_provider,
        p_old=idx.entry_p.copy(), score_old=idx.entry_score.copy(),
        a1_ref=a1_ref, a2_ref=a2_ref, acc_old=ds.accuracy.copy(),
        c_hat=bstate.c_hat.copy(), copying=result.copying.copy(),
        considered=bstate.considered.copy(), dec_bucket=bstate.dec_bucket.copy(),
        l_counts=idx.l_counts, err=bstate.err.copy(),
    )
    return result, state


def incremental_detect(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    state: IncrementalState,
    rho: float = 1.0,
    rho_acc: float = 0.2,
) -> DetectionResult:
    """One incremental round. Mutates ``state`` in place."""
    t0 = time.perf_counter()
    idx = state.index
    S = ds.n_sources
    E = idx.n_entries
    acc_new = ds.accuracy.astype(np.float64)

    # new entry probabilities via any provider's claim (padding columns of a
    # committed store have no providers — clamp the lookup and zero their
    # deltas so they never join the big/small classification)
    live = idx.entry_item >= 0
    p_new = p_claim[state.first_provider,
                    np.maximum(idx.entry_item, 0)].astype(np.float32)
    p_new = np.where(live, p_new, state.p_old)
    score_new = score_same_np(
        p_new.astype(np.float64), state.a1_ref, state.a2_ref, cfg.s, cfg.n
    ).astype(np.float32)
    delta = np.where(live, score_new - state.score_old, 0.0)
    big = np.abs(delta) > rho
    small_dec = (~big) & (delta < 0)
    small_inc = (~big) & (delta > 0)

    # ---- pass 1a: exact deltas from big-change entries -------------------
    d_c = np.zeros((S, S), np.float64)
    values_examined = 0
    for e in np.nonzero(big)[0]:
        provs = idx.providers(e)
        if len(provs) < 2:
            continue
        a_new = acc_new[provs]
        a_old = state.acc_old.astype(np.float64)[provs]
        f_new = score_same_np(float(p_new[e]), a_new[:, None], a_new[None, :], cfg.s, cfg.n)
        f_old = score_same_np(float(state.p_old[e]), a_old[:, None], a_old[None, :], cfg.s, cfg.n)
        sub = np.ix_(provs, provs)
        # only update pairs whose decision point lies after this entry
        gate = state.dec_bucket[sub] >= state.entry_bucket[e]
        d_c[sub] += np.where(gate, f_new - f_old, 0.0)
        values_examined += int(np.triu(gate, 1).sum())

    # ---- pass 1b: conservative batched bound for small changes -----------
    d_rho_dec = float(-delta[small_dec].min()) if small_dec.any() else 0.0
    d_rho_inc = float(delta[small_inc].max()) if small_inc.any() else 0.0

    def _masked_counts(mask: np.ndarray) -> np.ndarray:
        # Σ_chunks V_c[:, m] V_c[:, m]ᵀ — per-chunk partial sums of 0/1
        # products are exact integers in f32, bit-equal to the dense matmul
        out = np.zeros((S, S), np.float32)
        if not mask.any():
            return out
        for ch in idx.store.iter_chunks():
            m = mask[ch.start: ch.start + ch.width]
            if m.any():
                v = ch.V[:, m].astype(np.float32)
                out += v @ v.T
        return out

    cnt_dec = _masked_counts(small_dec)
    cnt_inc = _masked_counts(small_inc)

    c_base = state.c_hat.astype(np.float64) + d_c
    # the bootstrap's accumulated p̂-error bound (zeroed wherever a previous
    # round rescored exactly) — the keep rules must hold BEYOND it, so kept
    # decisions stay provably exact for any index layout (DESIGN.md §7)
    err = (state.err if state.err is not None
           else np.zeros((S, S), np.float32)).astype(np.float64)
    # worst case against the current decision
    worst_down = c_base - d_rho_dec * cnt_dec - err
    worst_up = c_base + d_rho_inc * cnt_inc + err

    log_ratio = np.log(cfg.alpha / cfg.beta)
    was_copy = state.copying
    # copying pairs stay decided if even the worst-case decrease keeps them over θ_cp
    keep_copy = was_copy & (np.maximum(worst_down, worst_down.T) >= cfg.theta_cp)
    # no-copying pairs stay decided if the worst-case increase keeps them independent
    z_up = log_ratio + np.logaddexp(worst_up, worst_up.T)
    keep_ind = (~was_copy) & (z_up < 0.0)

    big_acc = np.abs(acc_new - state.acc_old) > rho_acc
    acc_flag = big_acc[:, None] | big_acc[None, :]

    candidates = state.considered & ~(keep_copy | keep_ind)
    candidates |= state.considered & acc_flag
    candidates &= np.triu(np.ones((S, S), bool), 1)
    n_cand = int(candidates.sum())
    n_considered = int(np.triu(state.considered, 1).sum())
    state.pass1_settled = 1.0 - n_cand / max(n_considered, 1)

    # ---- passes 2–3 collapsed: exact rescore of candidates ---------------
    c_fwd = c_base.astype(np.float32)
    pi, pj = np.nonzero(candidates)
    if rescore_pairs_exact(ds, p_claim, cfg, pi, pj, c_fwd):
        values_examined += int(state.l_counts[pi, pj].sum())
    np.fill_diagonal(c_fwd, 0.0)

    copying = decide_copying_np(c_fwd, c_fwd.T, cfg) & state.considered
    pr_ind = posterior_independence_np(c_fwd, c_fwd.T, cfg)
    pr_ind = np.where(state.considered, pr_ind, 1.0)
    np.fill_diagonal(pr_ind, 1.0)
    np.fill_diagonal(copying, False)

    # ---- fold updates back into the state ---------------------------------
    state.c_hat = c_fwd.copy()
    state.copying = copying.copy()
    state.p_old[big] = p_new[big]
    state.score_old[big] = score_new[big]
    state.acc_old[big_acc] = ds.accuracy[big_acc]
    if state.err is not None and len(pi):
        state.err = state.err.copy()
        state.err[pi, pj] = state.err[pj, pi] = 0.0   # rescored ⇒ now exact

    counter = ComputeCounter(
        pairs_considered=n_cand,
        shared_values_examined=values_examined,
        score_computations=2 * values_examined + 2 * n_cand,
        index_entries=E,
    )
    return DetectionResult(c_fwd=c_fwd, pr_independent=pr_ind, copying=copying,
                           counter=counter, wall_time_s=time.perf_counter() - t0)
