"""Distributed copy detection — pair-space 2D sharding over a TPU mesh.

The paper's §VIII names two parallelization opportunities ("per entry" and
"per pair of sources"). We realize both with shard_map on the production
mesh (launch/mesh.py):

  * the S×S pair space is tiled 2D: C-block rows over the ``data`` axis and
    columns over the ``model`` axis (a SUMMA-like decomposition — each
    device owns one (rows × cols) tile of C);
  * the entry dimension E (the reduction) is sharded over the ``pod`` axis;
    each pod accumulates partial co-occurrence counts over its entry shard
    and a single psum("pod") combines them — one all-reduce of S²/device
    floats per bucket group, overlapping pods' compute.

The incidence matrix V is passed twice with different shardings (row-block
copy and column-block copy); XLA lays each out once per device — there is no
gather of the full V anywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scoring import score_same
from repro.core.types import CopyConfig


def _local_pair_scores(vr, vc, acc_r, acc_c, p_hat, s, n, has_pod):
    """Per-device: C_same→ tile + shared-count tile over the local entry shard.

    vr: (S_r, K, w) row-block incidence (entry shard local)
    vc: (S_c, K, w) column-block incidence
    """
    f_a1 = acc_r[:, None]
    f_a2 = acc_c[None, :]

    def body(carry, xs):
        c_same, n_cnt = carry
        vr_k, vc_k, p_k = xs
        if vr_k.dtype == jnp.int8:
            # int8 incidence (§Perf H3): halves HBM traffic vs bf16; the MXU
            # accumulates 0/1 products exactly in int32
            count = jnp.dot(vr_k, vc_k.T,
                            preferred_element_type=jnp.int32).astype(jnp.float32)
        else:
            count = jnp.dot(vr_k, vc_k.T, preferred_element_type=jnp.float32)
        # p is constant within a bucket ⇒ any local representative works
        f = score_same(p_k[0], f_a1, f_a2, s, n)
        return (c_same + f * count, n_cnt + count), None

    S_r, K, w = vr.shape
    S_c = vc.shape[0]
    # the accumulators are device-varying over the pair-tile axes — mark them
    varying = ("data", "model") + (("pod",) if has_pod else ())
    zero = jax.lax.pcast(jnp.zeros((S_r, S_c), jnp.float32), varying, to="varying")
    (c_same, n_cnt), _ = jax.lax.scan(
        body, (zero, zero), (jnp.moveaxis(vr, 1, 0), jnp.moveaxis(vc, 1, 0), p_hat))
    if has_pod:
        c_same = jax.lax.psum(c_same, "pod")
        n_cnt = jax.lax.psum(n_cnt, "pod")
    return c_same, n_cnt


def distributed_pair_scores_lowerable(mesh: Mesh, n_sources: int, K: int,
                                      width: int, cfg: CopyConfig,
                                      dtype=jnp.bfloat16):
    """Shapes-only variant for the dry-run: returns a Lowered without ever
    materializing the (K, S, w) incidence tensor (which at 1M-source scale
    would be hundreds of GB on the host)."""
    has_pod = "pod" in mesh.axis_names
    if has_pod:
        width += (-width) % mesh.shape["pod"]
    e_axis = "pod" if has_pod else None
    spec_r = P("data", None, e_axis)
    spec_c = P("model", None, e_axis)
    out_spec = P("data", "model")
    shard_fn = jax.jit(
        jax.shard_map(
            partial(_local_pair_scores, s=cfg.s, n=cfg.n, has_pod=has_pod),
            mesh=mesh,
            in_specs=(spec_r, spec_c, P("data"), P("model"),
                      P(None, e_axis) if has_pod else P(None, None)),
            out_specs=(out_spec, out_spec),
        ),
        in_shardings=(
            NamedSharding(mesh, spec_r), NamedSharding(mesh, spec_c),
            NamedSharding(mesh, P("data")), NamedSharding(mesh, P("model")),
            NamedSharding(mesh, P(None, e_axis) if has_pod else P(None, None)),
        ),
        out_shardings=(NamedSharding(mesh, out_spec),
                       NamedSharding(mesh, out_spec)),
    )
    v_sds = jax.ShapeDtypeStruct((n_sources, K, width), dtype)
    acc_sds = jax.ShapeDtypeStruct((n_sources,), jnp.float32)
    p_sds = jax.ShapeDtypeStruct((K, width), jnp.float32)
    return shard_fn.lower(v_sds, v_sds, acc_sds, acc_sds, p_sds)


def distributed_pair_scores(
    mesh: Mesh,
    v_ksw: np.ndarray,          # (K, S, w) bucketed incidence (bf16/f32)
    p_hat: np.ndarray,          # (K,)
    acc: np.ndarray,            # (S,)
    cfg: CopyConfig,
):
    """Lowerable distributed C_same→/count computation.

    Returns a jitted function-of-nothing whose output shardings tile C over
    (data, model); call ``.lower().compile()`` for the dry-run or execute on
    a real mesh. Entry (bucket-width) dim is sharded over 'pod' when present.
    """
    has_pod = "pod" in mesh.axis_names
    K, S, w = v_ksw.shape

    # pad the entry width to a multiple of the pod axis (zero columns are
    # inert: they contribute 0 to every co-occurrence count)
    if has_pod:
        pods = mesh.shape["pod"]
        w_pad = (-w) % pods
        if w_pad:
            v_ksw = np.pad(np.asarray(v_ksw), ((0, 0), (0, 0), (0, w_pad)))
            w += w_pad

    # (S, K, w) layouts so the S dim is leading for row/col sharding
    v_skw = jnp.asarray(np.moveaxis(np.asarray(v_ksw), 0, 1))
    acc = jnp.asarray(acc, jnp.float32)
    p_hat_a = jnp.asarray(p_hat, jnp.float32)

    e_axis = "pod" if has_pod else None
    spec_r = P("data", None, e_axis)
    spec_c = P("model", None, e_axis)
    out_spec = P("data", "model")

    shard_fn = jax.jit(
        jax.shard_map(
            partial(_local_pair_scores, s=cfg.s, n=cfg.n, has_pod=has_pod),
            mesh=mesh,
            in_specs=(spec_r, spec_c, P("data"), P("model"),
                      P(None, e_axis) if has_pod else P(None, None)),
            out_specs=(out_spec, out_spec),
        ),
        in_shardings=(
            NamedSharding(mesh, spec_r), NamedSharding(mesh, spec_c),
            NamedSharding(mesh, P("data")), NamedSharding(mesh, P("model")),
            NamedSharding(mesh, P(None, e_axis) if has_pod else P(None, None)),
        ),
        out_shardings=(NamedSharding(mesh, out_spec), NamedSharding(mesh, out_spec)),
    )

    # p_hat must broadcast per (K, w_local) — expand to (K, w) so the entry
    # shard picks the right representative for its slice
    p_kw = jnp.broadcast_to(p_hat_a[:, None], (K, w))

    def run():
        return shard_fn(v_skw, v_skw, acc, acc, p_kw)

    def lower():
        args = (
            jax.ShapeDtypeStruct(v_skw.shape, v_skw.dtype),
            jax.ShapeDtypeStruct(v_skw.shape, v_skw.dtype),
            jax.ShapeDtypeStruct(acc.shape, acc.dtype),
            jax.ShapeDtypeStruct(acc.shape, acc.dtype),
            jax.ShapeDtypeStruct((K, w), jnp.float32),
        )
        return shard_fn.lower(*args)

    run.lower = lower
    return run
