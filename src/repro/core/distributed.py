"""Distributed copy detection — sharded decompositions of the pair space.

The paper's §VIII names two parallelization opportunities ("per entry" and
"per pair of sources"). This module realizes both, at two granularities:

  * ``sharded_tile_scores`` — the DetectionEngine's production dataflow
    (DESIGN.md §3): the S×S pair space is cut into T×T tiles, tiles that
    survive the Ē pruning are round-robined over a 1-D device mesh with
    shard_map, and each device scans its tiles, slicing the bucket-aligned
    incidence and feeding the copyscore kernel one rectangular tile at a
    time. The incidence tensor is replicated (it is the small operand);
    only the tile list and the (n_tiles, T, T) outputs are sharded.

  * ``distributed_pair_scores`` — 2-D pair-space sharding over the
    production TPU mesh (launch/mesh.py): C-block rows over ``data``,
    columns over ``model`` (a SUMMA-like decomposition), with the entry
    dimension optionally sharded over ``pod`` and combined by one psum.

The incidence matrix V is passed twice with different shardings (row-block
copy and column-block copy); XLA lays each out once per device — there is no
gather of the full V anywhere.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax ≥ 0.6 exposes shard_map at the top level
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map


def _mark_varying(x, axes):
    """pcast-to-varying where the API exists (jax ≥ 0.7, where shard_map
    checks that scan carries stay replicated otherwise); no-op before."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to="varying")
    return x

from repro.core.scoring import score_same
from repro.core.types import CopyConfig
from repro.kernels.ops import copyscore_tile_fused


# ---------------------------------------------------------------------------
# 1-D tile sharding (DetectionEngine production path)
# ---------------------------------------------------------------------------

def _local_tile_scores(v_skw, acc, p_hat, delta, nout_blk, coords, *, tile,
                       s, n, impl, block_i, block_j):
    """Per-device: scan this shard's unordered pair tiles (fused dual kernel).

    v_skw:  (S_pad, K, w) chunk-aligned incidence, replicated — K chunks of
            the ``CorpusStore`` (one group of the engine's stream)
    nout_blk: (K,) float32 — 1.0 where the chunk lies before the Ē
            boundary (chunk handles carry this; the boundary is
            chunk-aligned by construction, so the channel is exact)
    coords: (n_local, 2) int32 — (row-block, col-block) indices of the tiles
            assigned to this device, r ≤ c (triangular schedule); (-1, -1)
            marks a padding slot — both mesh padding AND tiles chunk-pruned
            for this group — which produces zeros without any compute
    →       five (n_local, T, T) stacks: C_same→, C_same← (the mirrored
            tile's C→, transposed), shared count, count outside Ē (the
            considered test), and the approximation-error bound.
    """
    S_pad, K, w = v_skw.shape

    def compute(rc):
        r0 = rc[0] * tile
        c0 = rc[1] * tile
        vr = jax.lax.dynamic_slice(v_skw, (r0, 0, 0), (tile, K, w))
        vc = jax.lax.dynamic_slice(v_skw, (c0, 0, 0), (tile, K, w))
        a_r = jax.lax.dynamic_slice(acc, (r0,), (tile,))
        a_c = jax.lax.dynamic_slice(acc, (c0,), (tile,))
        return copyscore_tile_fused(
            vr.reshape(tile, K * w), vc.reshape(tile, K * w), p_hat, a_r, a_c,
            s=s, n_false=n, block_i=block_i, block_j=block_j, block_e=w,
            impl=impl, delta_blk=delta, nout_blk=nout_blk)

    def skip(rc):
        del rc
        return (jnp.zeros((tile, tile), jnp.float32),) * 5

    def one_tile(_, rc):
        return 0, jax.lax.cond(rc[0] >= 0, compute, skip, rc)

    _, outs = jax.lax.scan(one_tile, 0, coords)
    return outs


def sharded_tile_scores(
    mesh: Mesh,
    v_skw,                   # (S_pad, K, w) incidence, S_pad % tile == 0
    acc,                     # (S_pad,) accuracies (0.5 in padding rows)
    p_hat,                   # (K,) representative p̂ per chunk
    coords: np.ndarray,      # (n_tiles, 2) int32 surviving (row, col) tiles
    cfg: CopyConfig,
    *,
    tile: int,
    delta: np.ndarray,       # (K,) per-chunk score-error bound δ
    nout: np.ndarray = None,  # (K,) 1.0 ⇔ chunk before the Ē boundary
    ebar_bucket: int | None = None,   # legacy alternative to ``nout``
    impl: str = "auto",
    block_i: int = 128,
    block_j: int = 128,
    donate: bool = False,
):
    """Shard surviving pair tiles over a 1-D mesh; returns stacked tiles.

    The incidence argument is one GROUP of chunk handles from the engine's
    stream — (S_pad, K, w) with per-chunk p̂ / δ / non-Ē arrays riding
    along — never the full matrix (DESIGN.md §6). ``coords`` lists
    unordered (r ≤ c) tiles and is padded to a multiple of the mesh size
    with (-1, -1) markers — padding slots (and tiles the caller chunk-pruned
    for this group) short-circuit to zero outputs inside the device scan
    (lax.cond) instead of recomputing a real tile. Output: five
    (n_tiles_padded, T, T) arrays (C_same→, C_same←, count, count outside
    Ē, error bound).

    ``donate=True`` donates the v-slab buffer to the call (the prefetched
    double-buffered stream never reuses a group's slab, so XLA may recycle
    it in place). Keep it off on CPU — unusable-donation warnings.
    """
    axis = mesh.axis_names[0]
    n_dev = mesh.shape[axis]
    n_tiles = len(coords)
    K = v_skw.shape[1]
    if nout is None:
        eb = K if ebar_bucket is None else int(ebar_bucket)
        nout = (np.arange(K) < eb).astype(np.float32)
    pad = (-n_tiles) % n_dev
    if pad:
        coords = np.concatenate([coords,
                                 np.full((pad, 2), -1, coords.dtype)])

    fn = _sharded_tile_fn(mesh, tile, cfg.s, cfg.n, impl, block_i, block_j,
                          donate)
    return fn(jnp.asarray(v_skw), jnp.asarray(acc, jnp.float32),
              jnp.asarray(p_hat, jnp.float32),
              jnp.asarray(delta, jnp.float32),
              jnp.asarray(nout, jnp.float32),
              jnp.asarray(coords, jnp.int32))


@functools.lru_cache(maxsize=64)
def _sharded_tile_fn(mesh: Mesh, tile: int, s: float, n: float, impl: str,
                     block_i: int, block_j: int, donate: bool = False):
    """Cached jitted shard_map for the tile scan.

    The engine streams chunk groups through this in a host loop, so the
    compiled executable MUST be reused across calls — a fresh
    ``jax.jit(shard_map(...))`` per group would retrace every time.
    ``donate`` releases the v-slab argument's buffer to XLA (argument 0).
    """
    axis = mesh.axis_names[0]
    local = partial(_local_tile_scores, tile=tile, s=s, n=n,
                    impl=impl, block_i=block_i, block_j=block_j)
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(axis)),
        out_specs=(P(axis),) * 5,
    ), donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# 2-D tile sharding: tiles over `data`, entry chunks over `pod`
# ---------------------------------------------------------------------------

def _local_tile_scores_2d(v_skw, acc, p_hat, delta, nout_blk, coords, *,
                          tile, s, n, impl, block_i, block_j, pod_axis):
    """Per-device: scan this data-shard's tiles over the local chunk shard,
    then one psum over ``pod`` combines the per-chunk partial channels."""
    outs = _local_tile_scores(v_skw, acc, p_hat, delta, nout_blk, coords,
                              tile=tile, s=s, n=n, impl=impl,
                              block_i=block_i, block_j=block_j)
    return tuple(jax.lax.psum(o, pod_axis) for o in outs)


def sharded_tile_scores_2d(
    mesh: Mesh,
    v_skw,                   # (S_pad, K, w) incidence, S_pad % tile == 0
    acc,                     # (S_pad,) accuracies (0.5 in padding rows)
    p_hat,                   # (K,) representative p̂ per chunk
    coords: np.ndarray,      # (n_tiles, 2) int32 surviving (row, col) tiles
    cfg: CopyConfig,
    *,
    tile: int,
    delta: np.ndarray,       # (K,) per-chunk score-error bound δ
    nout: np.ndarray = None,  # (K,) 1.0 ⇔ chunk before the Ē boundary
    impl: str = "auto",
    block_i: int = 128,
    block_j: int = 128,
):
    """Shard tiles over ``data`` AND entry chunks over ``pod`` (2-D mesh).

    Same contract as ``sharded_tile_scores``, but each pod member scans
    only its chunk slice of the group and one psum per channel combines
    the partial sums — so a group's resident incidence per device is
    K/pods chunks instead of K. Chunks are padded to a pod multiple with
    INERT chunks (zero incidence, δ = 0, non-Ē flag 0): a zero chunk
    contributes exactly zero to all five channels, so the padding never
    perturbs a result. The psum reorders float additions relative to the
    1-D stream, which the engine's rescore margin absorbs — decisions
    stay bit-equal (DESIGN.md §3.4, §10).
    """
    d_axis, p_axis = mesh.axis_names
    n_data = mesh.shape[d_axis]
    del n_data  # coords padding below keys off the mesh size directly
    n_pod = mesh.shape[p_axis]
    v_skw = np.asarray(v_skw)
    S_pad, K, w = v_skw.shape
    p_hat = np.asarray(p_hat, np.float32)
    delta = np.asarray(delta, np.float32)
    nout = (np.ones(K, np.float32) if nout is None
            else np.asarray(nout, np.float32))
    kpad = (-K) % n_pod
    if kpad:
        v_skw = np.concatenate(
            [v_skw, np.zeros((S_pad, kpad, w), v_skw.dtype)], axis=1)
        p_hat = np.concatenate([p_hat, np.full(kpad, 0.5, np.float32)])
        delta = np.concatenate([delta, np.zeros(kpad, np.float32)])
        nout = np.concatenate([nout, np.zeros(kpad, np.float32)])
    n_tiles = len(coords)
    pad = (-n_tiles) % mesh.shape[d_axis]
    if pad:
        coords = np.concatenate([coords,
                                 np.full((pad, 2), -1, coords.dtype)])
    fn = _sharded_tile_fn_2d(mesh, tile, cfg.s, cfg.n, impl,
                             block_i, block_j)
    return fn(jnp.asarray(v_skw), jnp.asarray(acc, jnp.float32),
              jnp.asarray(p_hat), jnp.asarray(delta), jnp.asarray(nout),
              jnp.asarray(coords, jnp.int32))


@functools.lru_cache(maxsize=64)
def _sharded_tile_fn_2d(mesh: Mesh, tile: int, s: float, n: float,
                        impl: str, block_i: int, block_j: int):
    """Cached jitted shard_map for the 2-D (data×pod) tile scan."""
    d_axis, p_axis = mesh.axis_names
    local = partial(_local_tile_scores_2d, tile=tile, s=s, n=n, impl=impl,
                    block_i=block_i, block_j=block_j, pod_axis=p_axis)
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(None, p_axis, None), P(), P(p_axis), P(p_axis),
                  P(p_axis), P(d_axis)),
        out_specs=(P(d_axis),) * 5,
    ))


# ---------------------------------------------------------------------------
# 2-D pair-space sharding (production TPU mesh)
# ---------------------------------------------------------------------------

def _local_pair_scores(vr, vc, acc_r, acc_c, p_hat, s, n, has_pod):
    """Per-device: C_same→ tile + shared-count tile over the local entry shard.

    vr: (S_r, K, w) row-block incidence (entry shard local)
    vc: (S_c, K, w) column-block incidence
    """
    f_a1 = acc_r[:, None]
    f_a2 = acc_c[None, :]

    def body(carry, xs):
        c_same, n_cnt = carry
        vr_k, vc_k, p_k = xs
        if vr_k.dtype == jnp.int8:
            # int8 incidence (§Perf H3): halves HBM traffic vs bf16; the MXU
            # accumulates 0/1 products exactly in int32
            count = jnp.dot(vr_k, vc_k.T,
                            preferred_element_type=jnp.int32).astype(jnp.float32)
        else:
            count = jnp.dot(vr_k, vc_k.T, preferred_element_type=jnp.float32)
        # p is constant within a bucket ⇒ any local representative works
        f = score_same(p_k[0], f_a1, f_a2, s, n)
        return (c_same + f * count, n_cnt + count), None

    S_r = vr.shape[0]
    S_c = vc.shape[0]
    # the accumulators are device-varying over the pair-tile axes — mark them
    varying = ("data", "model") + (("pod",) if has_pod else ())
    zero = _mark_varying(jnp.zeros((S_r, S_c), jnp.float32), varying)
    (c_same, n_cnt), _ = jax.lax.scan(
        body, (zero, zero), (jnp.moveaxis(vr, 1, 0), jnp.moveaxis(vc, 1, 0), p_hat))
    if has_pod:
        c_same = jax.lax.psum(c_same, "pod")
        n_cnt = jax.lax.psum(n_cnt, "pod")
    return c_same, n_cnt


def distributed_pair_scores_lowerable(mesh: Mesh, n_sources: int, K: int,
                                      width: int, cfg: CopyConfig,
                                      dtype=jnp.bfloat16):
    """Shapes-only variant for the dry-run: returns a Lowered without ever
    materializing the (K, S, w) incidence tensor (which at 1M-source scale
    would be hundreds of GB on the host)."""
    has_pod = "pod" in mesh.axis_names
    if has_pod:
        width += (-width) % mesh.shape["pod"]
    e_axis = "pod" if has_pod else None
    spec_r = P("data", None, e_axis)
    spec_c = P("model", None, e_axis)
    out_spec = P("data", "model")
    shard_fn = jax.jit(
        shard_map(
            partial(_local_pair_scores, s=cfg.s, n=cfg.n, has_pod=has_pod),
            mesh=mesh,
            in_specs=(spec_r, spec_c, P("data"), P("model"),
                      P(None, e_axis) if has_pod else P(None, None)),
            out_specs=(out_spec, out_spec),
        ),
        in_shardings=(
            NamedSharding(mesh, spec_r), NamedSharding(mesh, spec_c),
            NamedSharding(mesh, P("data")), NamedSharding(mesh, P("model")),
            NamedSharding(mesh, P(None, e_axis) if has_pod else P(None, None)),
        ),
        out_shardings=(NamedSharding(mesh, out_spec),
                       NamedSharding(mesh, out_spec)),
    )
    v_sds = jax.ShapeDtypeStruct((n_sources, K, width), dtype)
    acc_sds = jax.ShapeDtypeStruct((n_sources,), jnp.float32)
    p_sds = jax.ShapeDtypeStruct((K, width), jnp.float32)
    return shard_fn.lower(v_sds, v_sds, acc_sds, acc_sds, p_sds)


def distributed_pair_scores(
    mesh: Mesh,
    v_ksw: np.ndarray,          # (K, S, w) bucketed incidence (bf16/f32)
    p_hat: np.ndarray,          # (K,)
    acc: np.ndarray,            # (S,)
    cfg: CopyConfig,
):
    """Lowerable distributed C_same→/count computation.

    Returns a jitted function-of-nothing whose output shardings tile C over
    (data, model); call ``.lower().compile()`` for the dry-run or execute on
    a real mesh. Entry (bucket-width) dim is sharded over 'pod' when present.
    """
    has_pod = "pod" in mesh.axis_names
    K, S, w = v_ksw.shape

    # pad the entry width to a multiple of the pod axis (zero columns are
    # inert: they contribute 0 to every co-occurrence count)
    if has_pod:
        pods = mesh.shape["pod"]
        w_pad = (-w) % pods
        if w_pad:
            v_ksw = np.pad(np.asarray(v_ksw), ((0, 0), (0, 0), (0, w_pad)))
            w += w_pad

    # (S, K, w) layouts so the S dim is leading for row/col sharding
    v_skw = jnp.asarray(np.moveaxis(np.asarray(v_ksw), 0, 1))
    acc = jnp.asarray(acc, jnp.float32)
    p_hat_a = jnp.asarray(p_hat, jnp.float32)

    e_axis = "pod" if has_pod else None
    spec_r = P("data", None, e_axis)
    spec_c = P("model", None, e_axis)
    out_spec = P("data", "model")

    shard_fn = jax.jit(
        shard_map(
            partial(_local_pair_scores, s=cfg.s, n=cfg.n, has_pod=has_pod),
            mesh=mesh,
            in_specs=(spec_r, spec_c, P("data"), P("model"),
                      P(None, e_axis) if has_pod else P(None, None)),
            out_specs=(out_spec, out_spec),
        ),
        in_shardings=(
            NamedSharding(mesh, spec_r), NamedSharding(mesh, spec_c),
            NamedSharding(mesh, P("data")), NamedSharding(mesh, P("model")),
            NamedSharding(mesh, P(None, e_axis) if has_pod else P(None, None)),
        ),
        out_shardings=(NamedSharding(mesh, out_spec), NamedSharding(mesh, out_spec)),
    )

    # p_hat must broadcast per (K, w_local) — expand to (K, w) so the entry
    # shard picks the right representative for its slice
    p_kw = jnp.broadcast_to(p_hat_a[:, None], (K, w))

    def run():
        """Execute the sharded pass and return (C_same→, count) tiles."""
        return shard_fn(v_skw, v_skw, acc, acc, p_kw)

    def lower():
        """Lower (without executing) for the compile-only dry-run path."""
        args = (
            jax.ShapeDtypeStruct(v_skw.shape, v_skw.dtype),
            jax.ShapeDtypeStruct(v_skw.shape, v_skw.dtype),
            jax.ShapeDtypeStruct(acc.shape, acc.dtype),
            jax.ShapeDtypeStruct(acc.shape, acc.dtype),
            jax.ShapeDtypeStruct((K, w), jnp.float32),
        )
        return shard_fn.lower(*args)

    run.lower = lower
    return run
