"""Core library: the paper's copy-detection algorithms in JAX.

Public API:
  CopyConfig, ClaimsDataset, DetectionResult    — data model
  DetectionEngine                               — THE detection entry point
                                                  (tiled + sharded; all modes)
  pairwise_detect                               — exhaustive baseline (§II-B)
  build_index, bucketize                        — inverted index (§III)
  index_detect_exact, bucketed_index_detect     — INDEX (§III)
  bound_detect, hybrid_detect                   — BOUND/BOUND+/HYBRID (§IV)
  make_incremental_state, incremental_detect    — INCREMENTAL (§V)
  truth_finding                                 — iterative fusion driver
  sample_by_item, sample_by_cell, scale_sample  — sampling (§VI)
  fagin_input                                   — NRA baseline (Table X)
  DetectRequest, DetectionService, serve_batch  — batched serving (DESIGN §5)
  CorpusStore, engine_chunks, ResidentCorpus    — chunked incidence store +
                                                  resident serving buffers
                                                  (DESIGN §6)
  ShardPlan, ShardedCorpusStore, shard_store    — row-range-sharded corpus
                                                  data plane: per-shard row
                                                  slices, spill/bitpack,
                                                  exact partial merge
                                                  (DESIGN §10)
  DurabilityOptions, CommitLog, RestoreInfo     — commit-log persistence +
                                                  snapshot/restore (DESIGN §8,
                                                  OPERATIONS.md)
  retract_rows, RetractInfo, RetractRecord      — source retraction: unwind
                                                  membership, GC orphans,
                                                  WAL replay (DESIGN §9.4)
  CircuitBreaker, DeadlineExceeded              — traffic hardening: commit
                                                  circuit breaker, deadline
                                                  admission/expiry (DESIGN §9)

The per-algorithm functions remain as references and compatibility wrappers;
new code should construct a ``DetectionEngine`` with the mode it needs (or a
``DetectionService`` for concurrent corpus queries).
"""
from repro.core.bound import bound_detect, hybrid_detect
from repro.core.bucketed import bucketed_index_detect, index_detect_exact
from repro.core.engine import DetectionEngine, EngineOptions
from repro.core.fagin import fagin_input
from repro.core.incremental import (
    incremental_detect,
    make_incremental_state,
    rescore_pairs_exact,
)
from repro.core.index import (
    CommitInfo,
    RetractInfo,
    build_index,
    bucketize,
    commit_rows,
    compact_index,
    engine_chunks,
    retract_rows,
    rollback_commit,
)
from repro.core.sampling import sample_by_cell, sample_by_item, scale_sample
from repro.core.scoring import pairwise_detect
from repro.core.serving import (
    CircuitBreaker,
    DeadlineExceeded,
    DetectionService,
    DetectRequest,
    DetectResponse,
    ReplicaBroadcastError,
    ReplicaRouter,
    ResidentCorpus,
    ResultCache,
    ServiceOverloaded,
    ServiceStopped,
    serve_batch,
)
from repro.core.shardplan import (
    SealedShardError,
    ShardPlan,
    ShardScanError,
    ShardedCorpusStore,
    SpillCorruptionError,
    make_shard_plan,
    merge_shard_partials,
    rebalance_plan,
    shard_store,
)
from repro.core.store import (
    CorpusStore,
    PackedBlock,
    pack_membership,
    packed_count_matmul,
    unpack_membership,
)
from repro.core.wal import (
    CommitLog,
    CommitRecord,
    DurabilityOptions,
    NoValidSnapshotError,
    ReplayDivergenceError,
    RestoreInfo,
    RetractRecord,
)
from repro.core.truthfind import fusion_accuracy, truth_finding
from repro.core.types import (
    ClaimsDataset,
    CopyConfig,
    DetectionResult,
    claim_value_keys,
    pair_f_measure,
)

__all__ = [
    "CopyConfig", "ClaimsDataset", "DetectionResult", "pair_f_measure",
    "claim_value_keys",
    "DetectionEngine", "EngineOptions", "CorpusStore",
    "ShardPlan", "ShardedCorpusStore", "shard_store", "make_shard_plan",
    "rebalance_plan", "merge_shard_partials", "ShardScanError",
    "SealedShardError", "SpillCorruptionError",
    "PackedBlock", "pack_membership", "unpack_membership",
    "packed_count_matmul",
    "DetectRequest", "DetectResponse", "DetectionService", "ReplicaRouter",
    "ReplicaBroadcastError", "ResidentCorpus", "ResultCache", "serve_batch",
    "CircuitBreaker", "DeadlineExceeded", "ServiceOverloaded",
    "ServiceStopped",
    "DurabilityOptions", "CommitLog", "CommitRecord", "RestoreInfo",
    "NoValidSnapshotError", "ReplayDivergenceError", "RetractRecord",
    "pairwise_detect", "build_index", "bucketize", "engine_chunks",
    "commit_rows", "rollback_commit", "compact_index", "CommitInfo",
    "retract_rows", "RetractInfo",
    "index_detect_exact", "bucketed_index_detect",
    "bound_detect", "hybrid_detect",
    "make_incremental_state", "incremental_detect", "rescore_pairs_exact",
    "truth_finding", "fusion_accuracy",
    "sample_by_item", "sample_by_cell", "scale_sample",
    "fagin_input",
]
