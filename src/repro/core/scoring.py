"""Exact Bayesian pair scoring — Eqs. (2)–(8) of the paper.

This module is the *oracle*: the exhaustive PAIRWISE algorithm (§II-B) in a
vectorized form. Every scalable algorithm in this package (INDEX, BOUND,
HYBRID, INCREMENTAL, the Pallas kernel) is validated against it.

Conventions:
  C→[i, j] accumulates evidence that source i copies from source j
  ("S1 → S2" in the paper with S1 = i, S2 = j); the same-value contribution
  (Eq. 6) uses Pr(Φ_D(S2)) with S2 = j, the *copied* source. By symmetry of
  the observation, C←[i, j] = C→[j, i]: the backward matrix is the
  transpose, so we only ever materialize C→.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ClaimsDataset, CopyConfig, DetectionResult
from repro.utils.counters import ComputeCounter


# --------------------------------------------------------------------------
# Per-item contribution scores
# --------------------------------------------------------------------------

def pr_phi_source(p, a2):
    """Eq. (4): probability of observing S2's value — P·A2 + (1−P)(1−A2)."""
    return p * a2 + (1.0 - p) * (1.0 - a2)


def pr_independent(p, a1, a2, n):
    """Eq. (3): P·A1·A2 + (1−P)(1−A1)(1−A2)/n."""
    return p * a1 * a2 + (1.0 - p) * (1.0 - a1) * (1.0 - a2) / n


def score_same(p, a_copier, a_source, s, n):
    """Eq. (6): C→(D) for a shared value with truth probability p.

    a_copier = A(S1), a_source = A(S2).  Positive, larger for lower p.
    """
    ratio = pr_phi_source(p, a_source) / pr_independent(p, a_copier, a_source, n)
    return jnp.log(1.0 - s + s * ratio)


def score_same_np(p, a_copier, a_source, s, n):
    """NumPy twin of ``score_same`` (host-side index/bound bookkeeping)."""
    ratio = (p * a_source + (1 - p) * (1 - a_source)) / (
        p * a_copier * a_source + (1 - p) * (1 - a_copier) * (1 - a_source) / n
    )
    return np.log(1.0 - s + s * ratio)


# Inflation + slack on top of the sampled maximum of the δ sweep below: the
# accuracy sweep is a grid, not an analytic bound — |f(p) − f(p̂)| can peak at
# interior accuracies (≲2e-3/entry beyond the corner max at default s, n),
# and f's monotonicity in p is conditional (tests/test_properties.py).
DELTA_INFLATION = 1.5
DELTA_SLACK = 2e-3


def bucket_score_deltas(p_hat, p_lo, p_hi, acc: np.ndarray, cfg: CopyConfig,
                        inflation: float = DELTA_INFLATION,
                        slack: float = DELTA_SLACK) -> np.ndarray:
    """Per-bucket bound δ_k ≳ |f(A_i, A_j, p) − f(A_i, A_j, p̂_k)|.

    For any entry probability p in bucket k's [p_lo, p_hi] range: the
    extremes are swept against a grid of dataset accuracy quantiles, then
    inflated to cover interior maxima the grid misses. The sweep covers both
    role orders, so one δ_k bounds f→ and f← alike. Shared by the engine's
    tiled error channel (DESIGN.md §3.4) and BOUND's error-aware freezes
    (§2.2) — with it, accumulated Σ δ_k·count bounds the p̂ approximation of
    any pair score, which is what makes approximate decisions provably equal
    the exact INDEX for ANY bucketing or chunk layout (DESIGN.md §7).
    """
    a_grid = np.unique(np.quantile(acc.astype(np.float64),
                                   [0.0, 0.25, 0.5, 0.75, 1.0]))
    p_hat = np.asarray(p_hat, np.float64)
    delta = np.zeros(len(p_hat), np.float64)
    for a1 in a_grid:
        for a2 in a_grid:
            f_hat = score_same_np(p_hat, a1, a2, cfg.s, cfg.n)
            for pe in (np.asarray(p_lo, np.float64),
                       np.asarray(p_hi, np.float64)):
                f_edge = score_same_np(pe, a1, a2, cfg.s, cfg.n)
                delta = np.maximum(delta, np.abs(f_edge - f_hat))
    return (inflation * delta + slack).astype(np.float32)


def posterior_independence(c_fwd, c_bwd, cfg: CopyConfig):
    """Eq. (2) computed stably:  Pr(⊥|Φ) = σ(−(ln(α/β) + logaddexp(C→, C←)))."""
    log_ratio = np.log(cfg.alpha / cfg.beta)
    z = log_ratio + jnp.logaddexp(c_fwd, c_bwd)
    return jax.nn.sigmoid(-z)


def decide_copying(c_fwd, c_bwd, cfg: CopyConfig):
    """copying ⟺ Pr(⊥|Φ) ≤ .5 ⟺ ln(α/β) + logaddexp(C→, C←) ≥ 0."""
    return (np.log(cfg.alpha / cfg.beta) + jnp.logaddexp(c_fwd, c_bwd)) >= 0.0


def posterior_independence_np(c_fwd, c_bwd, cfg: CopyConfig):
    """NumPy twin of ``posterior_independence``; clips z to ±60 before the
    sigmoid so float32 never overflows. (S, S) in → (S, S) float32 out."""
    z = np.log(cfg.alpha / cfg.beta) + np.logaddexp(c_fwd, c_bwd)
    out = np.empty_like(z, dtype=np.float64)
    np.clip(z, -60.0, 60.0, out=out)
    return (1.0 / (1.0 + np.exp(out))).astype(np.float32)


def decide_copying_np(c_fwd, c_bwd, cfg: CopyConfig):
    """NumPy twin of ``decide_copying``: bool matrix, True ⟺ Pr(⊥|Φ) ≤ .5."""
    return (np.log(cfg.alpha / cfg.beta) + np.logaddexp(c_fwd, c_bwd)) >= 0.0


# --------------------------------------------------------------------------
# PAIRWISE — exhaustive detection (the paper's baseline, §II-B)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("s", "n"))
def _pairwise_block(vals_i, p_i, acc_i, vals_j, p_j, acc_j, s, n):
    """C→ for a (bi, bj) block of source pairs.

    vals_i (bi, D) int32, p_i (bi, D) — truth prob of the value i provides.
    Returns (bi, bj) C→ block:  i copies from j.
    """
    prov_i = (vals_i >= 0)[:, None, :]                    # (bi, 1, D)
    prov_j = (vals_j >= 0)[None, :, :]                    # (1, bj, D)
    shared = prov_i & prov_j
    same = shared & (vals_i[:, None, :] == vals_j[None, :, :])
    p = p_i[:, None, :]                                   # value prob (same value ⇒ same p)
    a1 = acc_i[:, None, None]
    a2 = acc_j[None, :, None]
    sc = score_same(p, a1, a2, s, n)                      # (bi, bj, D)
    ln1ms = jnp.log(1.0 - s)
    contrib = jnp.where(same, sc, jnp.where(shared, ln1ms, 0.0))
    return contrib.sum(axis=-1)


def pairwise_detect(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    block: int = 128,
) -> DetectionResult:
    """Exhaustive PAIRWISE copy detection. O(|S|²·|D|) work.

    p_claim[s, d]: probability that the value source s provides on item d is
    true (P(D.v) for v = values[s, d]); ignored where values[s, d] < 0.
    """
    t0 = time.perf_counter()
    S, D = ds.values.shape
    vals = jnp.asarray(ds.values)
    p = jnp.asarray(p_claim, dtype=jnp.float32)
    acc = jnp.asarray(ds.accuracy, dtype=jnp.float32)

    c_fwd = np.zeros((S, S), dtype=np.float32)
    for i0 in range(0, S, block):
        i1 = min(i0 + block, S)
        for j0 in range(0, S, block):
            j1 = min(j0 + block, S)
            blk = _pairwise_block(
                vals[i0:i1], p[i0:i1], acc[i0:i1],
                vals[j0:j1], p[j0:j1], acc[j0:j1],
                cfg.s, cfg.n,
            )
            c_fwd[i0:i1, j0:j1] = np.asarray(blk)
    np.fill_diagonal(c_fwd, 0.0)

    pr_ind = np.array(posterior_independence(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    copying = np.array(decide_copying(jnp.asarray(c_fwd), jnp.asarray(c_fwd.T), cfg))
    np.fill_diagonal(pr_ind, 1.0)
    np.fill_diagonal(copying, False)

    # Paper's computation accounting (Ex. 3.6): PAIRWISE examines every shared
    # item of every pair, 2 computations each (C→ and C←), over unordered pairs.
    prov = ds.provided_mask.astype(np.int64)
    l_counts = prov @ prov.T
    iu = np.triu_indices(S, k=1)
    shared_items = int(l_counts[iu].sum())
    counter = ComputeCounter(
        pairs_considered=S * (S - 1) // 2,
        shared_values_examined=shared_items,
        score_computations=2 * shared_items,
    )
    return DetectionResult(
        c_fwd=c_fwd,
        pr_independent=pr_ind,
        copying=copying,
        counter=counter,
        wall_time_s=time.perf_counter() - t0,
    )


def pair_scores_subset(
    ds: ClaimsDataset,
    p_claim: np.ndarray,
    cfg: CopyConfig,
    pairs_i: np.ndarray,
    pairs_j: np.ndarray,
) -> np.ndarray:
    """Exact C→ for an explicit list of pairs (used for near-threshold
    rescoring by the bucketed algorithms). Returns (n_pairs,) C→[i, j]."""
    vals = jnp.asarray(ds.values)
    p = jnp.asarray(p_claim, dtype=jnp.float32)
    acc = jnp.asarray(ds.accuracy, dtype=jnp.float32)
    return np.asarray(
        _pair_list_scores(vals, p, acc, jnp.asarray(pairs_i), jnp.asarray(pairs_j), cfg.s, cfg.n)
    )


@partial(jax.jit, static_argnames=("s", "n"))
def _pair_list_scores(vals, p, acc, pi, pj, s, n):
    vi, vj = vals[pi], vals[pj]                           # (P, D)
    shared = (vi >= 0) & (vj >= 0)
    same = shared & (vi == vj)
    sc = score_same(p[pi], acc[pi][:, None], acc[pj][:, None], s, n)
    contrib = jnp.where(same, sc, jnp.where(shared, jnp.log(1.0 - s), 0.0))
    return contrib.sum(axis=-1)
