"""Incremental per-chunk block-OR cache for tile∘chunk pruning (DESIGN.md §11).

The tiled engine prunes pair tiles per chunk with a block-OR reduction: for
chunk ``k``, ``g_k[b, e] = OR`` of the membership bits of entry ``e`` over
tile-row-block ``b``; ``chunk_keep[k] = (g_k @ g_k.T) > 0``. Before this
module every detect pass regathered all K reductions from scratch — O(S·E)
host work — even when the corpus changed by one commit of a few rows.

``BlockOrCache`` keeps the per-entry block incidence **over the committed
base store** (not the per-detect gathered store, whose column order changes
every pass) and updates it incrementally from the ``MutationDelta`` a
commit/retraction emits:

  * **commit** — membership is monotone under a commit (bits are only ever
    set, never cleared, and only in the appended rows), so OR-ing the new
    rows' bits into the trailing block rows of the ``touched`` entries is
    *exact*, not an approximation. Brand-new entry columns get a fresh
    full-column reduction (their provider sets span old rows too).
  * **retraction** — rows ≥ ``row_start`` compact upward, so every block
    row ≥ ``row_start // tile`` is recomputed from the post-retraction
    store (one slab per chunk, not the whole corpus) and GC'd columns are
    zeroed everywhere.

Validity is anchored on ``store.mseq`` — a globally monotonic
mutation-sequence number that snapshot *restores* refresh rather than
rewind, so a (store, mseq) pair can never name two different bit states
(see ``store.next_mseq``). Any mismatch, or a compaction (``full=True``
delta), just marks the cache stale; the next detect pass rebuilds it as a
zero-extra-cost side product of its fresh block-OR loop.

At detect time the engine derives each *gathered* chunk's mask by
permuting cached base columns through ``EngineChunks.order`` — gathered
column ``j`` is base column ``order[j]`` over the same rows (−1 markers are
inert zero columns), so the permuted mask is bit-equal to a fresh
reduction of the gathered chunk.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def _chunk_width(store, c: int) -> int:
    """Column count of chunk ``c`` for either store flavor."""
    if hasattr(store, "chunks"):
        return int(store.chunks[c].shape[1])
    return int(store._widths[c])


def _rows_slab(store, c: int, r0: int, r1: int) -> np.ndarray:
    """Dense int8 ``(r1 − r0, width_c)`` row slab of chunk ``c``.

    Rows beyond the live range (or the chunk's capacity) read as zero, so
    tile-aligned requests are always safe.
    """
    if hasattr(store, "assemble_rows"):
        return store.assemble_rows(c, r0, r1)
    blk = store.chunks[c]
    out = np.zeros((r1 - r0, blk.shape[1]), np.int8)
    hi = min(r1, blk.shape[0])
    if hi > r0:
        out[: hi - r0] = blk[r0:hi]
    return out


def chunk_block_inc(store, c: int, tile: int, n_blocks: int) -> np.ndarray:
    """Fresh per-entry block-OR of chunk ``c`` — bool ``(n_blocks, width)``.

    The ONE full-chunk reduction entry point (the engine's cache-miss path
    and the cache's new-column fills both route through it, which is what
    the zero-regather regression test counts). Sharded stores reduce shard
    by shard (``block_or`` — no host assembles the full chunk); dense
    stores reshape-reduce the live rows.
    """
    if hasattr(store, "block_or"):
        return store.block_or(c, tile, n_blocks)
    blk = store.chunks[c]
    w = blk.shape[1]
    out = np.zeros((n_blocks, w), bool)
    nr = min(store.n_rows, n_blocks * tile)
    full = nr // tile
    if full:
        out[:full] = (blk[: full * tile] != 0).reshape(
            full, tile, w).any(axis=1)
    if full * tile < nr and full < n_blocks:
        out[full] = (blk[full * tile: nr] != 0).any(axis=0)
    return out


def cols_block_inc(store, c: int, cols: np.ndarray, tile: int,
                   n_blocks: int) -> np.ndarray:
    """Block-OR restricted to local columns ``cols`` of chunk ``c``.

    O(rows · |cols|) — the commit-apply path uses it to fill brand-new
    entry columns (whose provider sets span old rows) without ever paying
    a full-chunk regather (``chunk_block_inc``).
    """
    cols = np.asarray(cols, np.int64)
    if hasattr(store, "chunks") and not hasattr(store, "block_or"):
        blk = store.chunks[c]
        sub = np.zeros((n_blocks * tile, len(cols)), np.int8)
        nr = min(store.n_rows, blk.shape[0], n_blocks * tile)
        if nr > 0:
            sub[:nr] = blk[:nr, cols]
    else:
        sub = _rows_slab(store, c, 0, n_blocks * tile)[:, cols]
    return (sub != 0).reshape(n_blocks, tile, len(cols)).any(axis=1)


class BlockOrCache:
    """Per-entry tile-block incidence over one base store, delta-updated.

    ``block_inc[b, e]`` is True iff any row of tile-block ``b`` provides
    entry ``e``. ``blocks_updated`` accumulates the (entry, block) cells
    written by incremental applies — the O(touched) work counter the
    pipeline benchmark asserts against O(K·E) regathers.
    """

    def __init__(self, store, tile: int, mseq: int, block_inc: np.ndarray):
        """Wrap an already-computed incidence (the engine's adoption path)."""
        self.store = store
        self.tile = int(tile)
        self.mseq = int(mseq)
        self.block_inc = block_inc
        self.blocks_updated = 0
        self.stale = False

    @classmethod
    def build(cls, store, tile: int) -> "BlockOrCache":
        """Full build straight from a store (tests / standalone use)."""
        tile = int(tile)
        nb = -(-max(store.n_rows, 0) // tile)
        inc = np.zeros((nb, store.n_entries), bool)
        w = store.chunk_entries
        for c in range(store.n_chunks):
            g = chunk_block_inc(store, c, tile, nb)
            inc[:, c * w: c * w + g.shape[1]] = g
        return cls(store, tile, store.mseq, inc)

    def matches(self, store, tile: int) -> bool:
        """True when this cache is valid for ``store`` at ``tile``."""
        return (not self.stale and store is self.store
                and int(tile) == self.tile
                and self.mseq == getattr(store, "mseq", -1))

    def chunk_mask(self, order_slice: np.ndarray) -> np.ndarray:
        """Mask of a GATHERED chunk: column ``j`` = base column
        ``order_slice[j]`` (−1 markers are inert, all-False columns)."""
        order_slice = np.asarray(order_slice, np.int64)
        g = np.zeros((self.block_inc.shape[0], len(order_slice)), bool)
        live = order_slice >= 0
        if live.any():
            g[:, live] = self.block_inc[:, order_slice[live]]
        return g

    def apply(self, delta) -> Optional[tuple]:
        """Update from one ``MutationDelta``; returns an undo token.

        Commits return a token for ``undo`` (the serving layer's transient
        commit→detect→rollback path); retractions return None (applied on
        the permanent path only). Any mismatch — wrong ``from_mseq``,
        compaction (``full``), missing delta — marks the cache stale
        instead of guessing; the next detect rebuilds it.
        """
        if (delta is None or self.stale or delta.full
                or delta.from_mseq != self.mseq):
            self.stale = True
            return None
        if delta.kind == "commit":
            return self._apply_commit(delta)
        self._apply_retract(delta)
        return None

    def _apply_commit(self, delta) -> tuple:
        """Monotone OR update: new rows of touched + fresh new columns."""
        T = self.tile
        store = self.store
        nb_old, E_old = self.block_inc.shape
        rb0 = delta.from_rows // T
        nb_new = -(-delta.to_rows // T)
        undo = (rb0, (nb_old, E_old), self.block_inc[rb0:].copy())
        E_new = store.n_entries
        grown = np.zeros((nb_new, E_new), bool)
        grown[:nb_old, :E_old] = self.block_inc
        self.block_inc = grown
        cells = 0
        touched = np.asarray(delta.touched, np.int64)
        if len(touched) and nb_new > rb0:
            w = store.chunk_entries
            slab_rows = (nb_new - rb0) * T
            for cid in np.unique(touched // w):
                cols = touched[touched // w == cid]
                slab = _rows_slab(store, int(cid), rb0 * T, rb0 * T + slab_rows)
                sub = slab[:, cols - cid * w] != 0
                self.block_inc[rb0:, cols] |= sub.reshape(
                    nb_new - rb0, T, len(cols)).any(axis=1)
            cells += len(touched) * (nb_new - rb0)
        ns = delta.new_entry_start
        if 0 <= ns < E_new:
            w = store.chunk_entries
            for cid in range(ns // w, store.n_chunks):
                s0 = cid * w
                wc = _chunk_width(store, cid)
                lo = max(ns, s0)
                if lo >= s0 + wc:
                    continue
                local = np.arange(lo - s0, wc)
                self.block_inc[:, lo: s0 + wc] = cols_block_inc(
                    store, cid, local, T, nb_new)
                cells += len(local) * nb_new
        self.blocks_updated += cells
        self.mseq = delta.to_mseq
        return undo

    def _recompute_tail(self, to_rows: int, row_start: int) -> None:
        """Resize to ``to_rows`` and recompute block rows ≥ ``row_start``.

        The shared row-shrink primitive: columns truncate/grow to the
        store's CURRENT entry count, surviving leading block rows copy
        over, and every block row from ``row_start // tile`` on is
        recomputed from the store's current rows (one slab per chunk).
        """
        T = self.tile
        store = self.store
        nb_new = -(-to_rows // T) if to_rows > 0 else 0
        E = store.n_entries
        new_inc = np.zeros((nb_new, E), bool)
        keep = min(self.block_inc.shape[0], nb_new)
        new_inc[:keep] = self.block_inc[:keep, :E]
        self.block_inc = new_inc
        rb0 = row_start // T
        if nb_new > rb0:
            w = store.chunk_entries
            for cid in range(store.n_chunks):
                slab = _rows_slab(store, cid, rb0 * T, nb_new * T)
                wc = slab.shape[1]
                self.block_inc[rb0:, cid * w: cid * w + wc] = (
                    slab != 0).reshape(nb_new - rb0, T, wc).any(axis=1)
            self.blocks_updated += (nb_new - rb0) * E

    def _apply_retract(self, delta) -> None:
        """Zero GC'd columns; recompute every block row ≥ the first
        retracted row (compaction shifted everything after it up)."""
        self._recompute_tail(delta.to_rows, delta.row_start)
        gc = delta.gc_entries
        if gc is not None and len(gc):
            # deactivated columns zero everywhere, including rows < row_start
            # the tail recompute never touched
            self.block_inc[:, np.asarray(gc, np.int64)] = False
        self.mseq = delta.to_mseq

    def rebase(self, delta) -> None:
        """Re-anchor a cache ADOPTED DURING a transient commit onto the
        rolled-back base store.

        ``serve_batch`` commits the batch's rows transiently, detects, then
        rolls the index back — so a cache the detect pass adopts is
        anchored mid-transient (``mseq == delta.to_mseq``) and would die
        with the rollback. After ``rollback_commit`` restored the store,
        dropping the appended columns, shrinking back to the pre-commit
        block rows, and recomputing the one boundary block row yields the
        exact base-state incidence — the NEXT batch's transient commit then
        chains off it incrementally. Anything that doesn't match goes
        stale instead.
        """
        if (delta is None or self.stale or delta.kind != "commit"
                or delta.to_mseq != self.mseq):
            self.stale = True
            return
        self._recompute_tail(delta.from_rows, delta.row_start)
        self.mseq = getattr(self.store, "mseq", -1)

    def undo(self, token: Optional[tuple]) -> None:
        """Reverse a committed ``apply`` after the store was rolled back.

        Contract: call immediately after ``rollback_commit`` restored the
        store — the cache re-anchors on the store's (fresh) post-rollback
        ``mseq``, and the saved trailing block rows put the incidence back
        bit-exact. ``None`` tokens are no-ops.
        """
        if token is None:
            return
        rb0, (nb_old, E_old), tail = token
        blk = np.zeros((nb_old, E_old), bool)
        blk[:rb0] = self.block_inc[:rb0, :E_old]
        blk[rb0:] = tail
        self.block_inc = blk
        self.mseq = getattr(self.store, "mseq", -1)
        self.stale = False


__all__ = ["BlockOrCache", "chunk_block_inc", "cols_block_inc"]
