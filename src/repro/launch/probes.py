"""Trip-count-exact roofline probes.

XLA's ``cost_analysis`` tallies a while-loop body ONCE, so a scanned-layers
train step under-reports flops/bytes/collectives by the loop trip counts.
All our trip counts are static (grad-accum, segment layer counts, MoE expert
count, attention chunk count), so we measure the loop *bodies* directly and
assemble the true per-step terms analytically:

  train:   accum · [ Σ_kind count_k · block_k  +  embed_head_loss ]  +  optimizer
  prefill:            Σ_kind count_k · block_fwd_k + head_fwd
  decode:             Σ_kind count_k · block_dec_k + head_fwd

Each probe is lowered with the SAME shardings/mesh as the real artifact, so
its collective mix is the real per-layer mix. Probes unroll their own inner
loops (MoE experts, long-context attention chunks) so nothing inside them is
undercounted. The Mamba recurrence (a per-step scan too fine to unroll) is
added analytically: ~10 flops per (token · d_inner_local · state) forward,
2× backward — it is <1% of the mixer's projection flops at these shapes.

The real full-step artifact is still compiled separately (dryrun.py) — it is
the compile-coherence proof and the memory_analysis source; probes only
supply the roofline *rate* terms.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.models.moe as moe_mod
from repro.launch.roofline import analyze_compiled
from repro.models import Model
from repro.models.common import make_rope
from repro.models.transformer import (
    block_decode,
    block_forward,
    init_segment,
    init_segment_cache,
    segment_cache_dims,
    segment_dims,
)
from repro.optim import OPTIMIZERS
from repro.runtime.sharding import _dims_tree_specs, spec_for


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _terms(compiled):
    r = analyze_compiled(compiled, chips=1)
    return np.array([r["flops_per_device"], r["hbm_bytes_per_device"],
                     r["collective_bytes_per_device"]])


def _probe_cfg(cfg, seq_len):
    """Probe variant: unrolled chunked attention for long sequences."""
    if seq_len >= 8192:
        return cfg.replace(attention_impl="chunked_unroll")
    return cfg


def _act_spec(mesh, ndim, batch=None):
    """Batch-sharded activation spec with divisibility fallback (batch=1
    cells replicate)."""
    if batch is not None:
        dims = ("batch",) + tuple(f"d{i}" for i in range(ndim - 1))
        return spec_for(dims, (batch,) + (0,) * (ndim - 1), mesh, "act") \
            if batch else P(*(None,) * ndim)
    ba = _batch_axes(mesh)
    return P(ba, *(None,) * (ndim - 1))


def probe_block(cfg, kind, mesh, rows, seq_len, *, train=True, cond_rows=None):
    """Per-layer fwd(+bwd) terms for one block kind at the cell's shapes."""
    pcfg = _probe_cfg(cfg, seq_len)
    seg_shapes = jax.eval_shape(
        lambda k: init_segment(k, kind, 1, pcfg), jax.random.PRNGKey(0))
    seg_specs = _dims_tree_specs(seg_shapes, segment_dims(kind, pcfg), mesh,
                                 "param")
    x_sds = jax.ShapeDtypeStruct((rows, seq_len, cfg.d_model),
                                 jnp.bfloat16 if cfg.dtype == "bfloat16"
                                 else jnp.float32)
    x_spec = _act_spec(mesh, 3)
    args = [seg_shapes, x_sds]
    in_sh = [_named(seg_specs, mesh), NamedSharding(mesh, x_spec)]
    has_cond = kind == "cross"
    if has_cond:
        c_sds = jax.ShapeDtypeStruct((rows, cfg.cond_len, cfg.cond_dim),
                                     x_sds.dtype)
        args.append(c_sds)
        in_sh.append(NamedSharding(mesh, _act_spec(mesh, 3)))

    moe_mod.PROBE_UNROLL = True
    try:
        def fwd(seg_params, x, cond=None):
            p_l = jax.tree.map(lambda a: a[0], seg_params)
            rope = make_rope(jnp.arange(seq_len), pcfg.resolved_head_dim,
                             pcfg.rope_theta)
            y = block_forward(kind, p_l, x, rope, pcfg, cond=cond)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        if train:
            fn = jax.grad(fwd, argnums=(0, 1))
        else:
            fn = fwd
        compiled = jax.jit(fn, in_shardings=tuple(in_sh)).lower(*args).compile()
    finally:
        moe_mod.PROBE_UNROLL = False
    t = _terms(compiled)
    # analytic Mamba recurrence correction (inner per-token scan)
    if kind in ("ssm", "hybrid_swa", "hybrid_full"):
        di_loc = cfg.resolved_d_inner / mesh.shape["model"]
        rows_dev = max(rows / np.prod([mesh.shape[a] for a in _batch_axes(mesh)]), 1)
        rec = rows_dev * seq_len * di_loc * cfg.ssm_state * 10.0
        t[0] += rec * (3.0 if train else 1.0)          # fwd + bwd ≈ 2×
    return t


def probe_block_decode(cfg, kind, mesh, batch, seq_len):
    """Per-layer one-token decode terms (cache update + masked attention)."""
    seg_shapes = jax.eval_shape(
        lambda k: init_segment(k, kind, 1, cfg), jax.random.PRNGKey(0))
    seg_specs = _dims_tree_specs(seg_shapes, segment_dims(kind, cfg), mesh,
                                 "param")
    cache_shapes = jax.eval_shape(
        lambda: init_segment_cache(kind, 1, cfg, batch, seq_len))
    cache_specs = _dims_tree_specs(cache_shapes, segment_cache_dims(kind),
                                   mesh, "act")
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x_sds = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), dt)
    args = [seg_shapes, cache_shapes, x_sds, jax.ShapeDtypeStruct((), jnp.int32)]
    in_sh = [_named(seg_specs, mesh), _named(cache_specs, mesh),
             NamedSharding(mesh, _act_spec(mesh, 3, batch=batch)),
             NamedSharding(mesh, P())]
    kwargs = {}
    if kind == "cross":
        c_sds = jax.ShapeDtypeStruct((batch, cfg.cond_len, cfg.cond_dim), dt)
        args.append(c_sds)
        in_sh.append(NamedSharding(mesh, _act_spec(mesh, 3, batch=batch)))

    def fn(seg_params, cache, x, pos, cond=None):
        p_l = jax.tree.map(lambda a: a[0], seg_params)
        c_l = jax.tree.map(lambda a: a[0], cache)
        y, c = block_decode(kind, p_l, x, c_l, pos, cfg, cond=cond)
        return y, c

    moe_mod.PROBE_UNROLL = True
    try:
        compiled = jax.jit(fn, in_shardings=tuple(in_sh),
                           donate_argnums=(1,)).lower(*args).compile()
    finally:
        moe_mod.PROBE_UNROLL = False
    t = _terms(compiled)
    if kind in ("ssm", "hybrid_swa", "hybrid_full"):
        di_loc = cfg.resolved_d_inner / mesh.shape["model"]
        t[0] += batch * di_loc * cfg.ssm_state * 10.0
    return t


def probe_head(cfg, mesh, rows, seq_len, *, train=True):
    """Embedding lookup + final norm + logits + (xent + grads) terms."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    V, D = cfg.vocab_size, cfg.d_model
    embed_sds = jax.ShapeDtypeStruct((V, D), jnp.float32)
    head_sds = None if cfg.tie_embeddings else jax.ShapeDtypeStruct((D, V), jnp.float32)
    x_sds = jax.ShapeDtypeStruct((rows, seq_len, D), dt)
    tok_sds = jax.ShapeDtypeStruct((rows, seq_len), jnp.int32)

    embed_spec = spec_for(("vocab", "d_model"), (V, D), mesh, "param")
    head_spec = spec_for(("d_model", "vocab"), (D, V), mesh, "param")
    ba_spec2 = _act_spec(mesh, 2)

    def loss_fn(embed, head, x_mid, tokens, labels):
        x0 = jnp.take(embed, tokens, axis=0).astype(dt)
        x = x_mid + x0
        h = (embed.T if head is None else head)
        logits = x.astype(jnp.float32) @ h.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)

    if cfg.tie_embeddings:
        def f(embed, x_mid, tokens, labels):
            return loss_fn(embed, None, x_mid, tokens, labels)
        args = [embed_sds, x_sds, tok_sds, tok_sds]
        in_sh = [NamedSharding(mesh, embed_spec),
                 NamedSharding(mesh, _act_spec(mesh, 3)),
                 NamedSharding(mesh, ba_spec2), NamedSharding(mesh, ba_spec2)]
        fn = jax.grad(f, argnums=(0, 1)) if train else f
    else:
        f = loss_fn
        args = [embed_sds, head_sds, x_sds, tok_sds, tok_sds]
        in_sh = [NamedSharding(mesh, embed_spec), NamedSharding(mesh, head_spec),
                 NamedSharding(mesh, _act_spec(mesh, 3)),
                 NamedSharding(mesh, ba_spec2), NamedSharding(mesh, ba_spec2)]
        fn = jax.grad(f, argnums=(0, 1, 2)) if train else f
    compiled = jax.jit(fn, in_shardings=tuple(in_sh)).lower(*args).compile()
    return _terms(compiled)


def probe_head_decode(cfg, mesh, batch):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    V, D = cfg.vocab_size, cfg.d_model
    embed_sds = jax.ShapeDtypeStruct((V, D), jnp.float32)
    x_sds = jax.ShapeDtypeStruct((batch, D), dt)
    tok_sds = jax.ShapeDtypeStruct((batch,), jnp.int32)
    embed_spec = spec_for(("vocab", "d_model"), (V, D), mesh, "param")

    def f(embed, x, tokens):
        x0 = jnp.take(embed, tokens, axis=0).astype(dt)
        logits = (x + x0).astype(jnp.float32) @ embed.T.astype(jnp.float32)
        return logits

    in_sh = (NamedSharding(mesh, embed_spec),
             NamedSharding(mesh, _act_spec(mesh, 2, batch=batch)),
             NamedSharding(mesh, _act_spec(mesh, 1, batch=batch)))
    compiled = jax.jit(f, in_shardings=in_sh).lower(embed_sds, x_sds, tok_sds
                                                    ).compile()
    return _terms(compiled)


def probe_optimizer(cfg, mesh):
    model = Model(cfg)
    optimizer = OPTIMIZERS[cfg.optimizer]()
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shapes = jax.eval_shape(optimizer.init, param_shapes)
    pd = model.param_dims()
    p_specs = _dims_tree_specs(param_shapes, pd, mesh, "param")
    o_specs = _dims_tree_specs(
        opt_shapes,
        optimizer.state_dims(pd, has_master=cfg.param_dtype == "bfloat16"),
        mesh, "param")

    def f(params, opt, grads):
        new_p, new_o = optimizer.update(grads, opt, params,
                                        jnp.zeros((), jnp.int32), 1e-4)
        return new_p, new_o

    in_sh = (_named(p_specs, mesh), _named(o_specs, mesh), _named(p_specs, mesh))
    compiled = jax.jit(f, in_shardings=in_sh,
                       donate_argnums=(0, 1)).lower(
        param_shapes, opt_shapes, param_shapes).compile()
    return _terms(compiled)


def probe_cell_terms(cfg, shape, mesh, grad_accum: int = None) -> dict:
    """Assembled true per-step (flops, hbm bytes, collective bytes)/device."""
    dp = int(np.prod([mesh.shape[a] for a in _batch_axes(mesh)]))
    kinds = {}
    if shape.kind == "train":
        accum = grad_accum or max(shape.global_batch // dp, 1)
        rows = shape.global_batch // accum
        total = np.zeros(3)
        for kind, count in cfg.plan:
            if kind not in kinds:
                kinds[kind] = probe_block(cfg, kind, mesh, rows, shape.seq_len,
                                          train=True)
            total += kinds[kind] * count
        total += probe_head(cfg, mesh, rows, shape.seq_len, train=True)
        total *= accum
        total += probe_optimizer(cfg, mesh)
    elif shape.kind == "prefill":
        rows = shape.global_batch
        total = np.zeros(3)
        for kind, count in cfg.plan:
            if kind not in kinds:
                kinds[kind] = probe_block(cfg, kind, mesh, rows, shape.seq_len,
                                          train=False)
            total += kinds[kind] * count
        total += probe_head(cfg, mesh, rows, shape.seq_len, train=False)
    else:  # decode
        B = shape.global_batch
        total = np.zeros(3)
        for kind, count in cfg.plan:
            if kind not in kinds:
                kinds[kind] = probe_block_decode(cfg, kind, mesh, B,
                                                 shape.seq_len)
            total += kinds[kind] * count
        total += probe_head_decode(cfg, mesh, B)
    return {
        "flops_per_device": float(total[0]),
        "hbm_bytes_per_device": float(total[1]),
        "collective_bytes_per_device": float(total[2]),
        "per_kind": {k: v.tolist() for k, v in kinds.items()},
    }
