"""Training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --batch 8 --seq 128 --checkpoint-dir /tmp/ckpt

Full-size configs are launched the same way on a real TPU slice; on this CPU
container use --reduced. Fault tolerance (checkpoint/restart), straggler
monitoring, and fusion-weighted data sampling are wired in from the runtime.
"""
from __future__ import annotations

import argparse



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--fusion-weighted", action="store_true",
                    help="derive source weights via copy detection first")
    args = ap.parse_args()

    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.data.tokens import Prefetcher, batches, synthetic_corpus
    from repro.models import Model
    from repro.runtime.train_loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)

    corpus = synthetic_corpus(vocab_size=cfg.vocab_size, seed=0)
    src_w = doc_w = None
    if args.fusion_weighted:
        from repro.data.fusion_weights import fusion_weights
        src_w, doc_w, _ = fusion_weights(corpus)
        print(f"[train] fusion weights: src range "
              f"[{src_w.min():.2f}, {src_w.max():.2f}]")
    data = batches(corpus, args.batch, args.seq,
                   source_weights=src_w, doc_weights=doc_w)
    if args.grad_accum > 1:
        base = data

        def accum():
            import jax
            while True:
                ms = [next(base) for _ in range(args.grad_accum)]
                yield jax.tree.map(lambda *xs: jnp.stack(xs), *ms)
        data = accum()

    state, history = train(
        model, Prefetcher(data), steps=args.steps, peak_lr=args.lr,
        grad_accum=args.grad_accum, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    print(f"[train] finished at step {int(state['step'])}, "
          f"final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
