"""Roofline-term extraction from compiled (AOT) artifacts.

compute  = HLO_FLOPs_per_device / peak_FLOP/s          (cost_analysis is per
memory   = HLO_bytes_per_device / HBM_bw                SPMD module = per chip)
collective = collective_bytes_per_device / ICI_bw

collective_bytes: cost_analysis does not expose collectives, so we parse the
compiled HLO text and sum the *result-shape* bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(tuple results summed per component). This is a consistent wire-traffic
proxy: a ring all-reduce moves ~2× result bytes per device and an all-gather
~1× — constant factors that don't change which term dominates.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `bf16[2,4096,128]` — dtype + dims (scalar = empty dims)
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>.*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind result bytes summed over the module (one device)."""
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group("op")
        # async pairs appear as -start/-done; count each logical op once
        line = m.group(0)
        if "-done(" in line:
            continue
        per_kind[op] += _shape_bytes(m.group("result"))
        counts[op] += 1
    return {"bytes": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0          # 6·N_active·D (train) / 2·N_active·D
    useful_flops_ratio: float = 0.0   # MODEL_FLOPS / (chips · HLO_FLOPs)

    def finalize(self, chips: int):
        self.compute_s = self.flops_per_device / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops:
            self.useful_flops_ratio = self.model_flops / max(
                self.flops_per_device * chips, 1.0)
        return self


def analyze_compiled(compiled, chips: int, model_flops: float = 0.0) -> dict:
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    rl = Roofline(
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(coll["total_bytes"]),
        model_flops=model_flops,
    ).finalize(chips)
    out = asdict(rl)
    out["collectives"] = coll
    if mem is not None:
        out["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        }
        # donated inputs alias outputs; live bytes ≈ args + temp
        out["memory"]["per_device_gb"] = (
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30)
    return out


def sharded_bytes(shapes_tree, specs_tree, mesh) -> float:
    """Exact per-device bytes of a tree given its PartitionSpecs."""
    import jax
    import numpy as np

    flat_s, treedef = jax.tree_util.tree_flatten(shapes_tree)
    flat_p = treedef.flatten_up_to(specs_tree)
    total = 0.0
    for sds, spec in zip(flat_s, flat_p):
        shard = 1
        for entry in (spec or ()):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shard *= mesh.shape[a]
        total += np.prod(sds.shape) * sds.dtype.itemsize / shard
    return float(total)


def count_params(shapes_tree, active_expert_frac: float = 1.0,
                 expert_paths=("wg", "wu", "wd")) -> tuple[float, float]:
    """(total params, active params) from a ShapeDtypeStruct tree.

    Leaves reached under a 'moe' key have a leading expert dim; only
    top_k/E of them are active per token.
    """
    import jax

    total = active = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes_tree)
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        total += n
        if "moe" in keys and any(k in expert_paths for k in keys):
            active += n * active_expert_frac
        elif "embed" in keys or "lm_head" in keys:
            pass                                   # excluded from 6ND
        else:
            active += n
    return total, active


def model_flops_for(cfg, shape, total_params: float, active_params: float) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode)."""
    if shape.kind == "train":
        return 6.0 * active_params * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * active_params * shape.global_batch * shape.seq_len
    return 2.0 * active_params * shape.global_batch          # decode: 1 token
