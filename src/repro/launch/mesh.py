"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# TPU v5e hardware model (per chip) — roofline constants
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
