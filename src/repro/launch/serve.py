"""Serving CLI: batched greedy decoding with KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.model import greedy_decode

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    cond = None
    if cfg.cond_len:
        cond = jnp.asarray(rng.normal(0, 1, (args.batch, cfg.cond_len,
                                             cfg.cond_dim)), jnp.float32)
    t0 = time.time()
    out = greedy_decode(model, params, prompts, args.new_tokens, cond=cond)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.new_tokens)
    print(f"[serve] {out.shape} tokens in {dt:.1f}s "
          f"({total / dt:.0f} tok/s incl. compile)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
