"""Serving CLI: LM decoding and copy-detection serving.

  --task lm (default): batched greedy decoding with KV/SSM caches.

      PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
          --reduced --batch 4 --prompt-len 16 --new-tokens 32

  --task detect: the batched detection service (core/serving.py,
      DESIGN.md §5). A corpus is held in memory; concurrent requests — each
      a few query sources to be checked for copying against the corpus —
      are drained from a bounded queue and folded into ONE tiled
      DetectionEngine pass per batch, with per-request scatter of the
      decision matrix and backpressure at the submit edge. Run with
      XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
      sharded tile path on CPU.

      PYTHONPATH=src python -m repro.launch.serve --task detect \
          --sources 512 --items 1536 --requests 32 --batch-requests 8

      --mode sample_verify serves the sample-then-verify engine
      (DESIGN.md §4) instead of the exact bucketed path.

      --commit-accepted exercises the corpus-mutation path end-to-end
      (DESIGN.md §7): after the first wave, every served request's rows are
      committed into the live corpus (delta-chunk re-index, no rebuild) and
      the wave is re-served — repeats hit the invalidation-aware result
      cache — then ServiceStats (cache hit rate, delta-chunk count,
      re-index/compaction counters) are printed. --replicas N serves through
      a ReplicaRouter with epoch-consistent commit broadcast.

      --deadline-s attaches a per-request deadline (DESIGN.md §9): requests
      the admission controller predicts cannot be served in time are shed
      at submit, queued requests whose deadline passes expire typed, and
      the adaptive batch limit shrinks under pressure. Queue-wait
      percentiles, shed/expired counts, and the final batch limit are
      printed. --breaker-threshold / --breaker-cooldown-s tune the
      per-replica commit circuit breaker when --replicas > 1.

      --retract-last N retracts the N newest corpus rows after the serve
      (and after --commit-accepted, if given) and prints the retraction
      receipt — rows unwound, index entries touched/GC'd, cache
      invalidations — demonstrating the membership-unwind path without a
      rebuild.

      --state-dir makes the service durable (DESIGN.md §8, OPERATIONS.md):
      commits append to a fsync'd commit log and full snapshots land every
      --snapshot-every commits. When the directory already holds a manifest
      the service is RESTORED from it — latest valid snapshot + log-tail
      replay — instead of built from the synthetic corpus, and the restore
      receipt (snapshot epoch, replayed commits, discarded torn-tail bytes)
      is printed. With --replicas each replica persists under its own
      replica-<i>/ subdirectory.
"""
from __future__ import annotations

import argparse
import time


def serve_lm(args):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.model import greedy_decode

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    cond = None
    if cfg.cond_len:
        cond = jnp.asarray(rng.normal(0, 1, (args.batch, cfg.cond_len,
                                             cfg.cond_dim)), jnp.float32)
    t0 = time.time()
    out = greedy_decode(model, params, prompts, args.new_tokens, cond=cond)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.new_tokens)
    print(f"[serve] {out.shape} tokens in {dt:.1f}s "
          f"({total / dt:.0f} tok/s incl. compile)")
    print(out[:, :16])


def serve_detect(args):
    import os

    import jax
    import numpy as np
    from repro.core import CopyConfig, DurabilityOptions
    from repro.core.serving import (
        DeadlineExceeded,
        DetectRequest,
        DetectionService,
        ReplicaRouter,
        ServiceOverloaded,
    )
    from repro.data.claims import (
        SyntheticSpec,
        oracle_claim_probs,
        synthetic_claims,
        synthetic_query_rows,
    )

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    spec = SyntheticSpec(n_sources=args.sources, n_items=args.items,
                         coverage="book", n_cliques=max(3, args.sources // 40),
                         clique_size=3, clique_items=12, seed=0)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    q = args.rows_per_request
    vals, acc, pq, origins = synthetic_query_rows(
        sc, args.requests * q, seed=1)
    requests = [
        DetectRequest(rid=i, values=vals[i * q:(i + 1) * q],
                      accuracy=acc[i * q:(i + 1) * q],
                      p_claim=pq[i * q:(i + 1) * q],
                      deadline_s=args.deadline_s)
        for i in range(args.requests)
    ]
    service_kw = dict(
        mode=args.mode,
        max_batch_requests=args.batch_requests,
        max_pending_rows=args.max_pending_rows,
        tile=args.tile, devices=args.devices,
        prefetch_depth=args.prefetch_depth)
    if args.shards and args.shards > 1:
        # row-range-sharded corpus plane (DESIGN.md §10): each detection
        # pass scans per shard and merges; spill/bitpack bound residency
        service_kw.update(
            n_shards=args.shards, shard_pack=args.shard_pack,
            shard_spill_bytes=args.shard_spill_bytes,
            shard_spill_dir=args.shard_spill_dir)
    if args.mesh_shape:
        d, pod = (int(x) for x in args.mesh_shape.split("x"))
        service_kw["mesh_shape"] = (d, pod)
    if args.state_dir:
        service_kw["durability"] = DurabilityOptions(
            state_dir=args.state_dir, snapshot_every=args.snapshot_every)
    restorable = (args.state_dir and args.replicas <= 1
                  and not args.shard_owners and os.path.exists(
                      os.path.join(args.state_dir, "manifest.json")))
    if restorable:
        svc = DetectionService.restore(args.state_dir,
                                       devices=args.devices)
        ri = svc.restore_info
        print(f"[serve] restored {args.state_dir}: snapshot epoch "
              f"{ri.snapshot_epoch} + {ri.replayed_commits} replayed "
              f"commits in {ri.wall_s:.2f}s "
              f"({ri.discarded_bytes} torn-tail bytes discarded); "
              f"corpus {svc.resident.n_corpus} sources at epoch {svc.epoch}")
    elif args.shard_owners:
        # shard-owner fleet (DESIGN.md §12): each replica OWNS one row
        # range of a single shared sharded index; tiled fan-out modes
        # scatter the scan per owner and merge on the router
        svc = ReplicaRouter(sc.dataset, p, cfg,
                            shard_owners=args.shard_owners,
                            breaker_threshold=args.breaker_threshold,
                            breaker_cooldown_s=args.breaker_cooldown_s,
                            shard_pack=args.shard_pack,
                            shard_spill_bytes=args.shard_spill_bytes,
                            shard_spill_dir=args.shard_spill_dir,
                            **{k: v for k, v in service_kw.items()
                               if k not in ("n_shards", "shard_pack",
                                            "shard_spill_bytes",
                                            "shard_spill_dir")})
        print(f"[serve] shard-owner fleet: {args.shard_owners} owners, "
              f"placement {svc._owner_plan().bounds.tolist()}")
    elif args.replicas > 1:
        svc = ReplicaRouter(sc.dataset, p, cfg, n_replicas=args.replicas,
                            breaker_threshold=args.breaker_threshold,
                            breaker_cooldown_s=args.breaker_cooldown_s,
                            **service_kw)
    else:
        svc = DetectionService(sc.dataset, p, cfg, **service_kw)
    print(f"[serve] corpus {args.sources}×{args.items}, mode={args.mode}, "
          f"devices={args.devices or len(jax.devices())}, "
          f"replicas={args.replicas}, "
          f"batch≤{args.batch_requests} requests, "
          f"backpressure at {args.max_pending_rows} rows")

    def _services(s):
        return s.replicas if isinstance(s, ReplicaRouter) else [s]

    def _reset(s):
        # fresh stats AND caches so the timed run measures engine passes,
        # not warm-up leftovers
        for one in _services(s):
            one.stats = type(one.stats)()
            if one.cache is not None:
                one.cache = type(one.cache)(one.cache.max_entries)

    # warm-up with one full-size batch (the largest union shape) so the
    # timed run mostly excludes JIT compilation — odd-sized batches the
    # worker happens to drain can still compile once; capped at the
    # pending-row budget (nothing drains until the flush); reset stats so
    # the printed passes/mean-batch describe only the timed run
    n_warm = max(1, min(args.batch_requests, args.max_pending_rows // q))
    for r in requests[:n_warm]:
        # deadline-free clone: a tight --deadline-s must not shed the
        # warm-up, whose whole point is to absorb JIT compilation
        svc.submit(DetectRequest(rid=f"warm-{r.rid}", values=r.values,
                                 accuracy=r.accuracy, p_claim=r.p_claim))
    svc.flush()
    _reset(svc)

    shed = expired = 0
    t0 = time.perf_counter()
    with svc:
        pairs = []
        for r in requests:
            try:
                pairs.append((r, svc.submit(r)))
            except (DeadlineExceeded, ServiceOverloaded):
                shed += 1
        served, results = [], []
        for r, f in pairs:
            try:
                results.append(f.result())
                served.append(r)
            except DeadlineExceeded:
                expired += 1
    dt = time.perf_counter() - t0

    hits = planted = 0
    for r, resp in zip(served, results):
        for row in range(q):
            o = int(origins[r.rid * q + row])
            if o >= 0:
                planted += 1
                hits += int(resp.copying[row, o])
    print(f"[serve] {len(results)}/{len(requests)} requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} req/s), "
          f"{svc.stats.batches} engine passes "
          f"(mean batch {svc.stats.mean_batch:.1f})")
    if results:
        lat = np.array([r.latency_s for r in results])
        print(f"[serve] latency p50={np.percentile(lat, 50) * 1e3:.0f} ms "
              f"p99={np.percentile(lat, 99) * 1e3:.0f} ms; "
              f"planted copiers detected {hits}/{planted}")
    if args.shards and args.shards > 1:
        es = _services(svc)[0].engine.last_stats
        print(f"[serve] shard plane: {es.get('n_shards')} shards "
              f"{es.get('shard_plan')}, peak resident/shard "
              f"{es.get('shard_peak_resident_bytes')} bytes, "
              f"mesh={es.get('mesh_shape') or '1-D'}")
    if args.deadline_s is not None:
        st = svc.stats
        limits = [s._batch_limit for s in _services(svc)]
        print(f"[serve] deadline {args.deadline_s * 1e3:.0f} ms: "
              f"{shed} shed at submit, {expired} expired in queue; "
              f"queue wait p50={st.queue_wait_p50 * 1e3:.0f} ms "
              f"p99={st.queue_wait_p99 * 1e3:.0f} ms; "
              f"batch limit {max(limits)} "
              f"({st.batch_shrinks} shrinks, {st.batch_grows} grows)")

    if args.commit_accepted:
        # fold the ACCEPTED rows into the live corpus — rows detection
        # cleared of copying (copier rows are rejected; independent rows
        # carry fresh evidence) — then re-serve the same wave: repeats whose
        # claims no commit touched come straight from the result cache
        t0 = time.perf_counter()
        n_acc = 0
        for r, resp in zip(served, results):
            keep = ~resp.copying.any(axis=1) & ~resp.intra_copying.any(axis=1)
            if keep.any():
                svc.commit(r.values[keep], r.accuracy[keep], r.p_claim[keep])
                n_acc += int(keep.sum())
        t_commit = time.perf_counter() - t0
        t0 = time.perf_counter()
        with svc:
            futs = []
            for r in requests:
                try:
                    futs.append(svc.submit(r))
                except (DeadlineExceeded, ServiceOverloaded):
                    pass
            for f in futs:
                try:
                    f.result()
                except DeadlineExceeded:
                    pass
        t_wave2 = time.perf_counter() - t0
        st = svc.stats
        corpus_rows = max(s.resident.n_corpus for s in _services(svc))
        print(f"[serve] committed {n_acc} accepted rows in {t_commit:.2f}s "
              f"({st.commits} commits, corpus now {corpus_rows} sources); "
              f"re-served wave in {t_wave2:.2f}s")
        print(f"[serve] ServiceStats: cache_hit_rate="
              f"{st.cache_hit_rate:.1%} ({st.cache_hits} hits / "
              f"{st.cache_misses} misses, "
              f"{st.cache_invalidations} invalidations), "
              f"delta_chunks={st.delta_chunks}, "
              f"new_entries={st.new_entries}, "
              f"reindexed_entries={st.reindexed_entries}, "
              f"compactions={st.compactions}")

    if args.retract_last:
        n = max(s.resident.n_corpus for s in _services(svc))
        k = min(args.retract_last, n - 1)
        row_ids = list(range(n - k, n))
        t0 = time.perf_counter()
        out = svc.retract(row_ids)
        t_retract = time.perf_counter() - t0
        info = (next(i for i in out if i is not None)
                if isinstance(out, list) else out)
        st = svc.stats
        print(f"[serve] retracted {info.rows} newest rows in "
              f"{t_retract * 1e3:.1f} ms: {info.touched_entries} index "
              f"entries re-scored, {info.gc_entries} GC'd, "
              f"{st.cache_invalidations} cache invalidations; corpus now "
              f"{max(s.resident.n_corpus for s in _services(svc))} sources "
              f"at epoch {max(s.epoch for s in _services(svc))}")
        if args.replicas > 1:
            print(f"[serve] breaker: trips={st.breaker_trips} "
                  f"open_now={st.breaker_open}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=("lm", "detect"), default="lm")
    # lm args
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    # detect args
    ap.add_argument("--sources", type=int, default=256)
    ap.add_argument("--items", type=int, default=1024)
    ap.add_argument("--mode", default="bucketed",
                    help="DetectionEngine mode (bucketed, sample_verify, ...)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rows-per-request", type=int, default=4)
    ap.add_argument("--batch-requests", type=int, default=8,
                    help="requests folded into one engine pass")
    ap.add_argument("--max-pending-rows", type=int, default=256,
                    help="backpressure bound on queued query rows")
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="chunk groups the async pipeline stages ahead of "
                         "the tile kernel (DESIGN.md §11); 0 = synchronous")
    ap.add_argument("--platform", default=None,
                    help="JAX platform (cpu/gpu/tpu); on gpu also enables "
                         "the latency-hiding scheduler XLA flags")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="virtual host CPU devices "
                         "(--xla_force_host_platform_device_count)")
    ap.add_argument("--shards", type=int, default=None,
                    help="row-range shards of the corpus data plane "
                         "(DESIGN.md §10); each detection pass scans per "
                         "shard and merges bit-equal to unsharded")
    ap.add_argument("--shard-pack", action="store_true",
                    help="bitpack shard chunk blocks to 1 bit/entry "
                         "during scans (8x over int8)")
    ap.add_argument("--shard-spill-bytes", type=int, default=None,
                    help="per-shard resident byte cap; cold blocks spill "
                         "to checksummed frames (LRU)")
    ap.add_argument("--shard-spill-dir", default=None,
                    help="spill directory (default: a temp dir when a "
                         "byte cap is set)")
    ap.add_argument("--mesh-shape", default=None,
                    help="2-D tile mesh DATAxPOD (e.g. 4x2): tiles over "
                         "data, entry chunks over pod")
    ap.add_argument("--commit-accepted", action="store_true",
                    help="after the first wave, commit every served "
                         "request's rows into the live corpus (delta-chunk "
                         "re-index) and re-serve the wave; prints "
                         "ServiceStats incl. cache hit rate")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds: hopeless "
                         "requests are shed at submit, stale queued ones "
                         "expire typed (DESIGN.md §9)")
    ap.add_argument("--retract-last", type=int, default=0,
                    help="after serving, retract the N newest corpus rows "
                         "and print the retraction receipt")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a ReplicaRouter with this many "
                         "DetectionService replicas (commits broadcast)")
    ap.add_argument("--shard-owners", type=int, default=None,
                    help="shard-owner fleet (DESIGN.md §12): this many "
                         "replicas, each OWNING one row range of a shared "
                         "sharded index; tiled fan-out modes scatter the "
                         "scan per owner and the router merges partial "
                         "grids bit-equal to a single host")
    ap.add_argument("--breaker-threshold", type=int, default=5,
                    help="consecutive commit failures before a replica's "
                         "circuit breaker opens and it is ejected from "
                         "the broadcast (--replicas > 1)")
    ap.add_argument("--breaker-cooldown-s", type=float, default=5.0,
                    help="seconds an open breaker waits before probing "
                         "the replica with a catch-up replay")
    ap.add_argument("--state-dir", default=None,
                    help="durable state directory (commit log + snapshots); "
                         "restored from when it already holds a manifest")
    ap.add_argument("--snapshot-every", type=int, default=16,
                    help="write a full snapshot every N commits "
                         "(0 = only the initial snapshot)")
    args = ap.parse_args()
    # platform/flag setup must precede the first JAX call (the task
    # functions import jax lazily, so this is early enough)
    if args.platform or args.host_devices:
        from repro.runtime.platform import (set_host_device_count,
                                            set_platform)
        if args.platform:
            set_platform(args.platform)
        if args.host_devices:
            set_host_device_count(args.host_devices)
    if args.task == "detect":
        serve_detect(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
