"""Serving CLI: LM decoding and copy-detection serving.

  --task lm (default): batched greedy decoding with KV/SSM caches.

      PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
          --reduced --batch 4 --prompt-len 16 --new-tokens 32

  --task detect: serve iterative detection rounds through the
      DetectionEngine (the single detection entry point) — simulates a
      fusion service whose value probabilities drift between requests, so
      incremental mode only pays for the deltas. Run with
      XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
      sharded tile path on CPU.

      PYTHONPATH=src python -m repro.launch.serve --task detect \
          --sources 512 --items 1536 --mode incremental --requests 8
"""
from __future__ import annotations

import argparse
import time


def serve_lm(args):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.model import greedy_decode

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    cond = None
    if cfg.cond_len:
        cond = jnp.asarray(rng.normal(0, 1, (args.batch, cfg.cond_len,
                                             cfg.cond_dim)), jnp.float32)
    t0 = time.time()
    out = greedy_decode(model, params, prompts, args.new_tokens, cond=cond)
    dt = time.time() - t0
    total = args.batch * (args.prompt_len + args.new_tokens)
    print(f"[serve] {out.shape} tokens in {dt:.1f}s "
          f"({total / dt:.0f} tok/s incl. compile)")
    print(out[:, :16])


def serve_detect(args):
    import jax
    import numpy as np
    from repro.core import CopyConfig, DetectionEngine
    from repro.data.claims import (
        SyntheticSpec,
        oracle_claim_probs,
        synthetic_claims,
    )

    cfg = CopyConfig(alpha=0.1, s=0.8, n=50.0)
    spec = SyntheticSpec(n_sources=args.sources, n_items=args.items,
                         coverage="book", n_cliques=max(3, args.sources // 40),
                         clique_size=3, clique_items=12, seed=0)
    sc = synthetic_claims(spec)
    p = oracle_claim_probs(sc)
    engine = DetectionEngine(cfg, mode=args.mode, tile=args.tile,
                             devices=args.devices)
    n_pairs = args.sources * (args.sources - 1) // 2
    print(f"[serve] detection service: {args.sources} sources × {args.items} "
          f"items, mode={args.mode}, devices={args.devices or len(jax.devices())}")

    rng = np.random.default_rng(0)
    pk = p
    for req in range(args.requests):
        t0 = time.perf_counter()
        res = engine.detect(sc.dataset, pk)
        dt = time.perf_counter() - t0
        stats = engine.last_stats
        tiles = (f" tiles={stats['tiles_kept']}/{stats['tiles_total']}"
                 if stats else "")
        print(f"[serve] req {req}: {dt * 1e3:7.1f} ms "
              f"({n_pairs / max(dt, 1e-9):12.0f} pairs/s) "
              f"copying={len(res.copying_pairs())}{tiles}")
        # drift: the fusion loop refreshed value probabilities
        pk = np.clip(pk + np.where(pk > 0, rng.normal(0, 0.004, pk.shape), 0),
                     1e-3, 0.999).astype(np.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=("lm", "detect"), default="lm")
    # lm args
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    # detect args
    ap.add_argument("--sources", type=int, default=256)
    ap.add_argument("--items", type=int, default=1024)
    ap.add_argument("--mode", default="incremental",
                    help="DetectionEngine mode (bucketed, hybrid, incremental, ...)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--devices", type=int, default=None)
    args = ap.parse_args()
    if args.task == "detect":
        serve_detect(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
