import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count on first
# backend init. These two lines are the whole reason this file exists as the
# dry-run entry point — do not move them.

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

For each cell we build the real jitted program (train_step with optimizer
update and grad accumulation, or the one-token serve_step with its KV/SSM
caches), lower it against ShapeDtypeStruct stand-ins (zero allocation),
compile it for the production mesh, and extract:

  * memory_analysis()  — proves the per-device footprint fits a v5e,
  * cost_analysis()    — FLOPs / bytes for §Roofline,
  * HLO collective sizes — the collective roofline term.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun.json
  python -m repro.launch.dryrun --copyscore --mesh multi     # paper workload
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    analyze_compiled,
    count_params,
    model_flops_for,
)
from repro.models import Model
from repro.optim import OPTIMIZERS
from repro.optim.schedule import warmup_cosine
from repro.runtime.sharding import _dims_tree_specs, spec_for
from repro.runtime.train_loop import make_train_step, train_state_dims


def _named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def build_train(cfg, shape, mesh):
    """→ (lowered, chips, model_flops)."""
    model = Model(cfg)
    optimizer = OPTIMIZERS[cfg.optimizer]()
    dp = int(np.prod([mesh.shape[a] for a in _batch_axes(mesh)]))
    # one sequence per data shard per microbatch; accumulate to global batch
    grad_accum = max(shape.global_batch // dp, 1)
    micro = shape.global_batch // grad_accum

    lr_fn = warmup_cosine(3e-4, 100, 10_000)
    step = make_train_step(model, optimizer, lr_fn, grad_accum=grad_accum)

    state_shapes = jax.eval_shape(
        lambda k: {"params": model.init(k),
                   "opt": optimizer.init(model.init(k)),
                   "step": jnp.zeros((), jnp.int32)},
        jax.random.PRNGKey(0))
    state_specs = _dims_tree_specs(state_shapes,
                                   train_state_dims(model, optimizer),
                                   mesh, "param")

    ba = _batch_axes(mesh)
    def tok_spec(t):
        lead = () if grad_accum == 1 else (None,)
        return P(*lead, ba, *(None,) * (t.ndim - len(lead) - 1))

    bshape = ((grad_accum, micro, shape.seq_len) if grad_accum > 1
              else (micro, shape.seq_len))
    batch_shapes = {"tokens": jax.ShapeDtypeStruct(bshape, jnp.int32),
                    "labels": jax.ShapeDtypeStruct(bshape, jnp.int32)}
    if cfg.cond_len:
        cshape = ((grad_accum, micro, cfg.cond_len, cfg.cond_dim)
                  if grad_accum > 1 else (micro, cfg.cond_len, cfg.cond_dim))
        batch_shapes["cond"] = jax.ShapeDtypeStruct(cshape, jnp.bfloat16)
    batch_specs = {k: tok_spec(v) for k, v in batch_shapes.items()}

    jitted = jax.jit(
        step,
        in_shardings=(_named(state_specs, mesh), _named(batch_specs, mesh)),
        out_shardings=(_named(state_specs, mesh), None),
        donate_argnums=(0,),
    )
    lowered = jitted.lower(state_shapes, batch_shapes)
    total, active = count_params(state_shapes["params"],
                                 active_expert_frac=(cfg.top_k / cfg.n_experts
                                                     if cfg.n_experts else 1.0))
    mf = model_flops_for(cfg, shape, total, active)
    from repro.launch.roofline import sharded_bytes
    state_gb = sharded_bytes(state_shapes, state_specs, mesh) / 2**30
    # live working set ≈ state (params+opt, donated/aliased) + grads (bf16-ish
    # f32) + per-microbatch activations under remat (~8 residual-sized bufs/layer depth 1)
    act_gb = (micro * shape.seq_len * cfg.d_model * 4 * 8) / 2**30 / \
        max(np.prod([mesh.shape[a] for a in _batch_axes(mesh)]), 1)
    grads_gb = sharded_bytes(state_shapes["params"],
                             state_specs["params"], mesh) / 2**30
    return lowered, mesh.size, mf, {"grad_accum": grad_accum,
                                    "total_params": total,
                                    "active_params": active,
                                    "analytic_gb": {
                                        "state": round(state_gb, 2),
                                        "grads": round(grads_gb, 2),
                                        "activations": round(act_gb, 2),
                                        "total": round(state_gb + grads_gb
                                                       + act_gb, 2)}}


def build_serve(cfg, shape, mesh, prefill=False):
    model = Model(cfg)
    B = shape.global_batch

    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = _dims_tree_specs(param_shapes, model.param_dims(), mesh, "param")
    ba = _batch_axes(mesh)

    total, active = count_params(param_shapes,
                                 active_expert_frac=(cfg.top_k / cfg.n_experts
                                                     if cfg.n_experts else 1.0))
    mf = model_flops_for(cfg, shape, total, active)

    if prefill:
        def prefill_step(params, tokens, cond=None):
            return model.prefill(params, tokens, cond=cond)

        args = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), jnp.int32)}
        specs = {"tokens": P(ba, None)}
        if cfg.cond_len:
            args["cond"] = jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.cond_dim),
                                                jnp.bfloat16)
            specs["cond"] = P(ba, None, None)
        jitted = jax.jit(prefill_step,
                         in_shardings=(_named(p_specs, mesh),
                                       *(_named(specs[k], mesh) for k in args)),
                         )
        lowered = jitted.lower(param_shapes, *args.values())
        return lowered, mesh.size, mf, {"total_params": total,
                                        "active_params": active}

    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
    c_specs = _dims_tree_specs(cache_shapes, model.cache_dims(), mesh, "act")

    def serve_step(params, cache, tokens, pos, cond=None):
        return model.decode_step(params, cache, tokens, pos, cond=cond)

    tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    tok_spec = spec_for(("batch",), (B,), mesh, kind="act")
    in_sh = [_named(p_specs, mesh), _named(c_specs, mesh),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())]
    args = [param_shapes, cache_shapes, tok_sds, pos_sds]
    if cfg.cond_len:
        cond_sds = jax.ShapeDtypeStruct((B, cfg.cond_len, cfg.cond_dim),
                                        jnp.bfloat16)
        cond_spec = spec_for(("batch", "seq", "dm"),
                             cond_sds.shape, mesh, kind="act")
        in_sh.append(NamedSharding(mesh, cond_spec))
        args.append(cond_sds)

    jitted = jax.jit(serve_step, in_shardings=tuple(in_sh),
                     donate_argnums=(1,))
    lowered = jitted.lower(*args)
    return lowered, mesh.size, mf, {"total_params": total,
                                    "active_params": active}


def build_copyscore(mesh, n_sources=1_048_576 // 8, n_entries=2_097_152 // 4,
                    n_buckets=16):
    """The paper's own workload on the production mesh (launch/mesh.py;
    the 2-D pair-space decomposition of DESIGN.md §3.3):
    distributed bucketed pair scoring, entries sharded over pods.
    int8 incidence + K=16 buckets per §Perf H3 (9.73 s → 0.48 s memory term)."""
    from repro.core.distributed import distributed_pair_scores_lowerable
    from repro.core.types import CopyConfig

    K = n_buckets
    w = n_entries // K
    lowered = distributed_pair_scores_lowerable(mesh, n_sources, K, w,
                                                CopyConfig(), dtype=jnp.int8)
    flops = 2.0 * n_sources * n_sources * n_entries    # useful matmul flops
    return lowered, mesh.size, flops, {"n_sources": n_sources,
                                       "n_entries": n_entries,
                                       "n_buckets": K}


def run_cell(arch, shape_name, mesh_kind):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if arch == "copyscore":
        lowered, chips, mf, extra = build_copyscore(mesh)
    else:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "status": "skipped", "reason": why}
        if shape.kind == "train":
            lowered, chips, mf, extra = build_train(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered, chips, mf, extra = build_serve(cfg, shape, mesh, prefill=True)
        else:
            lowered, chips, mf, extra = build_serve(cfg, shape, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    result = analyze_compiled(compiled, chips, model_flops=mf)

    # the artifact proves compile-coherence and provides memory_analysis; the
    # roofline *rate* terms come from trip-count-exact probes (probes.py)
    result["artifact_raw"] = {k: result[k] for k in
                              ("flops_per_device", "hbm_bytes_per_device",
                               "collective_bytes_per_device")}
    if arch == "copyscore":
        # the bucket scan body is tallied once; scale flops/bytes by K
        # (the cross-pod psum sits outside the loop — counted once, correct)
        K = extra.get("n_buckets", 64)
        result["flops_per_device"] *= K
        result["hbm_bytes_per_device"] *= K
    else:
        from repro.launch.probes import probe_cell_terms
        probe = probe_cell_terms(get_config(arch), SHAPES[shape_name], mesh,
                                 grad_accum=extra.get("grad_accum"))
        result.update({k: probe[k] for k in
                       ("flops_per_device", "hbm_bytes_per_device",
                        "collective_bytes_per_device")})
        result["per_kind_terms"] = probe["per_kind"]
    # recompute the three terms from the corrected rates
    from repro.launch.roofline import Roofline
    rl = Roofline(result["flops_per_device"], result["hbm_bytes_per_device"],
                  result["collective_bytes_per_device"],
                  model_flops=mf).finalize(chips)
    result.update({"compute_s": rl.compute_s, "memory_s": rl.memory_s,
                   "collective_s": rl.collective_s,
                   "bottleneck": rl.bottleneck,
                   "useful_flops_ratio": rl.useful_flops_ratio})
    result.update({"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "chips": chips, "status": "ok",
                   "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
                   **extra})
    return result


def all_cells():
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--copyscore", action="store_true",
                    help="dry-run the paper's distributed copy-score workload")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    if args.all:
        # subprocess per cell: isolates compiler memory, resumable
        results = {}
        if args.out and os.path.exists(args.out):
            results = json.load(open(args.out))
        cells = [(a, s, m) for a, s in all_cells() for m in ("single", "multi")]
        cells += [("copyscore", "pairscore", m) for m in ("single", "multi")]
        for arch, shape_name, mesh_kind in cells:
            key = f"{arch}|{shape_name}|{mesh_kind}"
            if key in results and results[key].get("status") in ("ok", "skipped"):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--mesh", mesh_kind]
            cmd += (["--copyscore"] if arch == "copyscore"
                    else ["--arch", arch, "--shape", shape_name])
            print(f"[dryrun] {key} ...", flush=True)
            try:
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=args.timeout,
                                      env={**os.environ, "PYTHONPATH": "src"})
                line = [l for l in proc.stdout.splitlines()
                        if l.startswith("CELLRESULT")]
                if proc.returncode == 0 and line:
                    results[key] = json.loads(line[0][len("CELLRESULT"):])
                else:
                    results[key] = {"arch": arch, "shape": shape_name,
                                    "mesh": mesh_kind, "status": "error",
                                    "error": (proc.stderr or proc.stdout)[-2000:]}
            except subprocess.TimeoutExpired:
                results[key] = {"arch": arch, "shape": shape_name,
                                "mesh": mesh_kind, "status": "timeout"}
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                json.dump(results, open(args.out, "w"), indent=1)
            st = results[key].get("status")
            print(f"[dryrun] {key}: {st}", flush=True)
        n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
        print(f"[dryrun] done: {n_ok}/{len(results)} ok")
        return

    if args.copyscore:
        result = run_cell("copyscore", "pairscore", args.mesh)
    else:
        result = run_cell(args.arch, args.shape, args.mesh)
    if result.get("status") == "ok":
        mem = result.get("memory", {})
        print(f"memory_analysis: args={mem.get('argument_bytes', 0) / 2**30:.2f} GiB "
              f"temp={mem.get('temp_bytes', 0) / 2**30:.2f} GiB "
              f"peak={mem.get('peak_bytes', 0) / 2**30:.2f} GiB per device")
        print(f"cost_analysis: flops/device={result['flops_per_device']:.3e} "
              f"bytes/device={result['hbm_bytes_per_device']:.3e} "
              f"collective bytes/device={result['collective_bytes_per_device']:.3e}")
        print(f"roofline terms (s): compute={result['compute_s']:.4f} "
              f"memory={result['memory_s']:.4f} "
              f"collective={result['collective_s']:.4f} "
              f"→ {result['bottleneck']}-bound")
    print("CELLRESULT" + json.dumps(result))


if __name__ == "__main__":
    main()
