"""Benchmark datasets: synthetics shaped like the paper's Table V."""
from __future__ import annotations


import numpy as np

from repro.data.claims import (
    SyntheticClaims,
    SyntheticSpec,
    oracle_claim_probs,
    synthetic_claims,
)

# (name, spec, pairwise_mode): 'full' = run PAIRWISE end-to-end;
# 'extrapolate' = time PAIRWISE on 10% of items and scale linearly
# (documented — the paper's own PAIRWISE on Book-full took 11,536 s)
BENCH_SPECS = {
    "book_cs": (SyntheticSpec(n_sources=894, n_items=2528, coverage="book",
                              n_cliques=25, clique_size=3, clique_items=12,
                              seed=0), "full"),
    "stock_1day": (SyntheticSpec(n_sources=55, n_items=16000, coverage="stock",
                                 n_cliques=6, clique_size=3, seed=0), "full"),
    # large sets sized for the single-core CPU container (the paper's scale
    # runs on the TPU path; relative cascades are what these measure)
    "book_full": (SyntheticSpec(n_sources=3182, n_items=8000, coverage="book",
                                n_cliques=60, clique_size=3, clique_items=12,
                                seed=0), "extrapolate"),
    "stock_2wk": (SyntheticSpec(n_sources=55, n_items=32000, coverage="stock",
                                n_cliques=6, clique_size=3, seed=0),
                  "extrapolate"),
}

SMALL = ("book_cs", "stock_1day")

# DetectionEngine scaling matrix (benchmarks.run scaling): source counts
# spanning two orders of magnitude, run single- vs multi-device. Item counts
# grow sub-linearly so the 2k case stays tractable on the CPU container.
SCALING_SPECS = {
    64: SyntheticSpec(n_sources=64, n_items=384, coverage="book",
                      n_cliques=4, clique_size=3, clique_items=12, seed=0),
    512: SyntheticSpec(n_sources=512, n_items=1536, coverage="book",
                       n_cliques=14, clique_size=3, clique_items=12, seed=0),
    2048: SyntheticSpec(n_sources=2048, n_items=3072, coverage="book",
                        n_cliques=50, clique_size=3, clique_items=12, seed=0),
}


_cache: dict = {}


def load(name: str) -> tuple[SyntheticClaims, np.ndarray]:
    if name not in _cache:
        spec, _ = BENCH_SPECS[name]
        sc = synthetic_claims(spec)
        _cache[name] = (sc, oracle_claim_probs(sc))
    return _cache[name]


def pairwise_mode(name: str) -> str:
    return BENCH_SPECS[name][1]
